from .checkpoint import (CheckpointManager, latest_step,  # noqa: F401
                         load_checkpoint, save_checkpoint)
