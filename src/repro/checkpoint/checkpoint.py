"""Checkpointing: atomic, sharded, async-capable, restart-safe.

Format: one directory per step containing <leaf-path>.npy files plus a
manifest (tree structure + step + rng + dataset cursor). Writes go to a
tmp dir then os.replace() — a crash mid-write never corrupts the latest
checkpoint (fault-tolerance requirement). Durability is explicit, not
assumed: every written file, the tmp dir, and the parent dir after the
rename are fsync'd, so once save() returns the checkpoint survives a
power cut — os.replace alone is only atomic against OTHER renames; the
kernel was still free to lose both the data and the rename itself. A
background thread makes save() non-blocking (training continues during
I/O); `keep` bounds disk. Stale ``.tmp-*`` dirs from killed writers are
swept on the next save and are invisible to latest_step/load.

On real multi-host pods each host writes only the shards it owns
(process-local addressable shards); on this single-process container that
degenerates to full arrays — the code path is the same.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

# np.save can't roundtrip bfloat16 (stores void16): save as uint16 view
# and restore via the manifest's logical dtype.
_VIEW_SAVE = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
              "float8_e5m2": np.uint8}
_VIEW_LOAD = {"bfloat16": ml_dtypes.bfloat16,
              "float8_e4m3fn": ml_dtypes.float8_e4m3fn,
              "float8_e5m2": ml_dtypes.float8_e5m2}


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        flat[key] = leaf
    return flat


def _fsync_path(path):
    """fsync a file or directory by path — force the DATA (or the dir's
    entries) to disk, not just into the page cache."""
    fd = os.open(str(path), os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save_checkpoint(ckpt_dir: str, step: int, tree,
                    extra: Optional[dict] = None, keep: int = 3) -> str:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    # sweep leftovers of writers that died mid-save (different pid, or a
    # previous incarnation of this one) — published steps never match
    for stale in ckpt_dir.glob(".tmp-*"):
        shutil.rmtree(stale, ignore_errors=True)
    tmp = ckpt_dir / f".tmp-{step}-{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    flat = _flatten(tree)
    manifest = {"step": step, "keys": [], "extra": extra or {},
                "time": time.time()}
    for i, (key, leaf) in enumerate(sorted(flat.items())):
        arr = np.asarray(leaf)
        logical = str(arr.dtype)
        if logical in _VIEW_SAVE:
            arr = arr.view(_VIEW_SAVE[logical])
        fname = f"leaf{i:05d}.npy"
        np.save(tmp / fname, arr)
        manifest["keys"].append({"key": key, "file": fname,
                                 "dtype": logical,
                                 "shape": list(arr.shape)})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    # durability barrier, in dependency order: file data first, then the
    # tmp dir's entries, THEN the rename, then the parent dir so the
    # rename itself is on disk before the caller is told the step exists
    for f in tmp.iterdir():
        _fsync_path(f)
    _fsync_path(tmp)
    final = ckpt_dir / f"step_{step:010d}"
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)                    # atomic publish
    _fsync_path(ckpt_dir)
    _gc(ckpt_dir, keep)
    return str(final)


def _gc(ckpt_dir: Path, keep: int):
    steps = sorted(ckpt_dir.glob("step_*"))
    for old in steps[:-keep]:
        shutil.rmtree(old, ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = sorted(Path(ckpt_dir).glob("step_*"))
    if not steps:
        return None
    return int(steps[-1].name.split("_")[1])


def load_checkpoint(ckpt_dir: str, tree_like,
                    step: Optional[int] = None) -> Tuple[Any, int, dict]:
    """Restore into the structure of `tree_like`. Returns
    (tree, step, extra)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    d = Path(ckpt_dir) / f"step_{step:010d}"
    manifest = json.loads((d / "manifest.json").read_text())
    by_key = {}
    for e in manifest["keys"]:
        arr = np.load(d / e["file"])
        if e["dtype"] in _VIEW_LOAD:
            arr = arr.view(_VIEW_LOAD[e["dtype"]])
        by_key[e["key"]] = arr
    flat_like = _flatten(tree_like)
    assert set(flat_like) == set(by_key), (
        f"checkpoint/tree mismatch: {set(flat_like) ^ set(by_key)}")
    leaves, treedef = jax.tree_util.tree_flatten(tree_like)
    keys_in_order = list(_flatten(tree_like).keys())
    restored = treedef.unflatten([by_key[k] for k in keys_in_order])
    return restored, manifest["step"], manifest["extra"]


class CheckpointManager:
    """Async save + resume. save() snapshots to host memory synchronously
    (cheap) and writes on a worker thread (non-blocking)."""

    def __init__(self, ckpt_dir: str, keep: int = 3, every: int = 100):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self.every = every
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def maybe_save(self, step: int, tree, extra: Optional[dict] = None,
                   blocking: bool = False):
        if step % self.every:
            return
        self.wait()
        host_tree = jax.tree_util.tree_map(np.asarray, tree)  # snapshot

        def work():
            try:
                save_checkpoint(self.ckpt_dir, step, host_tree, extra,
                                self.keep)
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def restore_or_none(self, tree_like):
        try:
            return load_checkpoint(self.ckpt_dir, tree_like)
        except FileNotFoundError:
            return None
