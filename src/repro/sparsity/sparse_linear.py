"""DB-PIM sparsity as a first-class LM feature.

The paper evaluates CNNs; this module applies the identical hybrid-grained
pipeline (block-wise value pruning -> FTA bit-level quantization) to the
projection matrices of any of the 10 assigned architectures:

  * `sparsify_params` compresses every eligible projection (attention
    q/k/v/o, MLP gate/up/down, MoE experts, SSM in/out) — stacked layer
    tensors are handled per-layer; routers/norms/embeddings stay dense
    (same reasoning as the paper's dw-conv exclusion);
  * `dequant_tree` reconstructs FTA-compliant float weights (exact on the
    INT8 x scale grid) so the SAME model code runs the compressed model;
  * `pim_speedup_estimate` maps each projection to the DB-PIM cost model
    -> a beyond-paper result: DB-PIM speedup/energy for transformer
    inference (EXPERIMENTS.md §Beyond-paper).

On TPU the compressed tensors feed the Pallas kernels
(kernels.block_sparse_matmul for the value level, kernels.fta_int8_matmul
for the bit level).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fta, pruning
from repro.core.pim_model import (DEFAULT_PIM, LayerGEMM, evaluate_model,
                                  evaluate_dense_baseline,
                                  sparsity_from_export)
from repro.models.config import ModelConfig

#: Kernel dispatch modes for compressed projections. "value" skips pruned
#: weight blocks (block_sparse_matmul), "bit" serves FTA/INT8 weights
#: (fta_int8_matmul), "joint" fuses both in one kernel
#: (joint_sparse_matmul) — the paper's headline configuration.
KERNEL_MODES = ("dense", "value", "bit", "joint")

ELIGIBLE = re.compile(
    r"(attn|xattn)/(wq|wk|wv|wo)$|mlp/w_(gate|up|down)$|"
    r"moe/w_(gate|up|down)$|moe/dense_mlp/w_(gate|up|down)$|"
    r"ssm/(in_proj|out_proj)$")


@dataclass
class DBPIMCompressed:
    """Compressed weight artifact tree + per-tensor sparsity metadata."""
    tensors: Dict[str, dict] = field(default_factory=dict)
    report: Dict[str, dict] = field(default_factory=dict)


def _key(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


def _compress_2d(w2: np.ndarray, value_sparsity: float, alpha: int):
    K, N = w2.shape
    pad = (-N) % alpha
    if pad:
        w2 = np.pad(w2, ((0, 0), (0, pad)))
    mask = np.asarray(pruning.block_prune_mask(w2.astype(np.float32),
                                               value_sparsity, alpha))
    amax = np.abs(w2).max() + 1e-12
    scale = amax / 127.0
    q = np.clip(np.round(w2 / scale), -127, 127).astype(np.int32)
    q_fta, phi = fta.fta_quantize(q, mask)
    return q_fta, float(scale), mask, np.asarray(phi), pad


def sparsify_params(params, cfg: ModelConfig,
                    value_sparsity: Optional[float] = None,
                    alpha: int = 8) -> DBPIMCompressed:
    vs = cfg.dbpim_value_sparsity if value_sparsity is None else value_sparsity
    out = DBPIMCompressed()
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    for path, leaf in flat:
        key = _key(path)
        if not ELIGIBLE.search(key) or leaf.ndim < 2:
            continue
        arr = np.asarray(leaf, dtype=np.float32)
        lead = arr.shape[:-2]
        arr2 = arr.reshape((-1,) + arr.shape[-2:])
        qs, masks, phis = [], [], []
        scale_list = []
        pad = 0
        for l in range(arr2.shape[0]):
            q, scale, mask, phi, pad = _compress_2d(arr2[l], vs, alpha)
            qs.append(q)
            masks.append(mask)
            phis.append(phi)
            scale_list.append(scale)
        q_all = np.stack(qs).reshape(lead + qs[0].shape)
        mask_all = np.stack(masks).reshape(lead + masks[0].shape)
        out.tensors[key] = {
            "q": q_all.astype(np.int8), "scale": np.asarray(scale_list,
                                                            np.float32),
            "mask": mask_all.astype(np.int8), "pad": pad,
            "orig_shape": arr.shape, "dtype": str(leaf.dtype),
        }
        sp = sparsity_from_export(qs[0] * masks[0], masks[0], phis[0])
        out.report[key] = {
            "value_sparsity": sp.value_sparsity,
            "bit_sparsity": fta.achieved_bit_sparsity(qs[0], masks[0]),
            "phi_hist": sp.phi_hist,
            "int8_bytes": int(q_all.size),
            "orig_bytes": int(arr.size * (2 if "bfloat16" in str(leaf.dtype)
                                          else 4)),
        }
    return out


def dequant_tree(params, comp: DBPIMCompressed):
    """Replace eligible leaves with their FTA-compliant reconstruction."""
    def visit(path, leaf):
        key = _key(path)
        t = comp.tensors.get(key)
        if t is None:
            return leaf
        lead = t["orig_shape"][:-2]
        q = t["q"].reshape((-1,) + t["q"].shape[-2:]).astype(np.float32)
        w = q * t["scale"].reshape(-1, 1, 1)
        if t["pad"]:
            w = w[:, :, :-t["pad"]]
        w = w.reshape(t["orig_shape"])
        return jnp.asarray(w, dtype=leaf.dtype)

    return jax.tree_util.tree_map_with_path(visit, params)


def pim_speedup_estimate(comp: DBPIMCompressed, cfg: ModelConfig,
                         tokens: int = 64):
    """Map the compressed projections onto the DB-PIM cost model:
    speedup/energy/U_act of running this LM's matmuls on the paper's chip
    vs its dense digital-PIM baseline."""
    layers, sps = [], {}
    for key, t in comp.tensors.items():
        q2 = t["q"].reshape((-1,) + t["q"].shape[-2:])
        mask2 = t["mask"].reshape((-1,) + t["mask"].shape[-2:])
        K, N = q2.shape[-2:]
        g = LayerGEMM(key, M=tokens, K=K, N=N, kind="fc")
        layers.append(g)
        phi = np.asarray(fta.compute_thresholds(
            q2[0].astype(np.int32), mask2[0].astype(np.int32)))
        sps[key] = sparsity_from_export(q2[0].astype(np.int32),
                                        mask2[0].astype(np.int32), phi)
    ours = evaluate_model(layers, sps, use_input_bit=False)
    dense = evaluate_dense_baseline(layers)
    return {
        "speedup": dense.cycles / ours.cycles,
        "energy_savings": 1 - ours.energy_pj / dense.energy_pj,
        "u_act": ours.u_act,
        "n_projections": len(layers),
    }


# ---------------------------------------------------------------------------
# Kernel-mode dispatch: pack projections once offline, then intercept the
# model's matmuls (the dense_fn hook of apply_mlp / attention) with the
# Pallas kernel selected by ModelConfig.dbpim_mode.
# ---------------------------------------------------------------------------

def pack_projection(w2, mode: str, value_sparsity: float = 0.6) -> dict:
    """Compile one 2D projection (K, N) into the artifact for `mode`.

    Value pruning here is TILE-granular (ops.tile_prune_mask) — the unit
    the kernels can skip; the paper-faithful 1 x alpha pruning lives in
    sparsify_params for the accuracy/cost-model artifacts. Falls back to
    a reference artifact (same math, plain jnp) when the weight shape
    does not divide the kernel tiling — "joint" pads internally and
    never needs the fallback.
    """
    from repro.kernels import block_sparse_matmul as bsk
    from repro.kernels import fta_int8_matmul as ftk
    from repro.kernels import ops
    if mode not in KERNEL_MODES:
        raise ValueError(f"mode {mode!r} not in {KERNEL_MODES}")
    w = np.asarray(w2, np.float32)
    K, N = w.shape
    if mode == "dense":
        return {"kind": "dense"}
    if mode == "joint":
        packed = ops.pack_joint_sparse(w, value_sparsity=value_sparsity)
        return {"kind": "joint", "packed": packed}

    if mode == "value":
        # tile-granular pruning: the unit block_sparse_matmul can skip
        mask = ops.tile_prune_mask(w, value_sparsity, bsk.BK, bsk.BN)
        art = {"kind": "value_ref", "w": jnp.asarray(w * mask)}
        if K % bsk.BK == 0 and N % bsk.BN == 0:
            w_blocks, idx = ops.pack_block_sparse(w * mask,
                                                  np.ones_like(w, np.int32))
            art.update(kind="value", w_blocks=w_blocks, idx=idx)
        return art
    # mode == "bit": per-filter INT8 scale + FTA projection, dense layout
    # (no value pruning — same quantization step the joint pack uses)
    q, scales = ops.quantize_int8_fta(w, np.ones_like(w, np.int32))
    kind = "bit" if (K % ftk.BK == 0 and N % ftk.BN == 0) else "bit_ref"
    return {"kind": kind, "q": jnp.asarray(q.astype(np.int8)),
            "scales": jnp.asarray(scales)}


def build_kernel_tables(named_weights: Dict[str, np.ndarray],
                        cfg: Optional[ModelConfig] = None,
                        mode: Optional[str] = None,
                        value_sparsity: Optional[float] = None,
                        ) -> Dict[str, dict]:
    """Pack every named 2D projection for the configured kernel mode."""
    mode = mode or (cfg.dbpim_mode if cfg is not None else "joint")
    vs = value_sparsity if value_sparsity is not None else \
        (cfg.dbpim_value_sparsity if cfg is not None else 0.6)
    return {name: pack_projection(w, mode, vs)
            for name, w in named_weights.items()}


def kernel_dense_fn(tables: Dict[str, dict], interpret: bool = None):
    """Build the dense_fn(w, x, name) hook for apply_mlp / attention.

    Projections found in `tables` run on the packed artifact (Pallas
    kernel or its reference fallback); anything else stays a plain
    matmul. Kernel tilings that need M % 128 == 0 fall back to the
    reference math for ragged activation batches.
    """
    from repro.kernels import block_sparse_matmul as bsk
    from repro.kernels import fta_int8_matmul as ftk
    from repro.kernels import ops

    def mm(w, x, name):
        t = tables.get(name)
        if t is None or t["kind"] == "dense":
            return x @ w
        rows = int(np.prod(x.shape[:-1]))
        if t["kind"] == "joint":
            return ops.joint_dense(x, t["packed"],
                                   interpret=interpret).astype(x.dtype)
        if t["kind"] == "value" and rows % bsk.BM == 0:
            return ops.sparse_dense(x, t["w_blocks"].astype(x.dtype),
                                    t["idx"], interpret=interpret)
        if t["kind"] in ("value", "value_ref"):
            return x @ t["w"].astype(x.dtype)
        if t["kind"] == "bit" and rows % ftk.BM == 0:
            return ops.fta_dense(x, t["q"], t["scales"],
                                 interpret=interpret).astype(x.dtype)
        # bit_ref / ragged-M bit: same INT8 x scale math in plain jnp
        wd = t["q"].astype(jnp.float32) * t["scales"]
        return (x.astype(jnp.float32) @ wd).astype(x.dtype)

    return mm


# ---------------------------------------------------------------------------
# Stacked serving tables: ALL L layers of every projection family packed
# with one shared MAXB, as scan-carryable arrays. This is what lets
# `lax.scan`-stacked forwards (transformer / SSM / decode) run the joint
# kernel end-to-end instead of per-layer: the scan slices the leading
# layer axis, the body rebuilds the per-layer JointPacked view and
# dispatches through the same dense_fn(w, x, name) hook the layers
# already accept.
# ---------------------------------------------------------------------------

@dataclass
class StackedKernelTables:
    """Scan-carryable joint-sparse weights for a whole layer stack.

    ``arrays`` is a pytree of stacked jnp arrays (leading axis = layer) —
    pass it as scan xs next to the stacked params. ``static`` holds the
    per-projection (k, n, k_pad) logical dims the per-layer JointPacked
    view needs (scan cannot carry python ints). Grouped (MoE expert)
    entries — keys ``moe/*`` — carry a second leading axis E after the
    layer axis; the per-expert dispatch is the ``expert`` attribute of
    the dense_fn hook (models.moe routes its batched expert einsums
    through it).
    """
    arrays: Dict[str, Dict[str, jnp.ndarray]]
    static: Dict[str, Tuple[int, int, int]]
    interpret: Optional[bool] = None

    def dense_fn(self, slices):
        """Build the dense_fn(w, x, name) hook from one layer's slices
        (the per-iteration xs the scan body receives). The returned hook
        carries the grouped per-expert variant as ``mm.expert`` —
        ``expert(w, x, name)`` computes the batched expert contraction
        ``x[..., e, :, :] @ w[e]`` for every expert through the joint
        kernel (one ``joint_dense`` call per packed expert slice) when
        ``name`` is packed, and falls back to the plain einsum
        otherwise."""
        from repro.kernels import ops

        def _packed(t, name, e=None):
            k, n, k_pad = self.static[name]
            a = (t if e is None
                 else {key: arr[e] for key, arr in t.items()})
            return ops.JointPacked(a["w_blocks"], a["idx"], a["scales"],
                                   a["nblocks"], k, n, k_pad)

        def mm(w, x, name):
            t = None if slices is None else slices.get(name)
            if t is None:
                return x @ w
            return ops.joint_dense(x, _packed(t, name),
                                   interpret=self.interpret).astype(x.dtype)

        def expert(w, x, name):
            """x (..., E, C, D) x w (E, D, F) -> (..., E, C, F)."""
            t = None if slices is None else slices.get(name)
            if t is None:
                return jnp.einsum("...eck,ekf->...ecf", x, w)
            E = t["w_blocks"].shape[0]
            outs = [ops.joint_dense(x[..., e, :, :], _packed(t, name, e),
                                    interpret=self.interpret).astype(x.dtype)
                    for e in range(E)]
            return jnp.stack(outs, axis=-3)

        mm.expert = expert
        return mm


@dataclass
class SegmentedKernelTables:
    """Per-segment stacked packs for a whole decoder (models.segments
    layout): ``segments`` maps segment name -> StackedKernelTables, each
    packed independently with its own shared MAXB. The forward/decode
    segment loops thread ``segments[seg.name]`` through that segment's
    scan.

    ``arrays`` / ``static`` present the flat single-dict view older
    consumers (benchmarks, launch.serve byte accounting) iterate:
    single-segment stacks pass through unprefixed (identical to the
    pre-segmentation layout); multi-segment stacks prefix keys with the
    segment name ("seg02/wq")."""
    segments: Dict[str, StackedKernelTables]

    @property
    def arrays(self) -> Dict[str, Dict[str, jnp.ndarray]]:
        if set(self.segments) == {"blocks"}:
            return self.segments["blocks"].arrays
        return {f"{s}/{name}": t
                for s, seg in self.segments.items()
                for name, t in seg.arrays.items()}

    @property
    def static(self) -> Dict[str, Tuple[int, int, int]]:
        if set(self.segments) == {"blocks"}:
            return self.segments["blocks"].static
        return {f"{s}/{name}": t
                for s, seg in self.segments.items()
                for name, t in seg.static.items()}


def _stacked_projections(params, cfg: ModelConfig):
    """segment name -> {hook name -> stacked weight} for every decoder
    segment (models.segments.decoder_layout / packable_projections —
    the shared single source of truth). Rank-3 (L, K, N) entries pack
    per-layer; rank-4 ``moe/*`` entries (L, E, K, N) pack grouped across
    the expert axis too. Routers stay dense (same reasoning as the
    paper's dw-conv exclusion: tiny, accuracy-critical). Returns None
    (dense serving) when the param tree does not carry the stacked
    segment subtrees."""
    from repro.models.segments import decoder_layout, packable_projections

    out = {}
    for seg in decoder_layout(cfg):
        blk = params.get(seg.name)
        if blk is None:
            return None
        projs = {}
        for name in packable_projections(seg, cfg):
            node = blk
            for part in _proj_subpath(seg, name).split("/"):
                node = node.get(part) if isinstance(node, dict) else None
                if node is None:
                    break
            if node is None:
                continue        # e.g. gelu MLP has no w_gate
            projs[name] = node
        out[seg.name] = projs
    return out


def _proj_subpath(seg, name: str) -> str:
    """Param subpath of a hook name within one segment's block tree."""
    from repro.models.segments import projection_param_path
    full = projection_param_path(seg, name)
    return full[len(seg.name) + 1:]


def build_stacked_tables(params, cfg: ModelConfig,
                         mode: Optional[str] = None,
                         value_sparsity: Optional[float] = None,
                         bk: Optional[int] = None, bn: Optional[int] = None,
                         interpret: Optional[bool] = None,
                         ) -> Optional[SegmentedKernelTables]:
    """Pack every eligible stacked projection of `params` for serving,
    per decoder segment (each segment gets its own shared-MAXB pack).

    mode "joint" packs at cfg.dbpim_value_sparsity (column-balanced tile
    pruning + INT8/FTA payload: (1 - vs) * 0.5 of dense bf16 weight
    traffic); "bit" packs the same layout at zero value sparsity (0.5x
    traffic); "value" packs the bf16-PAYLOAD variant of the same layout
    (compacted blocks hold the raw bf16 weights with unit scales:
    (1 - vs) of dense traffic, no bit-level compression) so value-only
    sparsity also serves end-to-end through the scan. "dense" returns
    None — plain matmuls.

    Every family packs (the segment layout closed the matrix: hybrid
    sublayer runs and the whisper decoder — including cross-attention —
    are segments like any other; the whisper ENCODER stays dense, it
    runs once per request and never rides decode-step weight traffic).
    bk/bn default to the kernel tile, clamped down to the padded
    projection dims so reduced smoke configs (d_model < 128) do not pack
    pure padding.
    """
    from repro.kernels import ops

    mode = mode or (cfg.dbpim_mode if cfg.dbpim else "dense")
    if mode not in KERNEL_MODES:
        raise ValueError(f"mode {mode!r} not in {KERNEL_MODES}")
    if mode == "dense":
        return None
    if mode == "bit":
        vs = 0.0
    else:
        vs = value_sparsity if value_sparsity is not None else \
            cfg.dbpim_value_sparsity
    payload = "bf16" if mode == "value" else "int8"
    by_segment = _stacked_projections(params, cfg)
    if by_segment is None:
        return None

    segments: Dict[str, StackedKernelTables] = {}
    for seg_name, projections in by_segment.items():
        arrays: Dict[str, Dict[str, jnp.ndarray]] = {}
        static: Dict[str, Tuple[int, int, int]] = {}
        for name, w in projections.items():
            w = np.asarray(w, np.float32)
            _round8 = lambda d: max(8, 8 * (-(-d // 8)))
            bk_eff = bk if bk is not None else min(ops.BK,
                                                   _round8(w.shape[-2]))
            bn_eff = bn if bn is not None else min(ops.BN,
                                                   _round8(w.shape[-1]))
            pack = (ops.pack_joint_sparse_grouped if w.ndim == 4
                    else ops.pack_joint_sparse_stacked)
            packed = pack(w, value_sparsity=vs or None, bk=bk_eff,
                          bn=bn_eff, payload=payload)
            arrays[name] = {"w_blocks": packed.w_blocks, "idx": packed.idx,
                           "scales": packed.scales,
                           "nblocks": packed.nblocks}
            static[name] = (packed.k, packed.n, packed.k_pad)
        segments[seg_name] = StackedKernelTables(arrays=arrays,
                                                 static=static,
                                                 interpret=interpret)
    return SegmentedKernelTables(segments=segments)


def _packed_param_paths(cfg: ModelConfig):
    """Exact '/'-joined param paths of every packable projection. Exact
    paths — not suffixes — so a whisper decoder pack strips the decoder's
    cross-attention copies but never the dense encoder's identically-
    suffixed ones, and hybrid per-segment copies strip one segment at a
    time."""
    from repro.models.segments import (decoder_layout,
                                       packable_projections,
                                       projection_param_path)
    paths = set()
    for seg in decoder_layout(cfg):
        for name in packable_projections(seg, cfg):
            paths.add(projection_param_path(seg, name))
    return paths


def strip_packed_projections(params, cfg: ModelConfig):
    """Replace every stacked-packed projection with a (L, 1, 1) zero
    placeholder: once the tables serve those matmuls, keeping the dense
    bf16 copies device-resident alongside them would make joint serving
    cost ~1.3x dense HBM instead of ~0.3x. The placeholder keeps the
    param tree structure (scan xs still slice a leading layer axis; the
    dense_fn hook never reads the weight it intercepts) and falls through
    every sharding rule to replicated. Strips exactly what
    build_stacked_tables packs — cross-attention and hybrid per-segment
    copies included; the whisper encoder (unpacked) keeps its weights."""
    if _stacked_projections(params, cfg) is None:
        return params
    paths = _packed_param_paths(cfg)

    def visit(path, leaf):
        if _key(path) in paths:
            return jnp.zeros((leaf.shape[0], 1, 1), leaf.dtype)
        return leaf
    return jax.tree_util.tree_map_with_path(visit, params)


def reconstruct_stacked_params(params, tables: SegmentedKernelTables, cfg):
    """Dense FTA reference weights: replace each packed projection in
    `params` with its unpacked (pruned + dequantized) stack, so the SAME
    plain-matmul forward reproduces what the joint kernels compute — the
    fp32-tolerance reference the stacked serving path is tested against.
    """
    from repro.kernels import ops
    from repro.models.segments import decoder_layout, projection_param_path

    segs = {s.name: s for s in decoder_layout(cfg)}
    recon = {}
    for seg_name, seg_tables in tables.segments.items():
        for name in seg_tables.arrays:
            t = seg_tables.arrays[name]
            k, n, k_pad = seg_tables.static[name]
            if t["w_blocks"].ndim == 6:      # grouped (L, E, ...) experts
                packed = ops.JointPackedGrouped(t["w_blocks"], t["idx"],
                                                t["scales"], t["nblocks"],
                                                k, n, k_pad)
                dense = ops.unpack_joint_sparse_grouped(packed)
            else:
                packed = ops.JointPackedStacked(t["w_blocks"], t["idx"],
                                                t["scales"], t["nblocks"],
                                                k, n, k_pad)
                dense = ops.unpack_joint_sparse_stacked(packed)
            full_path = projection_param_path(segs[seg_name], name)
            recon[full_path] = jnp.asarray(dense)

    def visit(path, leaf):
        dense = recon.get(_key(path))
        if dense is not None:
            return dense.astype(leaf.dtype)
        return leaf
    return jax.tree_util.tree_map_with_path(visit, params)


# ---------------------------------------------------------------------------
# In-graph INT8 weight serving (decode is weight-traffic-bound: storing
# projections INT8 + per-filter scale halves the dominant roofline term).
# jax-traceable => works under eval_shape for the dry-run.
# ---------------------------------------------------------------------------

def quantize_params_for_serving(params):
    """Eligible projection leaves -> {"q": int8, "scale": f32 per-filter}."""
    def visit(path, leaf):
        key = _key(path)
        if not ELIGIBLE.search(key) or leaf.ndim < 2:
            return leaf
        amax = jnp.max(jnp.abs(leaf.astype(jnp.float32)), axis=-2,
                       keepdims=True)
        scale = amax / 127.0 + 1e-12
        q = jnp.clip(jnp.round(leaf.astype(jnp.float32) / scale),
                     -127, 127).astype(jnp.int8)
        return {"q": q, "scale": scale.astype(jnp.float32)}
    return jax.tree_util.tree_map_with_path(visit, params)


def dequant_params_for_serving(qparams, dtype=jnp.bfloat16):
    """Inverse of quantize_params_for_serving (dequant fuses into matmuls
    on TPU — HBM traffic stays INT8)."""
    def visit(node):
        if isinstance(node, dict) and set(node) == {"q", "scale"}:
            return (node["q"].astype(jnp.float32) * node["scale"]
                    ).astype(dtype)
        return node
    return jax.tree_util.tree_map(
        visit, qparams,
        is_leaf=lambda x: isinstance(x, dict) and set(x) == {"q", "scale"})
