from .sparse_linear import (DBPIMCompressed, dequant_tree,  # noqa: F401
                            pim_speedup_estimate, sparsify_params)
