"""Trip-aware jaxpr cost analysis.

XLA-CPU's `compiled.cost_analysis()` counts `while` (lax.scan) bodies ONCE
— a 36-layer scanned model reports ~1/36 of its FLOPs. This walker
recurses through scan/cond/pjit/remat with the static trip counts jax
knows, giving exact matmul FLOPs (and an elementwise tally) for the
roofline compute term, plus an HBM-traffic estimate for the memory term.

Traffic model: dot_general counts operands + result once per execution
(weights re-read per microbatch — matching an HBM-resident weight-
stationary-per-step schedule); other ops count result bytes only
(elementwise chains fuse; their inputs are usually some other op's
freshly-written result, already counted). Gather/scatter count operand +
result. This is an estimate — it cannot see XLA's actual fusion — but it
is trip-correct, which dominates the error.

WEIGHT traffic (`weight_bytes`): the decode roofline term the DB-PIM
serving path attacks. Three rules, in precedence order per operand:
  * PROVENANCE (the exact rule): `analyze(fn, params, ...)` tags every
    leaf of the argument(s) named by `weight_argnums` (default: arg 0,
    the params pytree at every call site in this repo) and propagates
    the tag through structural ops (convert/reshape/transpose/slice/
    broadcast) and into scan/cond/pjit/remat bodies by positional invar
    mapping. A dot_general operand that still carries the tag is a
    stored-parameter read and charges its full bytes — REGARDLESS of
    rank or batch dims. This is what counts the MoE per-expert einsum
    (`ecd,edf->ecf` — the rank-3 `edf` weight lowers with a batch dim,
    and jnp.einsum may even place it as the LHS operand) and any other
    stacked rank-3+ parameter read, while leaving attention/SSM
    activation einsums (operands PRODUCED in-graph: conv outputs,
    updated KV caches, softmax probs) uncharged even though some share
    the (rank-3, one-batch-dim) shape signature.
  * dot_general shape fallback: the rhs operand when it is rank-2 with
    no batch dims — `x @ W` projections whose weight lost its tag to a
    non-structural op (e.g. the in-graph int8 dequant multiply).
    Charged through `convert_src`, so an int8 weight dequantized
    in-graph charges 1 B/element.
  * pallas_call: every operand that is NOT a plain rank-2 float
    activation — i.e. integer payloads/index tables (int8 w_blocks,
    int32 idx) plus rank-2 floats with a leading broadcast dim of 1
    (per-filter scales) and float operands of rank != 2 (block payloads).
    For the packed kernels this is exactly payload + idx + scales.

PER-PATH WATERFALL (`weight_bytes_by_path`): every byte charged into
`weight_bytes` is ALSO attributed to the parameter path it came from —
the provenance tags carry the pytree key path of the seeding leaf
("blocks/attn/wq", "seg00/blocks/ssm/w_in", "blocks/moe/w1", ...), and
`const_weights` extends the same tagging to arrays CLOSED OVER by the
step function (the stacked kernel tables, matched by object identity
against the jaxpr's constvars and labeled "tables/<family>/<part>").
Bytes charged by the shape fallbacks, whose provenance is unknown, land
in explicit "(untagged ...)" rows. The rows are charged at exactly the
same sites with exactly the same integer byte values as the scalar, so
`sum(weight_bytes_by_path.values()) == weight_bytes` holds EXACTLY —
the equality the serving benchmark asserts per call kind.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import numpy as np
from jax import core as jcore


def _dtype_bytes(aval) -> int:
    try:
        return np.dtype(aval.dtype).itemsize
    except Exception:
        return 4


def _nelems(aval) -> int:
    try:
        n = 1
        for d in aval.shape:
            n *= int(d)
        return n
    except Exception:
        return 0


def _bytes(aval) -> int:
    return _nelems(aval) * _dtype_bytes(aval)


def _dot_flops(eqn) -> int:
    """2 * prod(out) * prod(contract dims of lhs)."""
    (lc, _), _ = eqn.params["dimension_numbers"]
    lhs = eqn.invars[0].aval
    out = eqn.outvars[0].aval
    k = 1
    for d in lc:
        k *= int(lhs.shape[d])
    return 2 * _nelems(out) * k


_SUBJAXPR_PARAMS = ("jaxpr", "call_jaxpr", "fun_jaxpr", "cond_jaxpr",
                    "body_jaxpr")

#: ops that read a stored array without computing on it — a tagged
#: (parameter-provenance) input keeps its tag through these. Anything
#: else (adds, muls, scatters, ...) produces a NEW array and drops it.
_STRUCTURAL = ("convert_element_type", "reshape", "transpose", "squeeze",
               "expand_dims", "slice", "dynamic_slice", "rev",
               "broadcast_in_dim", "sharding_constraint", "copy")


def _is_var(v) -> bool:
    return isinstance(v, jcore.Var)


def _map_tags(outer_invars, inner_invars, tagged):
    """Positional outer->inner tag mapping for sub-jaxpr recursion (scan
    consts+carry+xs, pjit/remat bodies). Tags are {var: param path}. A
    count mismatch (e.g. while's cond consts) drops the tags —
    undercounting is the safe failure."""
    if len(outer_invars) != len(inner_invars):
        return {}
    return {iv: tagged[ov] for ov, iv in zip(outer_invars, inner_invars)
            if _is_var(ov) and ov in tagged}


def _is_pallas_weight(aval) -> bool:
    """Weight-operand heuristic for pallas_call (see module docstring):
    everything except a plain rank-2 float activation counts as stored
    weight/metadata — int8 payloads, int32 index tables, (1, N) scales,
    rank>2 block payloads. Known limit: an INTEGER activation (only the
    dbmu bit-true oracle, which no serving graph contains) would be
    misclassified as weight."""
    try:
        kind = np.dtype(aval.dtype).kind
        shape = tuple(aval.shape)
    except Exception:
        return False
    if kind in ("i", "u"):
        return True
    # floating covers bf16 payloads too: ml_dtypes' bfloat16 reports
    # numpy kind "V" (void), so a kind == "f" check alone would silently
    # drop the value-only stacked payload from the weight tally
    is_float = kind == "f" or jax.numpy.issubdtype(aval.dtype,
                                                   jax.numpy.floating)
    return is_float and (len(shape) != 2 or shape[0] == 1)


#: waterfall rows for bytes the shape fallbacks charge — provenance
#: unknown, but the bytes must still appear in a row so the rows sum to
#: weight_bytes exactly
UNTAGGED_DOT = "(untagged dot rhs)"
UNTAGGED_PALLAS = "(untagged pallas operand)"


def _walk(jaxpr, mult: int, acc: Dict[str, float],
          convert_src: Dict[Any, Any] = None, weight_vars=None, wf=None):
    # convert_src: var -> pre-convert var, so a dot whose operand is a
    # freshly dequantized int8 weight charges int8 bytes (the dequant
    # fuses into the matmul on TPU; HBM sees the int8 tensor).
    # weight_vars: {var: param path} with parameter provenance (see
    # module docstring); grown in place as structural ops pass tags along.
    # wf: the per-path waterfall accumulator ({path: bytes}); every
    # weight_bytes charge below mirrors into it at the same value.
    convert_src = {} if convert_src is None else convert_src
    weight_vars = {} if weight_vars is None else weight_vars

    def tag_of(v):
        if not _is_var(v):
            return None
        p = weight_vars.get(v)
        if p is None:
            p = weight_vars.get(convert_src.get(v, v))
        return p

    def tagged(v):
        return tag_of(v) is not None

    def charge(b, path):
        acc["weight_bytes"] += b
        if wf is not None:
            wf[path] = wf.get(path, 0.0) + b

    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim in _STRUCTURAL and eqn.invars and tagged(eqn.invars[0]):
            weight_vars[eqn.outvars[0]] = tag_of(eqn.invars[0])
        if prim == "convert_element_type" and len(eqn.invars) == 1:
            convert_src[eqn.outvars[0]] = eqn.invars[0]
            continue          # dtype converts fuse; no HBM traffic charged
        if prim == "dot_general":
            f = _dot_flops(eqn) * mult
            acc["dot_flops"] += f
            acc["flops"] += f
            op_bytes = 0
            for v in eqn.invars:
                src = convert_src.get(v, v) if _is_var(v) else v
                op_bytes += _bytes(src.aval)
            acc["bytes"] += (op_bytes
                             + _bytes(eqn.outvars[0].aval)) * mult
            # weight traffic, per operand (charged once each):
            #   1. parameter provenance — exact, any rank (MoE expert
            #      einsums place the rank-3 weight on either side);
            #   2. rank-2 no-batch rhs — the x @ W shape fallback for
            #      weights whose tag died (in-graph int8 dequant).
            charged = [False, False]
            for i, v in enumerate(eqn.invars):
                path = tag_of(v)
                if path is not None:
                    src = convert_src.get(v, v)
                    charge(_bytes(src.aval) * mult, path)
                    charged[i] = True
            _, (_, rb) = eqn.params["dimension_numbers"]
            rhs_v = eqn.invars[1]
            rhs = convert_src.get(rhs_v, rhs_v) if _is_var(rhs_v) else rhs_v
            if (not charged[1]
                    and len(getattr(rhs.aval, "shape", ())) == 2 and not rb):
                charge(_bytes(rhs.aval) * mult, UNTAGGED_DOT)
            continue
        if prim == "pallas_call":
            # Custom kernel (e.g. joint_sparse_matmul): its inner jaxpr
            # sees per-BLOCK avals, so plain recursion would undercount
            # by the grid size. Prefer the kernel's static CostEstimate
            # for FLOPs; without one, recurse into the kernel body with
            # the grid trip count as the multiplier (each grid step runs
            # the body once on one block). HBM charges operands + result:
            # packed INT8 payloads charge 1 B/weight and compacted tables
            # only their stored bytes — exactly the joint-sparsity
            # traffic saving the roofline should see.
            ce = eqn.params.get("cost_estimate")
            f = float(getattr(ce, "flops", 0) or 0)
            if f:
                acc["dot_flops"] += f * mult
                acc["flops"] += f * mult
                acc["pallas_flops"] += f * mult
            else:
                grid = getattr(eqn.params.get("grid_mapping"), "grid", ())
                steps = 1
                for g in grid:
                    steps *= int(g)
                inner = eqn.params["jaxpr"]
                sub = {k: 0.0 for k in acc}
                _walk(getattr(inner, "jaxpr", inner), mult * steps, sub)
                acc["dot_flops"] += sub["dot_flops"]
                acc["flops"] += sub["flops"]
                acc["pallas_flops"] += sub["dot_flops"]
            b = (sum(_bytes(v.aval) for v in eqn.invars)
                 + sum(_bytes(v.aval) for v in eqn.outvars)) * mult
            acc["bytes"] += b
            acc["pallas_bytes"] += b
            for v in eqn.invars:
                if _is_pallas_weight(v.aval):
                    charge(_bytes(v.aval) * mult,
                           tag_of(v) or UNTAGGED_PALLAS)
            continue
        if prim == "scan":
            length = int(eqn.params.get("length", 1))
            inner = eqn.params["jaxpr"]
            # scan invars are [consts, carry, xs] and map 1:1 onto the
            # body's invars — a tagged stacked weight carried as xs keeps
            # its tag on the per-iteration slice.
            _walk(inner.jaxpr, mult * length, acc,
                  weight_vars=_map_tags(eqn.invars, inner.jaxpr.invars,
                                        weight_vars), wf=wf)
            continue
        if prim == "while":
            # unbounded a priori; models don't use raw while. Count once.
            _walk(eqn.params["body_jaxpr"].jaxpr, mult, acc, wf=wf)
            continue
        if prim == "cond":
            branches = eqn.params.get("branches", ())
            best = None
            best_wf = None
            for br in branches:
                a = {k: 0.0 for k in acc}
                a_wf = None if wf is None else {}
                _walk(br.jaxpr, mult, a,
                      weight_vars=_map_tags(eqn.invars[1:], br.jaxpr.invars,
                                            weight_vars), wf=a_wf)
                if best is None or a["flops"] > best["flops"]:
                    best, best_wf = a, a_wf
            if best:
                for k in acc:
                    acc[k] += best[k]
                if wf is not None and best_wf:
                    for p, b in best_wf.items():
                        wf[p] = wf.get(p, 0.0) + b
            continue
        handled = False
        for pname in _SUBJAXPR_PARAMS:
            if pname in eqn.params:
                sub = eqn.params[pname]
                inner = getattr(sub, "jaxpr", sub)
                _walk(inner, mult, acc,
                      weight_vars=_map_tags(eqn.invars, inner.invars,
                                            weight_vars), wf=wf)
                handled = True
                break
        if handled:
            continue
        # leaf op: elementwise/reduce/gather/etc. FLOPs counted; bytes only
        # for data-movement primitives — elementwise/reduce chains between
        # matmuls fuse on TPU (their operands are freshly produced dot
        # results already charged at the dot).
        out_b = sum(_bytes(v.aval) for v in eqn.outvars)
        out_n = sum(_nelems(v.aval) for v in eqn.outvars)
        acc["flops"] += out_n * mult
        if prim in ("gather", "scatter", "scatter-add", "scatter_add",
                    "dynamic_slice", "dynamic_update_slice", "sort",
                    "cumsum", "cumlogsumexp"):
            acc["bytes"] += (out_b + sum(_bytes(v.aval)
                                         for v in eqn.invars)) * mult


def _path_str(key_path) -> str:
    """'blocks/attn/wq'-style label from a tree_util key path."""
    parts = []
    for k in key_path:
        if hasattr(k, "key"):             # DictKey
            parts.append(str(k.key))
        elif hasattr(k, "idx"):           # SequenceKey
            parts.append(str(k.idx))
        elif hasattr(k, "name"):          # GetAttrKey
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


def analyze(fn, *args, weight_argnums: Tuple[int, ...] = (0,),
            const_weights: Dict[str, Any] = None) -> Dict[str, float]:
    """Trip-aware cost of `fn(*args)` (args may be ShapeDtypeStructs).

    weight_argnums: which positional args hold stored parameters — their
    leaves seed the provenance tags behind the exact weight_bytes rule
    (module docstring). Every call site in this repo passes params first,
    so the default (0,) is right; pass () to fall back to the pure shape
    heuristics (e.g. when arg 0 is an activation).

    const_weights: {label: array-or-pytree} of stored weights the step
    CLOSES OVER instead of taking as arguments — the serving engines
    close over their stacked kernel tables. Leaves are matched by object
    identity against the traced jaxpr's constvars and seed provenance
    tags exactly like argument leaves do, so packed-table traffic is
    attributed to its table path in ``weight_bytes_by_path`` instead of
    the untagged-pallas fallback row.

    The result's ``weight_bytes_by_path`` maps parameter paths to the
    weight bytes charged against them; its values sum to
    ``weight_bytes`` exactly (all charges are integer byte counts,
    mirrored per-row at the charge site)."""
    closed = jax.make_jaxpr(fn)(*args)
    acc = {"flops": 0.0, "dot_flops": 0.0, "bytes": 0.0,
           "pallas_flops": 0.0, "pallas_bytes": 0.0, "weight_bytes": 0.0}
    tags = {}
    leaf_counts = [len(jax.tree_util.tree_leaves(a)) for a in args]
    if sum(leaf_counts) == len(closed.jaxpr.invars):
        offsets = np.concatenate([[0], np.cumsum(leaf_counts)])
        for i in weight_argnums:
            if 0 <= i < len(args):
                paths, _ = jax.tree_util.tree_flatten_with_path(args[i])
                invars = closed.jaxpr.invars[offsets[i]:offsets[i + 1]]
                for (kp, _), v in zip(paths, invars):
                    tags[v] = _path_str(kp)
    if const_weights:
        by_id = {}
        for label, tree in const_weights.items():
            for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
                suffix = _path_str(kp)
                by_id[id(leaf)] = (label + "/" + suffix if suffix
                                   else label)
        for cv, cval in zip(closed.jaxpr.constvars, closed.consts):
            label = by_id.get(id(cval))
            if label is not None:
                tags[cv] = label
    wf: Dict[str, float] = {}
    _walk(closed.jaxpr, 1, acc, weight_vars=tags, wf=wf)
    # argument + result residency: params/opt-state are read and written
    # once per step regardless of op-level traffic.
    arg_bytes = sum(_bytes(v.aval) for v in closed.jaxpr.invars)
    acc["arg_bytes"] = float(arg_bytes)
    acc["weight_bytes_by_path"] = wf
    return acc


def analyze_call_kinds(calls: Dict[str, tuple],
                       const_weights: Dict[str, Any] = None
                       ) -> Dict[str, Dict[str, float]]:
    """Per-engine-call-kind cost attribution.

    `calls` maps a call kind — the serving engine's executables, e.g.
    "decode" / "prefill_chunk_exact" / "prefill_parallel" (the builders in
    launch.steps annotate their step fns with a matching ``call_kind``) —
    to an ``(fn, args)`` tuple. Each kind is traced and walked separately,
    so weight_bytes (and every other tally) stays attributable to the
    call that pays it instead of collapsing into one blended number: the
    chunked-prefill traffic savings the benchmarks guard are per-KIND
    contracts (a parallel SSM chunk reads its projections once, an exact
    chunk C times, a decode step once per token). ``const_weights`` is
    forwarded to every analyze call (see analyze)."""
    return {kind: analyze(fn, *args, const_weights=const_weights)
            for kind, (fn, args) in calls.items()}
