"""Trip-aware jaxpr cost analysis.

XLA-CPU's `compiled.cost_analysis()` counts `while` (lax.scan) bodies ONCE
— a 36-layer scanned model reports ~1/36 of its FLOPs. This walker
recurses through scan/cond/pjit/remat with the static trip counts jax
knows, giving exact matmul FLOPs (and an elementwise tally) for the
roofline compute term, plus an HBM-traffic estimate for the memory term.

Traffic model: dot_general counts operands + result once per execution
(weights re-read per microbatch — matching an HBM-resident weight-
stationary-per-step schedule); other ops count result bytes only
(elementwise chains fuse; their inputs are usually some other op's
freshly-written result, already counted). Gather/scatter count operand +
result. This is an estimate — it cannot see XLA's actual fusion — but it
is trip-correct, which dominates the error.

WEIGHT traffic (`weight_bytes`): the decode roofline term the DB-PIM
serving path attacks. Heuristics, documented because they are heuristics:
  * dot_general: the rhs operand when it is rank-2 with no batch dims —
    every projection in this codebase is `x @ W` with a 2D weight, while
    attention/SSM einsums carry batch dims or higher rank. Charged
    through `convert_src`, so an int8 weight dequantized in-graph
    charges 1 B/element.
  * pallas_call: every operand that is NOT a plain rank-2 float
    activation — i.e. integer payloads/index tables (int8 w_blocks,
    int32 idx) plus rank-2 floats with a leading broadcast dim of 1
    (per-filter scales) and float operands of rank != 2 (block payloads).
    For the packed kernels this is exactly payload + idx + scales.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import numpy as np
from jax import core as jcore


def _dtype_bytes(aval) -> int:
    try:
        return np.dtype(aval.dtype).itemsize
    except Exception:
        return 4


def _nelems(aval) -> int:
    try:
        n = 1
        for d in aval.shape:
            n *= int(d)
        return n
    except Exception:
        return 0


def _bytes(aval) -> int:
    return _nelems(aval) * _dtype_bytes(aval)


def _dot_flops(eqn) -> int:
    """2 * prod(out) * prod(contract dims of lhs)."""
    (lc, _), _ = eqn.params["dimension_numbers"]
    lhs = eqn.invars[0].aval
    out = eqn.outvars[0].aval
    k = 1
    for d in lc:
        k *= int(lhs.shape[d])
    return 2 * _nelems(out) * k


_SUBJAXPR_PARAMS = ("jaxpr", "call_jaxpr", "fun_jaxpr", "cond_jaxpr",
                    "body_jaxpr")


def _is_pallas_weight(aval) -> bool:
    """Weight-operand heuristic for pallas_call (see module docstring):
    everything except a plain rank-2 float activation counts as stored
    weight/metadata — int8 payloads, int32 index tables, (1, N) scales,
    rank>2 block payloads. Known limit: an INTEGER activation (only the
    dbmu bit-true oracle, which no serving graph contains) would be
    misclassified as weight."""
    try:
        kind = np.dtype(aval.dtype).kind
        shape = tuple(aval.shape)
    except Exception:
        return False
    if kind in ("i", "u"):
        return True
    # floating covers bf16 payloads too: ml_dtypes' bfloat16 reports
    # numpy kind "V" (void), so a kind == "f" check alone would silently
    # drop the value-only stacked payload from the weight tally
    is_float = kind == "f" or jax.numpy.issubdtype(aval.dtype,
                                                   jax.numpy.floating)
    return is_float and (len(shape) != 2 or shape[0] == 1)


def _walk(jaxpr, mult: int, acc: Dict[str, float],
          convert_src: Dict[Any, Any] = None):
    # convert_src: var -> pre-convert var, so a dot whose operand is a
    # freshly dequantized int8 weight charges int8 bytes (the dequant
    # fuses into the matmul on TPU; HBM sees the int8 tensor).
    convert_src = {} if convert_src is None else convert_src
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "convert_element_type" and len(eqn.invars) == 1:
            convert_src[eqn.outvars[0]] = eqn.invars[0]
            continue          # dtype converts fuse; no HBM traffic charged
        if prim == "dot_general":
            f = _dot_flops(eqn) * mult
            acc["dot_flops"] += f
            acc["flops"] += f
            op_bytes = 0
            for v in eqn.invars:
                src = convert_src.get(v, v)
                op_bytes += _bytes(src.aval)
            acc["bytes"] += (op_bytes
                             + _bytes(eqn.outvars[0].aval)) * mult
            # projection weight traffic: rank-2 rhs with no batch dims
            # (x @ W); attention/SSM einsum dots have batch dims or rank>2
            _, (_, rb) = eqn.params["dimension_numbers"]
            rhs = convert_src.get(eqn.invars[1], eqn.invars[1])
            if len(getattr(rhs.aval, "shape", ())) == 2 and not rb:
                acc["weight_bytes"] += _bytes(rhs.aval) * mult
            continue
        if prim == "pallas_call":
            # Custom kernel (e.g. joint_sparse_matmul): its inner jaxpr
            # sees per-BLOCK avals, so plain recursion would undercount
            # by the grid size. Prefer the kernel's static CostEstimate
            # for FLOPs; without one, recurse into the kernel body with
            # the grid trip count as the multiplier (each grid step runs
            # the body once on one block). HBM charges operands + result:
            # packed INT8 payloads charge 1 B/weight and compacted tables
            # only their stored bytes — exactly the joint-sparsity
            # traffic saving the roofline should see.
            ce = eqn.params.get("cost_estimate")
            f = float(getattr(ce, "flops", 0) or 0)
            if f:
                acc["dot_flops"] += f * mult
                acc["flops"] += f * mult
                acc["pallas_flops"] += f * mult
            else:
                grid = getattr(eqn.params.get("grid_mapping"), "grid", ())
                steps = 1
                for g in grid:
                    steps *= int(g)
                inner = eqn.params["jaxpr"]
                sub = {k: 0.0 for k in acc}
                _walk(getattr(inner, "jaxpr", inner), mult * steps, sub)
                acc["dot_flops"] += sub["dot_flops"]
                acc["flops"] += sub["flops"]
                acc["pallas_flops"] += sub["dot_flops"]
            b = (sum(_bytes(v.aval) for v in eqn.invars)
                 + sum(_bytes(v.aval) for v in eqn.outvars)) * mult
            acc["bytes"] += b
            acc["pallas_bytes"] += b
            acc["weight_bytes"] += sum(
                _bytes(v.aval) for v in eqn.invars
                if _is_pallas_weight(v.aval)) * mult
            continue
        if prim == "scan":
            length = int(eqn.params.get("length", 1))
            inner = eqn.params["jaxpr"]
            _walk(inner.jaxpr, mult * length, acc)
            continue
        if prim == "while":
            # unbounded a priori; models don't use raw while. Count once.
            _walk(eqn.params["body_jaxpr"].jaxpr, mult, acc)
            continue
        if prim == "cond":
            branches = eqn.params.get("branches", ())
            sub = [dict(acc) for _ in branches]
            best = None
            for br in branches:
                a = {k: 0.0 for k in acc}
                _walk(br.jaxpr, mult, a)
                if best is None or a["flops"] > best["flops"]:
                    best = a
            if best:
                for k in acc:
                    acc[k] += best[k]
            continue
        handled = False
        for pname in _SUBJAXPR_PARAMS:
            if pname in eqn.params:
                sub = eqn.params[pname]
                _walk(getattr(sub, "jaxpr", sub), mult, acc)
                handled = True
                break
        if handled:
            continue
        # leaf op: elementwise/reduce/gather/etc. FLOPs counted; bytes only
        # for data-movement primitives — elementwise/reduce chains between
        # matmuls fuse on TPU (their operands are freshly produced dot
        # results already charged at the dot).
        out_b = sum(_bytes(v.aval) for v in eqn.outvars)
        out_n = sum(_nelems(v.aval) for v in eqn.outvars)
        acc["flops"] += out_n * mult
        if prim in ("gather", "scatter", "scatter-add", "scatter_add",
                    "dynamic_slice", "dynamic_update_slice", "sort",
                    "cumsum", "cumlogsumexp"):
            acc["bytes"] += (out_b + sum(_bytes(v.aval)
                                         for v in eqn.invars)) * mult


def analyze(fn, *args) -> Dict[str, float]:
    """Trip-aware cost of `fn(*args)` (args may be ShapeDtypeStructs)."""
    closed = jax.make_jaxpr(fn)(*args)
    acc = {"flops": 0.0, "dot_flops": 0.0, "bytes": 0.0,
           "pallas_flops": 0.0, "pallas_bytes": 0.0, "weight_bytes": 0.0}
    _walk(closed.jaxpr, 1, acc)
    # argument + result residency: params/opt-state are read and written
    # once per step regardless of op-level traffic.
    arg_bytes = sum(_bytes(v.aval) for v in closed.jaxpr.invars)
    acc["arg_bytes"] = float(arg_bytes)
    return acc


def analyze_call_kinds(calls: Dict[str, tuple]) -> Dict[str, Dict[str, float]]:
    """Per-engine-call-kind cost attribution.

    `calls` maps a call kind — the serving engine's executables, e.g.
    "decode" / "prefill_chunk_exact" / "prefill_parallel" (the builders in
    launch.steps annotate their step fns with a matching ``call_kind``) —
    to an ``(fn, args)`` tuple. Each kind is traced and walked separately,
    so weight_bytes (and every other tally) stays attributable to the
    call that pays it instead of collapsing into one blended number: the
    chunked-prefill traffic savings the benchmarks guard are per-KIND
    contracts (a parallel SSM chunk reads its projections once, an exact
    chunk C times, a decode step once per token)."""
    return {kind: analyze(fn, *args) for kind, (fn, args) in calls.items()}
