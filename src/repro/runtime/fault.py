"""Fault tolerance & elasticity harness.

On a real 1000+-node fleet, failures surface as (a) raised exceptions from
collectives / host runtime, (b) missing heartbeats, (c) stragglers. The
framework's contract:

  * every state mutation flows through the checkpoint manager (atomic,
    async) — the blast radius of any failure is <= `every` steps;
  * `run_resilient` wraps the step loop: on failure it restores the last
    checkpoint, optionally REBUILDS the mesh from the surviving device set
    (elastic re-mesh: drop a data-parallel slice, keep model-parallel
    groups intact), re-lowers the step, and continues;
  * `StragglerMonitor` tracks per-step wall time and flags outliers
    (slow hosts) for the scheduler to evict — mitigation on TPU pods is
    eviction + re-mesh, not work stealing, because lockstep collectives
    make one slow chip everyone's problem.

The container is single-process, so failures are injected in tests via
the `failure_hook`; the control flow is identical on real fleets.

The SERVING engine has its own request-granular fault layer
(serving.faults + serving.engine: per-slot quarantine and
recovery-by-replay instead of checkpoint restore) but reuses
`StragglerMonitor` verbatim for per-tick wall timing — outlier ticks
surface as `straggler_ticks` in serving.metrics.summary().
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import numpy as np


@dataclass
class StragglerMonitor:
    window: int = 50
    threshold: float = 2.0          # x median => straggler
    warmup: int = 10                # samples before flagging starts
    times: List[float] = field(default_factory=list)
    flagged: int = 0                # total stragglers seen (monotonic)

    def record(self, dt: float) -> bool:
        """Returns True if this step is a straggler outlier."""
        self.times.append(dt)
        if len(self.times) > self.window:
            self.times.pop(0)
        if len(self.times) < self.warmup:
            return False
        med = float(np.median(self.times))
        if dt > self.threshold * med:
            self.flagged += 1
            return True
        return False

    @property
    def p50(self) -> float:
        return float(np.median(self.times)) if self.times else 0.0


@dataclass
class ElasticMeshPlan:
    """How to shrink the mesh when a slice dies: drop along the data axis
    (model-parallel groups must stay complete — a lost TP peer loses the
    weights' shards; a lost DP slice only loses throughput)."""
    data_parallel: int
    model_parallel: int

    def degrade(self) -> "ElasticMeshPlan":
        if self.data_parallel <= 1:
            raise RuntimeError("cannot degrade below 1 data-parallel slice")
        return ElasticMeshPlan(self.data_parallel // 2, self.model_parallel)


def run_resilient(train_loop: Callable[[int, Optional[ElasticMeshPlan]], int],
                  *, total_steps: int, restore_step: Callable[[], int],
                  max_failures: int = 5,
                  plan: Optional[ElasticMeshPlan] = None,
                  on_failure: Optional[Callable[[BaseException], None]] = None
                  ) -> int:
    """Drive `train_loop(start_step, plan)` to completion with restarts.

    train_loop runs until done or raises; restore_step() returns the step
    to resume from (last durable checkpoint). Each failure optionally
    degrades the mesh plan (elastic downscale).
    """
    failures = 0
    step = restore_step()
    while step < total_steps:
        try:
            step = train_loop(step, plan)
        except Exception as e:   # noqa: BLE001 — any step failure
            failures += 1
            if on_failure:
                on_failure(e)
            if failures > max_failures:
                raise RuntimeError(
                    f"exceeded {max_failures} failures; last: {e}") from e
            step = restore_step()
            if plan is not None and failures >= 2:
                plan = plan.degrade()   # repeated failures: shed capacity
    return step
