"""While-trip-aware collective-byte accounting from optimized HLO text.

The dry-run's collective term needs bytes moved per step, but collectives
inside `while` (scan) bodies execute trip-count times. This parser:

  1. splits the HLO module into computations,
  2. records each computation's local collective result-bytes by kind,
  3. builds the call graph (while/call/fusion/conditional edges) with
     while-trip counts recovered from the loop condition's comparison
     constant,
  4. propagates multipliers from ENTRY.

Result bytes are per-DEVICE (the HLO is the partitioned SPMD program).
"""

from __future__ import annotations

import re
from typing import Dict

DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
               "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
               "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8}

_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_COLLECTIVE = re.compile(
    r"=\s*(?:\([^)]*\)\s*)?(\w+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_WHILE = re.compile(r"while\(.*?\),\s*condition=%?([\w\.\-]+),\s*"
                    r"body=%?([\w\.\-]+)")
_TRIP = re.compile(r'known_trip_count[^}]*"n":"(\d+)"')
_CALLS_FUSION = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_CONST_INT = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo: str) -> Dict[str, str]:
    """Computations start at column 0 with `%name (`/`ENTRY %name (` and
    contain indented op lines. (A regex over the whole module text breaks
    on tuple-typed while params — nested parens.)"""
    comps: Dict[str, str] = {}
    name = None
    buf: list = []
    for line in hlo.splitlines():
        if line and not line[0].isspace():
            m = _COMP_HEADER.match(line)
            if m:
                if name is not None:
                    comps[name] = "\n".join(buf)
                name = "__entry__" if m.group(1) else m.group(2)
                buf = [line]
                continue
        if name is not None:
            buf.append(line)
    if name is not None:
        comps[name] = "\n".join(buf)
    return comps


def _local_bytes(body: str) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for m in _COLLECTIVE.finditer(body):
        dt, dims, kind = m.group(1), m.group(2), m.group(3)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out[kind] = out.get(kind, 0) + n * DTYPE_BYTES[dt]
    return out


def _trip_count(cond_body: str) -> int:
    consts = [int(c) for c in _CONST_INT.findall(cond_body)]
    consts = [c for c in consts if 1 < c <= 1_000_000]
    return max(consts) if consts else 1


def collective_bytes(hlo: str) -> Dict[str, float]:
    comps = _split_computations(hlo)
    local = {name: _local_bytes(body) for name, body in comps.items()}

    # edges: (callee, multiplier)
    edges: Dict[str, list] = {name: [] for name in comps}
    for name, body in comps.items():
        for line in body.splitlines():
            m = _WHILE.search(line)
            if m:
                cond, wbody = m.group(1), m.group(2)
                tm = _TRIP.search(line)
                trips = int(tm.group(1)) if tm else \
                    _trip_count(comps.get(cond, ""))
                edges[name].append((wbody, trips))
                edges[name].append((cond, trips))
                continue
            for cm in _CALLS_FUSION.finditer(line):
                callee = cm.group(1)
                if callee in comps:
                    edges[name].append((callee, 1))

    total: Dict[str, float] = {}
    seen_stack = set()

    def visit(name: str, mult: float):
        if name in seen_stack or mult <= 0 or name not in comps:
            return
        seen_stack.add(name)
        for kind, b in local.get(name, {}).items():
            total[kind] = total.get(kind, 0.0) + b * mult
        for callee, trips in edges.get(name, []):
            visit(callee, mult * trips)
        seen_stack.discard(name)

    visit("__entry__", 1.0)
    total["total"] = sum(v for k, v in total.items() if k != "total")
    return total
