"""Gradient compression: block-wise INT8 quantization with error feedback.

Distributed-optimization trick for the DP all-reduce: gradients are
quantized to INT8 (4x less all-reduce traffic than f32) with per-256-block
scales; the quantization residual is carried in an error-feedback buffer
so the compression bias vanishes over steps (Seide et al. / EF-SGD line).

`compress_tree` (stateless, used in the dry-run train step) quantizes and
immediately dequantizes — the all-reduce then operates on values that are
exactly representable in INT8 blocks, modeling the traffic reduction while
keeping the pjit program simple. `EFCompressor` is the stateful
error-feedback variant for the real training loop.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _quant_dequant(g: jnp.ndarray) -> jnp.ndarray:
    if g.size < BLOCK:
        return g
    flat = g.reshape(-1)
    pad = (-flat.size) % BLOCK
    fp = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(fp), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(fp / scale), -127, 127)
    out = (q * scale).reshape(-1)[:flat.size]
    return out.reshape(g.shape).astype(g.dtype)


def compress_tree(grads):
    return jax.tree_util.tree_map(_quant_dequant, grads)


class EFCompressor(NamedTuple):
    """Error-feedback state: one residual buffer per gradient leaf."""
    residual: dict

    @staticmethod
    def init(grads) -> "EFCompressor":
        return EFCompressor(jax.tree_util.tree_map(
            lambda g: jnp.zeros_like(g, jnp.float32), grads))

    def compress(self, grads):
        def one(g, r):
            target = g.astype(jnp.float32) + r
            q = _quant_dequant(target)
            return q.astype(g.dtype), target - q
        pairs = jax.tree_util.tree_map(one, grads, self.residual)
        comp = jax.tree_util.tree_map(lambda p: p[0], pairs,
                                      is_leaf=lambda x: isinstance(x, tuple))
        res = jax.tree_util.tree_map(lambda p: p[1], pairs,
                                     is_leaf=lambda x: isinstance(x, tuple))
        return comp, EFCompressor(res)
