"""Activation sharding constraints with logical axis names.

Model code calls `constrain(x, "dp", None, "tp", None)`; the logical axes
resolve against the mesh active at trace time ("dp" -> (pod, data),
"tp" -> model) with divisibility checks, and become
with_sharding_constraint calls. Outside a mesh context this is a no-op, so
single-device smoke tests are unaffected.

Pinning activations matters: GSPMD propagates shardings from weights, but
mixed-divisibility cases (e.g. 8 KV heads on a 16-way model axis) let
replicated operands "win" and silently blow up per-device activation
memory. These constraints are load-bearing for the dry-run memory budget.
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


def _current_mesh():
    try:
        from jax._src.mesh import thread_resources
        m = thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and m.axis_names:
            return m
    except Exception:
        pass
    return None


def _resolve(mesh, logical, dim):
    if logical is None:
        return None
    if logical == "dp":
        axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
        axes = axes if len(axes) > 1 else (axes[0] if axes else None)
    elif logical == "tp":
        axes = "model" if "model" in mesh.axis_names else None
    else:
        axes = logical if logical in mesh.axis_names else None
    if axes is None:
        return None
    size = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        size *= dict(mesh.shape)[a]
    return axes if dim % size == 0 else None


def constrain(x, *logical_axes):
    mesh = _current_mesh()
    if mesh is None:
        return x
    if len(logical_axes) != x.ndim:
        return x
    spec = P(*[_resolve(mesh, ax, d)
               for ax, d in zip(logical_axes, x.shape)])
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


def constrain_any(x, *candidate_specs):
    """First candidate spec (tuple of logical axes) whose every named axis
    divides the corresponding dim is applied; otherwise no-op."""
    mesh = _current_mesh()
    if mesh is None:
        return x
    for spec in candidate_specs:
        if len(spec) != x.ndim:
            continue
        ok = True
        for ax, d in zip(spec, x.shape):
            if ax is not None and _resolve(mesh, ax, d) is None:
                ok = False
                break
        if ok:
            return constrain(x, *spec)
    return x
