"""Sharding rules: Megatron-style TP over the `model` axis, DP over
(`pod`, `data`), ZeRO-1 optimizer-state sharding, sequence-parallel KV
caches for batch-1 long-context decode.

Every rule is divisibility-checked: if a dim does not divide by the mesh
axis size the rule falls back to the next candidate, ending at replication.
This is what lets one rule set serve all 10 architectures (e.g. arctic's 56
heads are not 16-divisible -> its attention activations replicate over
`model` while its 128 experts and d_ff shard cleanly).
"""

from __future__ import annotations

import re
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fits(shape, spec, mesh: Mesh) -> bool:
    for dim, axes in zip(shape, spec):
        if axes is None:
            continue
        if dim % axis_size(mesh, axes) != 0:
            return False
    return len(spec) <= len(shape)


def first_fit(shape, candidates, mesh: Mesh) -> P:
    """First candidate PartitionSpec whose named axes divide the shape."""
    for spec in candidates:
        if _fits(shape, spec, mesh):
            return P(*spec)
    return P()


# ---------------------------------------------------------------------------
# Parameter rules. Paths are '/'-joined key paths into the param pytree;
# stacked layer params carry a leading layer axis which is never sharded.
# ---------------------------------------------------------------------------

_COL = "col"      # shard output features (column-parallel)
_ROW = "row"      # shard input features (row-parallel)

_PARAM_RULES = [
    # (path regex, kind) — kind decides which dim gets the model axis.
    (r"embed/tok$", "vocab"),
    (r"embed/out$", _COL),
    (r"(attn|xattn)/wq$", _COL),
    (r"(attn|xattn)/wk$", _COL),
    (r"(attn|xattn)/wv$", _COL),
    (r"(attn|xattn)/wo$", _ROW),
    (r"mlp/w_gate$", _COL),
    (r"mlp/w_up$", _COL),
    (r"mlp/w_down$", _ROW),
    (r"moe/router$", "replicate"),
    (r"moe/w_gate$", "expert_col"),
    (r"moe/w_up$", "expert_col"),
    (r"moe/w_down$", "expert_row"),
    (r"moe/dense_mlp/w_gate$", _COL),
    (r"moe/dense_mlp/w_up$", _COL),
    (r"moe/dense_mlp/w_down$", _ROW),
    (r"ssm/in_proj$", _COL),
    (r"ssm/out_proj$", _ROW),
    (r"ssm/conv_w$", "conv"),
    (r"ssm/conv_b$", "vector_model"),
    (r"patch_proj$", _COL),
]


def _spec_for(kind: str, shape, mesh: Mesh, offset: int) -> P:
    """offset = number of leading stacked-layer dims (never sharded)."""
    pad = (None,) * offset
    nd = len(shape) - offset

    def c(*tail):
        return pad + tail

    if kind == "vocab":
        cands = [c("model", None), c(None, "model"), c(None, None)]
    elif kind == _COL:
        cands = [c(None, "model"), c(None, None)]
    elif kind == _ROW:
        cands = [c("model", None), c(None, None)]
    elif kind == "expert_col":      # (E, D, F)
        cands = [c("model", None, None), c(None, None, "model"),
                 c(None, None, None)]
    elif kind == "expert_row":      # (E, F, D)
        cands = [c("model", None, None), c(None, "model", None),
                 c(None, None, None)]
    elif kind == "conv":            # (W, C)
        cands = [c(None, "model"), c(None, None)]
    elif kind == "vector_model":    # (C,)
        cands = [c("model",), c(None,)]
    else:
        cands = [c(*([None] * nd))]
    return first_fit(shape, cands, mesh)


def param_specs(params, mesh: Mesh, fsdp: bool = True,
                fsdp_min_elems: int = 1 << 20):
    """PartitionSpec pytree for a param tree (stacked layer dims detected
    from tree position: blocks/enc_blocks/segNN segment stacks carry a
    leading layer axis).

    fsdp=True additionally shards each large tensor's biggest unsharded
    dim over the DP axes (ZeRO-3 / FSDP): XLA all-gathers weights at use.
    Without it, replicated copies of 480B-class params cannot fit a chip.
    """

    def visit(path, leaf):
        pathstr = "/".join(str(getattr(k, "key", k)) for k in path)
        if pathstr.endswith("/scale"):
            return P()                     # int8 per-filter scales: tiny
        if pathstr.endswith("/q"):
            pathstr = pathstr[:-2]         # int8 payload: weight rules
        # stacked containers contribute leading layer axes
        offset = 0
        if re.search(r"^(blocks|enc_blocks|seg\d+)/", pathstr):
            offset = 1
        spec = P()
        for pat, kind in _PARAM_RULES:
            if re.search(pat, pathstr):
                spec = _spec_for(kind, leaf.shape, mesh, offset)
                break
        if fsdp and leaf.ndim >= 2 and leaf.size >= fsdp_min_elems:
            spec = zero1_spec(spec, leaf.shape, mesh,
                              skip_dims=tuple(range(offset)))
        return spec

    return jax.tree_util.tree_map_with_path(visit, params)


def named(specs, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        specs, is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# ZeRO-1: optimizer state additionally sharded over the data axes.
# ---------------------------------------------------------------------------

def zero1_spec(pspec: P, shape, mesh: Mesh, skip_dims=()) -> P:
    """Extend a param spec by sharding the largest unsharded dim over the
    DP axes (classic ZeRO partitioning expressed as a sharding).
    skip_dims: dims never sharded (e.g. the stacked layer axis that scan
    slices every iteration)."""
    dp = dp_axes(mesh)
    if not dp or not shape:
        return pspec
    spec = list(pspec) + [None] * (len(shape) - len(pspec))
    used = set()
    for axes in spec:
        for a in (axes if isinstance(axes, tuple) else (axes,)):
            used.add(a)
    if used & set(dp):          # already DP-sharded (e.g. FSDP param spec)
        return P(*spec)
    dpn = axis_size(mesh, dp)
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in order:
        if i in skip_dims:
            continue
        if spec[i] is None and shape[i] % dpn == 0:
            spec[i] = dp if len(dp) > 1 else dp[0]
            return P(*spec)
    return pspec


def opt_state_specs(params, pspecs, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda p, s: zero1_spec(s, p.shape, mesh), params, pspecs,
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Batch / activation / cache rules
# ---------------------------------------------------------------------------

def batch_specs(batch_tree, mesh: Mesh):
    """Shard the leading batch dim over (pod, data); fall back seq-dim
    sharding over `data` for batch-1 long-context inputs."""
    dp = dp_axes(mesh)
    dpa = dp if len(dp) > 1 else (dp[0] if dp else None)

    def visit(leaf):
        shape = leaf.shape
        if not shape:
            return P()
        if shape[0] % axis_size(mesh, dpa) == 0:
            return P(dpa)
        if len(shape) >= 2 and shape[1] % mesh.shape.get("data", 1) == 0:
            return P(None, "data")
        return P()

    return jax.tree_util.tree_map(visit, batch_tree)


def cache_specs(cache_tree, cfg, mesh: Mesh):
    """KV/SSM cache sharding for decode.

    Layout reminders: attn k/v (L, B, A, Hkv, hd); ssm conv
    (L, B, W-1, C), ssm state (L, B, H, Pd, N) — uniform across segments
    (hybrid segments use the same per-segment layouts).
    Batch shards over DP when divisible; otherwise (long_500k, B=1) the
    cache SEQUENCE dim shards over `data` (sequence-parallel decode) and
    SSM state heads shard over `data`. KV heads shard over `model` when
    divisible.
    """
    dp = dp_axes(mesh)
    dpa = dp if len(dp) > 1 else (dp[0] if dp else None)
    dpn = axis_size(mesh, dpa)

    def visit(path, leaf):
        pathstr = "/".join(str(getattr(k, "key", k)) for k in path)
        shape = leaf.shape
        if not shape or leaf.ndim <= 1:
            return P()
        if pathstr.endswith("/k") or pathstr.endswith("/v"):
            L, B, A, H, hd = shape
            spec = [None, None, None, None, None]
            if B % dpn == 0:
                spec[1] = dpa
            elif A % mesh.shape.get("data", 1) == 0:
                spec[2] = "data"
            if H % mesh.shape.get("model", 1) == 0:
                spec[3] = "model"
            # NOTE: when kv-heads < model axis the cache REPLICATES over
            # `model`. Sharding the seq dim instead was tried and REFUTED:
            # the dynamic-index cache update scatter cannot be partitioned
            # along the sharded dim, so GSPMD all-gathers the whole cache
            # every token (qwen decode collective 0.19s -> 1.55s). The
            # production fix is KV replication to the TP degree or a
            # shard_map decode kernel (EXPERIMENTS.md §Perf iter 4).
            return P(*spec)
        if pathstr.endswith("/pk") or pathstr.endswith("/pv"):
            # paged KV pool (L, n_pages, page_size, Hkv, hd): no batch
            # dim to DP-shard (pages are the unit of occupancy, owned by
            # whichever slot the host table says); kv-heads shard over
            # `model` exactly like the contiguous cache, everything else
            # replicates — the page-id gather must stay local
            L, NP_, PS_, H, hd = shape
            spec = [None, None, None, None, None]
            if H % mesh.shape.get("model", 1) == 0:
                spec[3] = "model"
            return P(*spec)
        if "ssm/state" in pathstr or pathstr.endswith("state"):
            B_idx = leaf.ndim - 4
            spec = [None] * leaf.ndim
            if shape[B_idx] % dpn == 0:
                spec[B_idx] = dpa
            if shape[B_idx + 1] % mesh.shape.get("model", 1) == 0:
                spec[B_idx + 1] = "model"
            return P(*spec)
        if "conv" in pathstr:
            B_idx = leaf.ndim - 3
            spec = [None] * leaf.ndim
            if shape[B_idx] % dpn == 0:
                spec[B_idx] = dpa
            if shape[-1] % mesh.shape.get("model", 1) == 0:
                spec[-1] = "model"
            return P(*spec)
        if "enc_out" in pathstr:
            spec = [None] * leaf.ndim
            if shape[0] % dpn == 0:
                spec[0] = dpa
            return P(*spec)
        return P()

    return jax.tree_util.tree_map_with_path(visit, cache_tree)
