from .pipeline import SyntheticLMDataset, make_pipeline  # noqa: F401
