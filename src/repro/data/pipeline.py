"""Deterministic synthetic token pipeline.

Production-shaped: per-host sharding (each host materializes only its
slice of the global batch), double-buffered prefetch on a background
thread, deterministic stateless sampling keyed by (seed, step) — so a
restart from checkpoint step N reproduces the exact same batch stream
(fault-tolerance requirement), and straggler-friendly (no cross-host
coordination in the data path).

The generator is a mixture of Zipf-distributed unigrams and repeated
n-gram motifs, giving a learnable (compressible) stream so loss curves
actually move — pure uniform tokens would be incompressible noise.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.models.config import ModelConfig


@dataclass
class SyntheticLMDataset:
    cfg: ModelConfig
    global_batch: int
    seq_len: int
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1
    motif_len: int = 16
    n_motifs: int = 512

    def __post_init__(self):
        assert self.global_batch % self.n_hosts == 0
        rng = np.random.default_rng(self.seed)
        v = self.cfg.vocab_size
        # Zipf unigram table + fixed motif bank (shared across hosts)
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self._probs = (1.0 / ranks ** 1.1)
        self._probs /= self._probs.sum()
        self._motifs = rng.integers(0, v, (self.n_motifs, self.motif_len))

    @property
    def host_batch(self) -> int:
        return self.global_batch // self.n_hosts

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Stateless: batch for a given global step, this host's slice."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 4097 + self.host_id)
        B, S, v = self.host_batch, self.seq_len, self.cfg.vocab_size
        toks = rng.choice(v, size=(B, S + 1), p=self._probs)
        # stitch in motifs (learnable structure)
        n_insert = (S // self.motif_len) // 2
        for b in range(B):
            ids = rng.integers(0, self.n_motifs, n_insert)
            offs = rng.integers(0, S + 1 - self.motif_len, n_insert)
            for m, o in zip(ids, offs):
                toks[b, o:o + self.motif_len] = self._motifs[m]
        batch = {"tokens": toks[:, :-1].astype(np.int32),
                 "labels": toks[:, 1:].astype(np.int32)}
        if self.cfg.is_encdec:
            batch["frames"] = rng.normal(
                0, 1, (B, self.cfg.encoder_seq, self.cfg.d_model)
            ).astype(np.float32)
        if self.cfg.frontend == "vision_stub":
            batch["frontend"] = rng.normal(
                0, 1, (B, self.cfg.n_patches, self.cfg.d_model)
            ).astype(np.float32)
        return batch


def make_pipeline(ds: SyntheticLMDataset, start_step: int = 0,
                  prefetch: int = 2) -> Iterator[Dict[str, np.ndarray]]:
    """Background-thread prefetching iterator starting at `start_step`."""
    q: "queue.Queue" = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def worker():
        step = start_step
        while not stop.is_set():
            try:
                q.put(ds.batch_at(step), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    try:
        while True:
            yield q.get()
    finally:
        stop.set()
