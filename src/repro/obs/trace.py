"""Two-clock structured tracing for the serving engine.

Every record carries BOTH clocks the serving stack reasons in:

  * ENGINE TICKS — the deterministic scheduler clock. Tick numbers are
    trace-reproducible (same workload seed -> same tick schedule), so
    regressions expressed in ticks ("replay prefills doubled TTFT") are
    guardable in CI.
  * WALL TIME — microseconds since the tracer was created
    (``ts_us``/``dur_us``), for latency attribution and the Chrome-trace
    timeline. Wall times are reporting-only; no guard compares them.

Record taxonomy (one JSON object per line in the JSONL dump):

  ==========  =========================================================
  type        fields
  ==========  =========================================================
  meta        version, arch, plus engine config (first record)
  span        name ("tick" | "call"), tick, ts_us, dur_us, attrs
  event       name (admit | prefill | first_token | quarantine |
              replay | shed | reject | release | fault | retry |
              crash | snapshot | restore), tick, ts_us, attrs
  interval    slot, rid, admit_tick, release_tick — one closed
              SlotInterval from the engine's slot audit log
  waterfall   kind, total, rows {param path -> weight bytes} — the
              per-call-kind traffic attribution (obs.waterfall)
  ==========  =========================================================

Span records are appended at BEGIN time (their ``dur_us`` is filled in
at end), so the record list is start-ordered and ``validate`` can check
wall-clock monotonicity by simple iteration. ``begin``/``end`` enforce
LIFO nesting: a "call" span always closes before its enclosing "tick"
span, which is what makes the Chrome conversion a pure reformat.

The tracer is PASSIVE: it never issues device calls and never touches
engine decisions, so tracing on vs off is bitwise-output- and
device-call-count-identical (the zero-overhead contract the chaos bench
and tests/test_obs.py guard).
"""

from __future__ import annotations

import json
import time
from typing import Dict, List, Optional

TRACE_VERSION = 1

#: span names the engine emits; anything else fails validation
SPAN_NAMES = ("tick", "call")
#: instant-event names the engine emits; crash/snapshot/restore are the
#: durability lifecycle (serving.journal / serving.snapshot) — one
#: tracer may span a kill + warm restart, and stays valid because the
#: restored engine resumes at a strictly later tick
EVENT_NAMES = ("admit", "prefill", "first_token", "quarantine", "replay",
               "shed", "reject", "release", "fault", "retry",
               "crash", "snapshot", "restore")


class TraceError(RuntimeError):
    """A structural invariant of the trace was violated (bad nesting,
    non-monotone clocks, an unclosed span, overlapping slot intervals)."""


class Tracer:
    """Collects span/event/interval records; ``dump`` writes JSONL."""

    def __init__(self, arch: Optional[str] = None, meta: Optional[dict] = None,
                 path: Optional[str] = None):
        self._wall0 = time.perf_counter()
        #: where this trace is meant to be dumped (advisory). The engine
        #: uses it for post-mortems: EngineStuckError dumps here and
        #: attaches the path, so a hung run is diagnosable offline.
        self.path = path
        self.records: List[dict] = [{
            "type": "meta", "version": TRACE_VERSION, "arch": arch,
            **(meta or {})}]
        self._open: List[dict] = []

    # -- clocks ------------------------------------------------------------
    def _now_us(self) -> float:
        return (time.perf_counter() - self._wall0) * 1e6

    # -- spans -------------------------------------------------------------
    def begin(self, name: str, tick: int, **attrs) -> dict:
        """Open a span; returns the handle ``end`` takes. The record is
        appended NOW (start-ordered stream); dur_us lands at ``end``."""
        span = {"type": "span", "name": name, "tick": int(tick),
                "ts_us": self._now_us(), "dur_us": None, "attrs": attrs}
        self.records.append(span)
        self._open.append(span)
        return span

    def end(self, span: dict, **attrs):
        """Close the MOST RECENTLY opened span (LIFO — crossing spans are
        a bug in the instrumentation, not a recordable state)."""
        if not self._open or self._open[-1] is not span:
            raise TraceError(
                f"span {span.get('name')!r} closed out of order — spans "
                f"must nest LIFO (open: "
                f"{[s['name'] for s in self._open]})")
        self._open.pop()
        span["dur_us"] = self._now_us() - span["ts_us"]
        if attrs:
            span["attrs"].update(attrs)

    # -- instants / intervals ---------------------------------------------
    def event(self, name: str, tick: int, **attrs):
        self.records.append({"type": "event", "name": name,
                             "tick": int(tick), "ts_us": self._now_us(),
                             "attrs": attrs})

    def interval(self, slot: int, rid: int, admit_tick: int,
                 release_tick: Optional[int]):
        """One closed slot-occupancy interval [admit_tick, release_tick)
        from the engine's audit log."""
        self.records.append({"type": "interval", "slot": int(slot),
                             "rid": int(rid),
                             "admit_tick": int(admit_tick),
                             "release_tick": (None if release_tick is None
                                              else int(release_tick))})

    def waterfall(self, kind: str, rows: Dict[str, float], total: float):
        """Per-call-kind weight-traffic attribution (obs.waterfall):
        rows map parameter paths to modeled weight bytes per call."""
        self.records.append({"type": "waterfall", "kind": kind,
                             "total": float(total),
                             "rows": {k: float(v)
                                      for k, v in rows.items()}})

    # -- export ------------------------------------------------------------
    def dump(self, path: str):
        if self._open:
            raise TraceError(f"dump with open spans: "
                             f"{[s['name'] for s in self._open]}")
        with open(path, "w") as f:
            for r in self.records:
                f.write(json.dumps(r) + "\n")


def load(path: str) -> List[dict]:
    """Read a JSONL trace back into the record list ``dump`` wrote."""
    records = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                records.append(json.loads(line))
    return records


def validate(records: List[dict]) -> Dict[str, int]:
    """Structural invariants every engine trace must satisfy:

      * first record is a meta record with a known version;
      * span/event wall clocks are monotone non-decreasing in record
        order (spans are start-ordered by construction);
      * tick numbers are monotone non-decreasing;
      * every span was closed (dur_us set, >= 0) and has a known name;
      * every "call" span lies WITHIN its tick's "tick" span on the wall
        clock, and "tick" spans never overlap each other;
      * slot intervals on one slot never overlap, release > admit.

    Returns counting stats ({"spans": n, "events": n, "intervals": n,
    "waterfalls": n}); raises TraceError on any violation.
    """
    if not records or records[0].get("type") != "meta":
        raise TraceError("trace must start with a meta record")
    if records[0].get("version") != TRACE_VERSION:
        raise TraceError(f"unknown trace version "
                         f"{records[0].get('version')!r}")
    stats = {"spans": 0, "events": 0, "intervals": 0, "waterfalls": 0}
    last_ts = -1.0
    last_tick = -1
    tick_spans: Dict[int, dict] = {}
    for i, r in enumerate(records[1:], start=1):
        t = r.get("type")
        if t == "span":
            stats["spans"] += 1
            if r.get("name") not in SPAN_NAMES:
                raise TraceError(f"record {i}: unknown span name "
                                 f"{r.get('name')!r}")
            if r.get("dur_us") is None or r["dur_us"] < 0:
                raise TraceError(f"record {i}: span {r['name']!r} "
                                 f"never closed (dur_us={r.get('dur_us')})")
            if r["name"] == "tick":
                if r["tick"] in tick_spans:
                    raise TraceError(f"record {i}: duplicate tick span "
                                     f"for tick {r['tick']}")
                prev = tick_spans.get(r["tick"] - 1)
                if prev is not None and \
                        r["ts_us"] < prev["ts_us"] + prev["dur_us"] - 1e-6:
                    raise TraceError(
                        f"record {i}: tick {r['tick']} span starts inside "
                        f"tick {r['tick'] - 1}")
                tick_spans[r["tick"]] = r
        elif t == "event":
            stats["events"] += 1
            if r.get("name") not in EVENT_NAMES:
                raise TraceError(f"record {i}: unknown event name "
                                 f"{r.get('name')!r}")
        elif t == "interval":
            stats["intervals"] += 1
            continue                      # no wall clock on intervals
        elif t == "waterfall":
            stats["waterfalls"] += 1
            continue
        elif t == "meta":
            raise TraceError(f"record {i}: meta record not first")
        else:
            raise TraceError(f"record {i}: unknown record type {t!r}")
        if r["ts_us"] < last_ts - 1e-6:
            raise TraceError(f"record {i}: wall clock went backwards "
                             f"({r['ts_us']:.1f} < {last_ts:.1f} us)")
        last_ts = max(last_ts, r["ts_us"])
        if r["tick"] < last_tick:
            raise TraceError(f"record {i}: tick went backwards "
                             f"({r['tick']} < {last_tick})")
        last_tick = r["tick"]
    # call-in-tick containment (wall clock)
    for r in records[1:]:
        if r.get("type") == "span" and r["name"] == "call":
            parent = tick_spans.get(r["tick"])
            if parent is None:
                raise TraceError(f"call span at tick {r['tick']} has no "
                                 f"tick span")
            if r["ts_us"] < parent["ts_us"] - 1e-6 or \
                    r["ts_us"] + r["dur_us"] > \
                    parent["ts_us"] + parent["dur_us"] + 1e-6:
                raise TraceError(
                    f"call span at tick {r['tick']} escapes its tick span "
                    f"on the wall clock")
    # per-slot interval exclusivity
    by_slot: Dict[int, List[dict]] = {}
    for r in records[1:]:
        if r.get("type") == "interval":
            by_slot.setdefault(r["slot"], []).append(r)
    for slot, ivs in by_slot.items():
        ivs.sort(key=lambda r: r["admit_tick"])
        prev_end = -1
        for iv in ivs:
            end = iv["release_tick"]
            if end is not None and end <= iv["admit_tick"]:
                raise TraceError(f"slot {slot}: empty/negative interval "
                                 f"[{iv['admit_tick']}, {end})")
            if iv["admit_tick"] < prev_end:
                raise TraceError(f"slot {slot}: overlapping intervals at "
                                 f"tick {iv['admit_tick']}")
            prev_end = end if end is not None else 10 ** 12
    return stats
