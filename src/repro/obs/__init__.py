"""Serving-engine observability: two-clock tracing (engine ticks + wall
time), Chrome-trace export, the recompilation sentinel, log-bucketed
latency histograms, and the per-parameter-path traffic waterfall.

Everything here is PASSIVE instrumentation: with the tracer off the
engine's outputs and device-call count are bitwise unchanged, and with
it on no extra device work is issued (the zero-overhead contract the
chaos benchmark guards)."""

from .chrome import to_chrome_trace  # noqa: F401
from .histogram import LogHistogram  # noqa: F401
from .sentinel import RecompileError, RecompileSentinel  # noqa: F401
from .trace import (EVENT_NAMES, SPAN_NAMES, TRACE_VERSION,  # noqa: F401
                    TraceError, Tracer, load, validate)
from .waterfall import (engine_waterfall, serving_cost_by_kind,  # noqa: F401
                        table_const_weights)
