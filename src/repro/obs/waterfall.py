"""Per-call-kind weight-traffic waterfall for the serving engine.

One scalar ``weight_bytes`` per call kind says WHETHER a run regressed;
the waterfall says WHERE: every byte is attributed to the parameter path
that moved it — dense projections by their pytree path
("blocks/attn/wq", "seg01/blocks/ssm/w_out", "blocks/moe/w1"), packed
stacked tables by table family and part ("tables/wq/w_blocks",
"tables/wq/idx", ...), and shape-fallback charges in explicit
"(untagged ...)" rows. Rows sum to the per-call ``weight_bytes``
EXACTLY (runtime.jaxpr_cost charges both at the same site with integer
byte values), which the serving benchmark equality-tests.

This is the instrumented-characterization layer the PIM benchmarking
literature (PAPERS.md: Gómez-Luna et al., CIMinus) argues real PIM
throughput work needs: modeled bytes are only trustworthy when you can
see which structure pays them.
"""

from __future__ import annotations

from typing import Dict, Optional

from repro.launch.steps import build_step
from repro.runtime.jaxpr_cost import analyze_call_kinds


def table_const_weights(tables) -> Optional[Dict[str, object]]:
    """{label: array} for a SegmentedKernelTables' packed arrays, keyed
    "tables/<family>/<part>" — the const_weights mapping
    runtime.jaxpr_cost.analyze uses to attribute closed-over pallas
    operands. None when serving dense (no tables)."""
    if tables is None:
        return None
    return {f"tables/{fam}/{part}": arr
            for fam, parts in tables.arrays.items()
            for part, arr in parts.items()}


def serving_cost_by_kind(cfg, mesh, params, cache, *, n_slots: int,
                         prefill_chunk: int, tables=None,
                         include_exact_fallback: bool = False,
                         paged: bool = False, max_pages: int = 0
                         ) -> Dict[str, Dict]:
    """Full jaxpr_cost accounting (weight_bytes + weight_bytes_by_path +
    flops/bytes) for one device call of every serving call kind ``cfg``
    supports, keyed by the step builders' call_kind tags.

    include_exact_fallback: for parallel-SSD archs, also analyze the
    exact-chunk step the parallel form is benchmarked against.
    paged/max_pages: analyze the page-table step variants (``cache`` must
    then be a pooled paged cache) — the extra ptab operand rides along."""
    import jax.numpy as jnp

    extra = ()
    if paged:
        extra = (jnp.full((n_slots, max_pages), -1, jnp.int32),)
    decode_fn, _ = build_step(cfg, mesh, "decode", stacked_tables=tables,
                              paged=paged)
    tok1 = jnp.zeros((n_slots, 1), jnp.int32)
    act = jnp.ones((n_slots,), bool)
    calls = {decode_fn.call_kind:
             (decode_fn, (params, cache, tok1, act) + extra)}
    caps = cfg.serving_capabilities()
    if caps.chunked_prefill:
        tokc = jnp.zeros((n_slots, prefill_chunk), jnp.int32)
        nv = jnp.full((n_slots,), prefill_chunk, jnp.int32)
        chunk_fn, _ = build_step(cfg, mesh, "prefill_chunk",
                                 stacked_tables=tables, paged=paged)
        calls[chunk_fn.call_kind] = (chunk_fn,
                                     (params, cache, tokc, nv) + extra)
        if include_exact_fallback and caps.parallel_prefill \
                and not cfg.prefill_exact:
            exact_fn, _ = build_step(cfg.scaled(prefill_exact=True), mesh,
                                     "prefill_chunk", stacked_tables=tables,
                                     paged=paged)
            calls[exact_fn.call_kind] = (exact_fn,
                                         (params, cache, tokc, nv) + extra)
    return analyze_call_kinds(calls,
                              const_weights=table_const_weights(tables))


def engine_waterfall(engine) -> Dict[str, Dict[str, object]]:
    """{call_kind: {"total": weight_bytes, "rows": {path: bytes}}} for a
    constructed ServeEngine — the traffic attribution a --trace-out run
    embeds in its trace (Tracer.waterfall) for the report CLI."""
    costs = serving_cost_by_kind(
        engine.cfg, engine.mesh, engine.params, engine.cache,
        n_slots=engine.n_slots, prefill_chunk=engine.prefill_chunk,
        tables=engine.stacked_tables, paged=engine.paged,
        max_pages=getattr(engine, "max_pages_per_slot", 0))
    return {kind: {"total": float(acc["weight_bytes"]),
                   "rows": dict(acc["weight_bytes_by_path"])}
            for kind, acc in costs.items()}
