"""Recompilation sentinel: the fixed-shape no-recompile contract, guarded.

The serving engine's whole performance story rests on every request
flowing through a handful of compiled-once executables (engine.py module
docstring). That property used to be folklore — a shape regression (a
scalar position sneaking back in, a cache dtype flip between calls)
would silently recompile every tick and only surface as a wall-clock
anomaly. The sentinel turns it into a hard invariant: each jitted step
is registered under a ``(call_kind, arch)`` key with a compile budget
(default: ONE), and ``check()`` — called once per engine tick — raises
``RecompileError`` the tick the budget is exceeded, naming the offender
and its compile count.

Counting uses the jit cache size (``PjitFunction._cache_size``), i.e.
the number of distinct (shape, dtype, sharding) signatures the
executable has compiled for — exactly "how many times did XLA compile
this step". On a jax build without the introspection hook the sentinel
degrades to inert (counts report -1, ``check`` passes) rather than
taking the engine down; ``available`` says which mode it is in.
"""

from __future__ import annotations

from typing import Dict


class RecompileError(RuntimeError):
    """A registered step compiled more often than its budget — the
    fixed-shape serving contract is broken."""


def _cache_size(fn) -> int:
    probe = getattr(fn, "_cache_size", None)
    if probe is None:
        return -1
    return int(probe())


class RecompileSentinel:
    """Registry of jitted step functions + per-key compile budgets."""

    def __init__(self, budget: int = 1):
        self.budget = budget
        self._fns: Dict[str, object] = {}

    @staticmethod
    def key(call_kind: str, arch: str) -> str:
        return f"{call_kind}@{arch}"

    def register(self, key: str, jitted):
        """Track ``jitted`` (a jax.jit result) under ``key``. Re-registering
        a key replaces the function (engines rebuild steps on reconfig)."""
        self._fns[key] = jitted

    @property
    def available(self) -> bool:
        """False when the jax build exposes no jit-cache introspection —
        the sentinel is then inert, not wrong."""
        return all(_cache_size(f) >= 0 for f in self._fns.values())

    def counts(self) -> Dict[str, int]:
        """Compile count per registered key (-1: introspection missing)."""
        return {k: _cache_size(f) for k, f in self._fns.items()}

    def check(self):
        """Raise RecompileError if any registered step exceeded its
        budget. Cheap (one int read per step), intended per-tick."""
        over = {k: n for k, n in self.counts().items() if n > self.budget}
        if over:
            raise RecompileError(
                f"step(s) recompiled past the budget of {self.budget} "
                f"compile(s): " +
                ", ".join(f"{k} compiled {n}x" for k, n in over.items()) +
                " — a fixed-shape serving step changed its input "
                "signature between calls")
