"""Log-bucketed latency histograms: percentiles without raw samples.

A serving run at production depth emits millions of per-call latencies;
storing them to compute p99 at shutdown is exactly the kind of
unbounded-memory observability the engine must not carry. ``LogHistogram``
keeps a fixed-granularity geometric bucketing instead: bucket ``i``
covers ``[min_value * growth**i, min_value * growth**(i+1))``, so
relative resolution is constant (``growth - 1``, ~9% at the default
1.09) across nine-plus decades while memory stays O(occupied buckets).

Percentiles are nearest-rank over the bucket counts and report the
GEOMETRIC MIDPOINT of the selected bucket — the estimate's relative
error is bounded by half a bucket width, which is the accuracy contract
tests/test_obs.py holds it to.
"""

from __future__ import annotations

import math
from typing import Dict


class LogHistogram:
    """Fixed-shape log-bucketed histogram of non-negative samples."""

    def __init__(self, min_value: float = 1e-6, growth: float = 1.09):
        if not min_value > 0 or not growth > 1:
            raise ValueError("need min_value > 0 and growth > 1")
        self.min_value = min_value
        self.growth = growth
        self._log_g = math.log(growth)
        self.buckets: Dict[int, int] = {}    # bucket index -> count
        self.count = 0
        self.total = 0.0

    def _index(self, value: float) -> int:
        if value <= self.min_value:
            return 0
        return int(math.log(value / self.min_value) // self._log_g)

    def add(self, value: float):
        """Record one sample (values <= min_value land in bucket 0)."""
        if value < 0:
            raise ValueError(f"negative sample {value}")
        i = self._index(value)
        self.buckets[i] = self.buckets.get(i, 0) + 1
        self.count += 1
        self.total += value

    def merge(self, other: "LogHistogram"):
        if (other.min_value, other.growth) != (self.min_value, self.growth):
            raise ValueError("cannot merge histograms with different "
                             "bucketings")
        for i, n in other.buckets.items():
            self.buckets[i] = self.buckets.get(i, 0) + n
        self.count += other.count
        self.total += other.total

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Nearest-rank percentile estimate (geometric bucket midpoint).
        0.0 when empty — percentiles of nothing are a reporting edge,
        not an error."""
        if not self.count:
            return 0.0
        rank = min(self.count, max(1, math.ceil(q * self.count)))
        seen = 0
        for i in sorted(self.buckets):
            seen += self.buckets[i]
            if seen >= rank:
                lo = self.min_value * self.growth ** i
                return lo * math.sqrt(self.growth)
        raise AssertionError("rank beyond total count")  # unreachable

    def to_dict(self) -> dict:
        """JSON-safe snapshot (bucket keys stringified for JSONL)."""
        return {"min_value": self.min_value, "growth": self.growth,
                "count": self.count, "total": self.total,
                "buckets": {str(i): n for i, n in
                            sorted(self.buckets.items())}}

    @classmethod
    def from_dict(cls, d: dict) -> "LogHistogram":
        h = cls(min_value=d["min_value"], growth=d["growth"])
        h.count = int(d["count"])
        h.total = float(d["total"])
        h.buckets = {int(i): int(n) for i, n in d["buckets"].items()}
        return h

    def summary_ms(self) -> dict:
        """The reporting block metrics.summary() embeds per call kind:
        count + mean/p50/p95/p99 in MILLISECONDS (samples are seconds)."""
        return {
            "count": self.count,
            "mean_ms": 1e3 * self.mean,
            "p50_ms": 1e3 * self.percentile(0.50),
            "p95_ms": 1e3 * self.percentile(0.95),
            "p99_ms": 1e3 * self.percentile(0.99),
        }
