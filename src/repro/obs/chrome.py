"""Chrome-trace (Perfetto-loadable) conversion of engine traces.

``to_chrome_trace`` reformats the obs.trace record stream into the
Trace Event Format JSON that chrome://tracing and https://ui.perfetto.dev
open directly: one process, thread 0 for the engine ("tick" and "call"
spans as complete "X" events), one thread per cache slot carrying that
slot's occupancy intervals (rendered as "rid<N>" spans) and lifecycle
instants. Wall microseconds map straight onto the trace clock; engine
ticks ride along in every event's ``args`` so the two clocks stay
cross-referencable inside the viewer.

Slot intervals are recorded in TICKS (they come from the scheduler's
audit log, which has no wall clock), so the converter rebuilds their
wall extent from the tick spans: an interval [admit, release) spans from
the start of the admit tick's span to the END of tick release-1's span.
"""

from __future__ import annotations

from typing import Dict, List

_ENGINE_TID = 0


def _thread_meta(tid: int, name: str) -> dict:
    return {"ph": "M", "pid": 0, "tid": tid, "name": "thread_name",
            "args": {"name": name}}


def to_chrome_trace(records: List[dict]) -> dict:
    """Trace Event Format dict ({"traceEvents": [...]}) from obs.trace
    records (as produced by Tracer.records / obs.trace.load)."""
    events: List[dict] = [_thread_meta(_ENGINE_TID, "engine")]
    meta = records[0] if records and records[0].get("type") == "meta" else {}
    if meta.get("arch"):
        events.append({"ph": "M", "pid": 0, "name": "process_name",
                       "args": {"name": f"serve:{meta['arch']}"}})

    # tick -> (start_us, end_us), for mapping tick-clock intervals to wall
    tick_bounds: Dict[int, tuple] = {}
    for r in records:
        if r.get("type") == "span" and r.get("name") == "tick" \
                and r.get("dur_us") is not None:
            tick_bounds[r["tick"]] = (r["ts_us"], r["ts_us"] + r["dur_us"])

    slots_seen = set()
    for r in records:
        t = r.get("type")
        if t == "span":
            name = r["name"]
            if name == "call":
                name = f"call:{r['attrs'].get('kind', '?')}"
            events.append({
                "ph": "X", "pid": 0, "tid": _ENGINE_TID, "name": name,
                "cat": r["name"], "ts": r["ts_us"],
                "dur": r["dur_us"] if r["dur_us"] is not None else 0.0,
                "args": {"tick": r["tick"], **r["attrs"]}})
        elif t == "event":
            slot = r["attrs"].get("slot")
            tid = _ENGINE_TID if slot is None else int(slot) + 1
            if slot is not None:
                slots_seen.add(int(slot))
            events.append({
                "ph": "i", "pid": 0, "tid": tid, "name": r["name"],
                "cat": "lifecycle", "ts": r["ts_us"],
                "s": "t" if slot is not None else "p",
                "args": {"tick": r["tick"], **r["attrs"]}})
        elif t == "interval":
            if not tick_bounds:
                continue                  # tickless trace: nothing to map to
            slots_seen.add(r["slot"])
            last_tick = max(tick_bounds)
            admit = min(max(r["admit_tick"], min(tick_bounds)), last_tick)
            rel = r["release_tick"]
            # [admit, release) in ticks: end at the END of tick release-1
            # (an open interval runs to the end of the trace)
            end_tick = last_tick if rel is None \
                else min(max(rel - 1, admit), last_tick)
            ts = tick_bounds[admit][0]
            events.append({
                "ph": "X", "pid": 0, "tid": r["slot"] + 1,
                "name": f"rid{r['rid']}", "cat": "slot", "ts": ts,
                "dur": max(tick_bounds[end_tick][1] - ts, 0.0),
                "args": {"rid": r["rid"], "admit_tick": r["admit_tick"],
                         "release_tick": r["release_tick"]}})
    for s in sorted(slots_seen):
        events.append(_thread_meta(s + 1, f"slot{s}"))
    return {"traceEvents": events, "displayTimeUnit": "ms"}
