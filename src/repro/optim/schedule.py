"""LR schedules. The paper's training protocol (Sec. VI-A): cosine
annealing 1e-3 -> 1e-7 with warmup (1e-5 start) and cooldown."""

from __future__ import annotations

import jax.numpy as jnp


def cosine_with_warmup(step, *, base_lr: float = 1e-3,
                       min_lr: float = 1e-7, warmup_start: float = 1e-5,
                       warmup_steps: int = 100, total_steps: int = 10000):
    step = jnp.asarray(step, jnp.float32)
    warm = warmup_start + (base_lr - warmup_start) * (
        step / jnp.maximum(warmup_steps, 1))
    t = jnp.clip((step - warmup_steps)
                 / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = min_lr + 0.5 * (base_lr - min_lr) * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < warmup_steps, warm, cos)
