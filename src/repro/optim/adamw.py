"""AdamW (the paper's optimizer) as pure pytree functions, with optional
INT8-quantized first/second moments (block-wise, using this repo's own
quantization machinery) — a distributed-optimization memory trick that cuts
optimizer-state HBM by 4x (discussed in EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict


def _zeros_like_state(p, int8: bool, sqrt_domain: bool):
    """Block-quantized INT8 moment storage (bitsandbytes-style).

    First moment: signed linear INT8 per 256-block. Second moment (always
    >= 0, huge dynamic range): quantized in the SQRT domain — linear INT8
    on v collapses small entries to zero and 1/sqrt(v+eps) then explodes.
    The `sqrt` marker key selects the codec.
    """
    if int8 and p.ndim >= 1 and p.size >= 256:
        blk = 256
        nblk = -(-p.size // blk)
        d = {"q": jnp.zeros((nblk, blk), jnp.int8),
             "scale": jnp.zeros((nblk, 1), jnp.float32)}
        if sqrt_domain:
            d["sqrt"] = jnp.ones((), jnp.int8)
        return d
    return jnp.zeros_like(p, dtype=jnp.float32)


def _size(shape):
    n = 1
    for d in shape:
        n *= int(d)
    return n


def _dequant(s, shape):
    if isinstance(s, dict):
        val = s["q"].astype(jnp.float32) * s["scale"]
        if "sqrt" in s:
            val = val * val
        return val.reshape(-1)[:_size(shape)].reshape(shape)
    return s


def _quant(x, like):
    if isinstance(like, dict):
        blk = like["q"].shape[1]
        nblk = like["q"].shape[0]
        pad = nblk * blk - x.size
        flat = jnp.pad(x.reshape(-1), (0, pad)).reshape(nblk, blk)
        if "sqrt" in like:
            flat = jnp.sqrt(jnp.maximum(flat, 0.0))
        scale = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0 + 1e-12
        out = {"q": jnp.round(flat / scale).astype(jnp.int8),
               "scale": scale.astype(jnp.float32)}
        if "sqrt" in like:
            out["sqrt"] = like["sqrt"]
        return out
    return x


def adamw_init(params, int8_state: bool = False) -> AdamWState:
    mk_m = lambda p: _zeros_like_state(p, int8_state, sqrt_domain=False)
    mk_v = lambda p: _zeros_like_state(p, int8_state, sqrt_domain=True)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree_util.tree_map(mk_m, params),
                      v=jax.tree_util.tree_map(mk_v, params))


def adamw_update(params, grads, state: AdamWState, *, lr,
                 b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
                 weight_decay: float = 0.01):
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(p, g, m_s, v_s):
        g = g.astype(jnp.float32)
        m = b1 * _dequant(m_s, p.shape) + (1 - b1) * g
        v = b2 * _dequant(v_s, p.shape) + (1 - b2) * g * g
        update = (m / c1) / (jnp.sqrt(v / c2) + eps)
        update = update + weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * update).astype(p.dtype)
        return new_p, _quant(m, m_s), _quant(v, v_s)

    leaves_p, treedef = jax.tree_util.tree_flatten(params)
    leaves_g = treedef.flatten_up_to(grads)
    leaves_m = treedef.flatten_up_to(state.m)
    leaves_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(leaves_p, leaves_g, leaves_m, leaves_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)
