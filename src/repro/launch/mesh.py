"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state. The dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512
before any jax import; smoke tests and benchmarks see the real single
device and use `make_test_mesh`.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh() -> Mesh:
    """1-device mesh with the production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))
