"""Render a serving-engine trace (launch.serve --trace-out) as text.

    PYTHONPATH=src python -m repro.launch.report trace.jsonl
    PYTHONPATH=src python -m repro.launch.report trace.jsonl \
        --chrome trace_chrome.json   # open in ui.perfetto.dev

Sections (each reads one record type of the obs.trace taxonomy):

  * TIMELINE   — per-call-kind span latency (count, total, p50/p95 from
    the recorded dur_us) plus engine-tick stats;
  * SLOTS      — per-slot occupancy bars from the closed SlotIntervals
    (the engine's audit log), busy fraction per slot and overall;
  * QUEUE      — queue-depth-over-time sparkline from the tick spans'
    queue_depth attr;
  * PAGE POOL  — page-pool occupancy sparkline from the tick spans'
    pages_used / pages_total attrs (paged engines only);
  * WATERFALL  — per-call-kind weight-traffic attribution by parameter
    path (rows sum to the call's weight_bytes exactly);
  * FAULTS     — fault / retry / quarantine / replay / preempt / shed /
    reject events grouped by kind, with the tick each fired on.

The trace is validated (obs.trace.validate) before rendering — a trace
that fails its structural invariants is a bug report, not a report.
"""

from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict
from typing import Dict, List

from repro.obs import to_chrome_trace, validate
from repro.obs.trace import load

#: sparkline glyphs, lowest to highest occupancy
_BARS = " .:-=+*#%@"


def _spark(values: List[float], vmax: float) -> str:
    if vmax <= 0:
        return "".join(" " for _ in values)
    out = []
    for v in values:
        i = min(int(v / vmax * (len(_BARS) - 1) + 0.5), len(_BARS) - 1)
        out.append(_BARS[i])
    return "".join(out)


def _percentile(sorted_vals: List[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    i = min(int(q * len(sorted_vals)), len(sorted_vals) - 1)
    return sorted_vals[i]


def _fmt_bytes(b: float) -> str:
    for unit in ("B", "KB", "MB", "GB"):
        if abs(b) < 1024 or unit == "GB":
            return f"{b:.1f} {unit}" if unit != "B" else f"{b:.0f} B"
        b /= 1024
    return f"{b:.1f} GB"


def render(records: List[dict], width: int = 64) -> str:
    stats = validate(records)
    meta = records[0]
    spans = [r for r in records if r.get("type") == "span"]
    events = [r for r in records if r.get("type") == "event"]
    intervals = [r for r in records if r.get("type") == "interval"]
    waterfalls = [r for r in records if r.get("type") == "waterfall"]
    ticks = [r for r in spans if r["name"] == "tick"]
    calls = [r for r in spans if r["name"] == "call"]
    lines: List[str] = []

    head = {k: v for k, v in meta.items() if k not in ("type", "version")}
    lines.append(f"trace v{meta['version']}  {head}")
    lines.append(f"records: {stats['spans']} spans, {stats['events']} "
                 f"events, {stats['intervals']} intervals, "
                 f"{stats['waterfalls']} waterfalls")

    # -- TIMELINE ----------------------------------------------------------
    lines.append("")
    lines.append("== TIMELINE ==")
    if ticks:
        durs = sorted(t["dur_us"] / 1e3 for t in ticks)
        total_ms = sum(durs)
        lines.append(f"{len(ticks)} ticks over {total_ms:.1f} ms wall  "
                     f"(tick p50={_percentile(durs, 0.5):.2f} "
                     f"p95={_percentile(durs, 0.95):.2f} ms)")
    by_kind: Dict[str, List[dict]] = defaultdict(list)
    for c in calls:
        tag = c["attrs"].get("kind", "?")
        if c["attrs"].get("replay"):
            tag += "+replay"
        by_kind[tag].append(c)
    for kind in sorted(by_kind):
        cs = by_kind[kind]
        durs = sorted(c["dur_us"] / 1e3 for c in cs)
        occ = [c["attrs"].get("occupancy") for c in cs]
        occ = [o for o in occ if o is not None]
        occ_s = (f"  occupancy mean={sum(occ) / len(occ):.2f}"
                 if occ else "")
        lines.append(f"  {kind:<28} {len(cs):>5} calls  "
                     f"p50={_percentile(durs, 0.5):.2f} "
                     f"p95={_percentile(durs, 0.95):.2f} ms  "
                     f"total={sum(durs):.1f} ms{occ_s}")

    # -- SLOTS -------------------------------------------------------------
    if intervals and ticks:
        n_ticks = max(t["tick"] for t in ticks) + 1
        lines.append("")
        lines.append("== SLOTS ==")
        by_slot: Dict[int, List[dict]] = defaultdict(list)
        for iv in intervals:
            by_slot[iv["slot"]].append(iv)
        n_cells = min(n_ticks, width)
        scale = n_ticks / n_cells          # ticks per display cell
        busy_total = 0
        for slot in sorted(by_slot):
            cells = [0.0] * n_cells
            busy = 0
            for iv in by_slot[slot]:
                end = iv["release_tick"] if iv["release_tick"] is not None \
                    else n_ticks
                busy += end - iv["admit_tick"]
                for t in range(iv["admit_tick"], min(end, n_ticks)):
                    c = min(int(t / scale), len(cells) - 1)
                    cells[c] += 1.0 / max(scale, 1.0)
            busy_total += busy
            lines.append(f"  slot {slot}  [{_spark(cells, 1.0)}]  "
                         f"busy {busy}/{n_ticks} "
                         f"({busy / n_ticks:.0%}, "
                         f"{len(by_slot[slot])} requests)")
        n_slots = max(by_slot) + 1
        lines.append(f"  overall busy fraction: "
                     f"{busy_total / (n_ticks * n_slots):.2f} "
                     f"over {n_slots} slots")

    # -- QUEUE -------------------------------------------------------------
    depths = [(t["tick"], t["attrs"].get("queue_depth", 0)) for t in ticks]
    if depths:
        lines.append("")
        lines.append("== QUEUE DEPTH ==")
        vals = [d for _, d in depths]
        vmax = max(vals)
        # bucket ticks down to the display width (mean depth per bucket)
        if len(vals) > width:
            per = len(vals) / width
            vals = [sum(vals[int(i * per):int((i + 1) * per)]) /
                    max(len(vals[int(i * per):int((i + 1) * per)]), 1)
                    for i in range(width)]
        lines.append(f"  [{_spark(vals, max(vmax, 1))}]  "
                     f"max={vmax}  mean={sum(d for _, d in depths) / len(depths):.2f}  "
                     f"(tick 0..{depths[-1][0]})")

    # -- PAGE POOL ---------------------------------------------------------
    pool = [(t["tick"], t["attrs"].get("pages_used"),
             t["attrs"].get("pages_total")) for t in ticks
            if t["attrs"].get("pages_total")]
    if pool:
        lines.append("")
        lines.append("== PAGE POOL ==")
        total = max(pt for _, _, pt in pool)
        vals = [float(pu) for _, pu, _ in pool]
        vmax = max(vals)
        full_ticks = sum(1 for v in vals if v >= total)
        mean = sum(vals) / len(vals)
        if len(vals) > width:
            per = len(vals) / width
            vals = [sum(vals[int(i * per):int((i + 1) * per)]) /
                    max(len(vals[int(i * per):int((i + 1) * per)]), 1)
                    for i in range(width)]
        lines.append(f"  [{_spark(vals, total)}]  "
                     f"pool {total} pages  max_used={vmax:.0f} "
                     f"mean={mean:.2f}  full {full_ticks}/{len(pool)} "
                     f"ticks")

    # -- WATERFALL ---------------------------------------------------------
    if waterfalls:
        lines.append("")
        lines.append("== WEIGHT-TRAFFIC WATERFALL (bytes / device call) ==")
        for wf in waterfalls:
            lines.append(f"  {wf['kind']}  total {_fmt_bytes(wf['total'])}")
            rows = sorted(wf["rows"].items(), key=lambda kv: -kv[1])
            top = max((v for _, v in rows), default=1.0)
            for path, b in rows:
                bar = "#" * max(int(b / top * 28), 1)
                lines.append(f"    {path:<36} {_fmt_bytes(b):>10}  "
                             f"{b / wf['total']:>6.1%}  {bar}")
            resid = wf["total"] - sum(wf["rows"].values())
            if resid:
                lines.append(f"    (!) rows - total residual: {resid}")

    # -- FAULTS ------------------------------------------------------------
    fault_names = ("fault", "retry", "quarantine", "replay", "preempt",
                   "shed", "reject")
    fevents = [e for e in events if e["name"] in fault_names]
    if fevents:
        lines.append("")
        lines.append("== FAULTS / RECOVERY ==")
        grouped: Dict[str, List[dict]] = defaultdict(list)
        for e in fevents:
            key = e["name"]
            sub = e["attrs"].get("kind") or e["attrs"].get("reason")
            if sub:
                key += f"[{sub}]"
            grouped[key].append(e)
        for key in sorted(grouped):
            es = grouped[key]
            tks = [e["tick"] for e in es]
            show = ", ".join(str(t) for t in tks[:12])
            more = f", +{len(tks) - 12} more" if len(tks) > 12 else ""
            lines.append(f"  {key:<28} {len(es):>4}x  "
                         f"ticks [{show}{more}]")
        replays = [e for e in events if e["name"] == "replay"]
        if replays:
            by_rid: Dict[int, int] = defaultdict(int)
            for e in replays:
                by_rid[e["attrs"]["rid"]] += 1
            att = ", ".join(f"req{r}: {n}" for r, n in sorted(by_rid.items()))
            lines.append(f"  replay attribution: {att}")

    lines.append("")
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Render a serving-engine JSONL trace "
                    "(launch.serve --trace-out) as text.")
    ap.add_argument("trace", help="JSONL trace file")
    ap.add_argument("--chrome", default=None, metavar="OUT.json",
                    help="also write a Chrome/Perfetto trace "
                         "(open at ui.perfetto.dev)")
    ap.add_argument("--width", type=int, default=64,
                    help="sparkline/occupancy-bar width in characters")
    args = ap.parse_args(argv)

    records = load(args.trace)
    sys.stdout.write(render(records, width=args.width))
    if args.chrome:
        with open(args.chrome, "w") as f:
            json.dump(to_chrome_trace(records), f)
        print(f"[report] chrome trace -> {args.chrome} "
              f"(open at ui.perfetto.dev)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
