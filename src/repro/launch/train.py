"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Wires together: config -> params -> sharded train step (grad accumulation,
AdamW + cosine schedule, optional INT8 optimizer state and gradient
compression) -> synthetic data pipeline -> async checkpointing ->
straggler monitor -> resilient restart loop. On the CPU container use
--reduced; on a pod the same flags drive the production mesh.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import SyntheticLMDataset, make_pipeline
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.launch.steps import build_train_step
from repro.models import init_params
from repro.optim import adamw_init
from repro.runtime import sharding as shr
from repro.runtime.fault import StragglerMonitor


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--int8-opt", action="store_true")
    ap.add_argument("--dbpim-every", type=int, default=0,
                    help="every N steps, project weights to the DB-PIM "
                         "FTA grid (hybrid-grained pruning, Fig. 4 stage "
                         "2) — train the compressed model in the loop")
    ap.add_argument("--dbpim-value-sparsity", type=float, default=0.0)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    mesh = (make_production_mesh() if args.production_mesh
            else make_test_mesh())
    print(f"[train] {cfg.name}: mesh={dict(mesh.shape)} "
          f"devices={len(jax.devices())}")

    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    opt_state = adamw_init(params, int8_state=args.int8_opt)
    n_params = sum(int(np.prod(p.shape))
                   for p in jax.tree_util.tree_leaves(params))
    print(f"[train] params: {n_params/1e6:.1f}M")

    step_fn, shard_fn = build_train_step(
        cfg, mesh, microbatches=args.microbatches,
        grad_compression=args.grad_compression)
    ds = SyntheticLMDataset(cfg, args.batch, args.seq, seed=args.seed)

    start_step = 0
    ckpt = None
    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir, every=args.ckpt_every)
        restored = ckpt.restore_or_none((params, opt_state))
        if restored is not None:
            (params, opt_state), start_step, _ = restored
            params = jax.tree_util.tree_map(jax.numpy.asarray, params)
            opt_state = jax.tree_util.tree_map(jax.numpy.asarray, opt_state)
            print(f"[train] resumed from step {start_step}")

    with mesh:
        batch0 = ds.batch_at(start_step)
        pspec, ospec, bspec = shard_fn(params, opt_state, batch0)
        jitted = jax.jit(step_fn,
                         in_shardings=(shr.named(pspec, mesh),
                                       shr.named(ospec, mesh),
                                       shr.named(bspec, mesh)),
                         donate_argnums=(0, 1))
        mon = StragglerMonitor()
        losses = []
        pipe = make_pipeline(ds, start_step)
        for step in range(start_step, args.steps):
            batch = next(pipe)
            t0 = time.time()
            params, opt_state, loss = jitted(params, opt_state, batch)
            loss_v = float(loss)
            dt = time.time() - t0
            losses.append(loss_v)
            if mon.record(dt):
                print(f"[train] step {step}: straggler ({dt:.2f}s vs "
                      f"p50 {mon.p50:.2f}s)")
            if step % args.log_every == 0:
                print(f"[train] step {step}: loss={loss_v:.4f} "
                      f"({dt*1e3:.0f} ms)", flush=True)
            if args.dbpim_every and (step + 1) % args.dbpim_every == 0:
                # FTA-aware training: periodic projection of every
                # eligible projection onto the FTA-compliant INT8 grid
                # (the paper applies it per epoch; STE == projected
                # weights keep training between projections).
                from repro.sparsity import dequant_tree, sparsify_params
                comp = sparsify_params(
                    params, cfg, value_sparsity=args.dbpim_value_sparsity)
                params = dequant_tree(params, comp)
            if ckpt:
                ckpt.maybe_save(step + 1, (params, opt_state),
                                extra={"loss": loss_v})
        if ckpt:
            ckpt.wait()
    print(f"[train] done: first loss {losses[0]:.4f} -> "
          f"last loss {losses[-1]:.4f}")
    return losses


if __name__ == "__main__":
    main()
