"""jit-able production steps: train (grad-accumulation + AdamW + schedule),
prefill, and decode — with explicit in/out shardings for a given mesh.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import decode_chunk, decode_step, loss_fn, merge_slots
from repro.models.config import ModelConfig
from repro.optim import AdamWState, adamw_init, adamw_update, \
    cosine_with_warmup
from repro.runtime import sharding as shr


def build_train_step(cfg: ModelConfig, mesh: Mesh, *,
                     microbatches: int = 1, int8_opt_state: bool = False,
                     grad_compression: bool = False):
    """Returns (train_step, in_shardings builder). The step:
      grads = mean over `microbatches` scan iterations (activation memory
      control); AdamW with the paper's cosine schedule; ZeRO-1-sharded
      optimizer state.
    """
    dpa = shr.dp_axes(mesh)
    dpa = dpa if len(dpa) > 1 else (dpa[0] if dpa else None)

    def train_step(params, opt_state: AdamWState, batch):
        def micro_loss(p, mb):
            return loss_fn(p, mb, cfg)

        if microbatches > 1:
            def reshard(x):
                x = x.reshape((microbatches, x.shape[0] // microbatches)
                              + x.shape[1:])
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, P(None, dpa)))
            mbatch = jax.tree_util.tree_map(reshard, batch)

            def acc_fn(carry, mb):
                loss, g = jax.value_and_grad(micro_loss)(params, mb)
                acc_loss, acc_g = carry
                return (acc_loss + loss,
                        jax.tree_util.tree_map(jnp.add, acc_g, g)), None

            zero = (jnp.zeros((), jnp.float32),
                    jax.tree_util.tree_map(
                        lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (loss_sum, grad_sum), _ = jax.lax.scan(acc_fn, zero, mbatch)
            loss = loss_sum / microbatches
            grads = jax.tree_util.tree_map(lambda g: g / microbatches,
                                           grad_sum)
        else:
            loss, grads = jax.value_and_grad(micro_loss)(params, batch)

        if grad_compression:
            from repro.runtime.compression import compress_tree
            grads = compress_tree(grads)

        lr = cosine_with_warmup(opt_state.step)
        new_params, new_state = adamw_update(params, grads, opt_state, lr=lr)
        return new_params, new_state, loss

    def shardings(params, opt_state, batch):
        # FSDP only when a TP-sharded replica would strain HBM: for small
        # models the per-(microbatch x layer) FSDP all-gathers cost far
        # more than the single DP grad all-reduce they displace (mamba2:
        # 1.38 TB/step of gathers for 2.6 GB of params). ZeRO-1 moment
        # sharding is kept either way (touched once per step).
        pspec = shr.param_specs(params, mesh, fsdp=_needs_fsdp(params, mesh))
        mv_spec = _moment_specs(params, pspec, opt_state.m, mesh)
        ospec = AdamWState(step=P(), m=mv_spec, v=mv_spec)
        bspec = shr.batch_specs(batch, mesh)
        return pspec, ospec, bspec

    return train_step, shardings


def _needs_fsdp(params, mesh, budget_bytes: float = 4e9) -> bool:
    pbytes = sum(leaf.size * getattr(leaf.dtype, "itemsize", 2)
                 for leaf in jax.tree_util.tree_leaves(params))
    return (pbytes / mesh.shape.get("model", 1)) > budget_bytes


def _moment_specs(params, pspecs, moments, mesh):
    """ZeRO-1 moment sharding. fp32 moments mirror the param spec extended
    over the DP axes; int8 block-quantized moments ({q, scale}) shard their
    block dim over DP."""
    dpa = shr.dp_axes(mesh)
    dpa = dpa if len(dpa) > 1 else (dpa[0] if dpa else None)
    dpn = shr.axis_size(mesh, dpa)

    leaves_p, treedef = jax.tree_util.tree_flatten(params)
    leaves_s = treedef.flatten_up_to(pspecs)
    leaves_m = treedef.flatten_up_to(moments)
    out = []
    for p, spec, m in zip(leaves_p, leaves_s, leaves_m):
        if isinstance(m, dict):            # int8 {q, scale}
            blk_spec = P(dpa) if m["q"].shape[0] % dpn == 0 else P()
            out.append({"q": blk_spec, "scale": blk_spec})
        else:
            out.append(shr.zero1_spec(spec, p.shape, mesh))
    return treedef.unflatten(out)


SERVE_CALL_KINDS = ("serve", "decode", "prefill_chunk")

#: Call-kind tag suffix for RECOVERY traffic: the serving engine reuses
#: the one compiled prefill executable for recovery-by-replay
#: re-prefills (a faulted slot's durable record re-enters through the
#: same fixed-shape chunk step — no extra compilation for the rare
#: path), but meters those calls separately by suffixing the step's
#: call_kind tag, e.g. "prefill_parallel+replay". Benchmarks multiply
#: metrics.calls_by_kind["<kind>+replay"] by the per-call weight bytes
#: of the base kind to price recovery overhead.
REPLAY_TAG = "+replay"

#: Same idea for WARM-RESTART traffic: after ServeEngine.restore, every
#: active slot re-prefills its durable record (prompt + journaled
#: tokens) through the same executable, and those calls are metered
#: "<kind>+restore". Restart replay is the cost snapshot cadence trades
#: against (work redone <= ticks since the last snapshot), so it must
#: be attributable separately from in-engine fault replays.
RESTORE_TAG = "+restore"


def build_step(cfg: ModelConfig, mesh: Mesh, call_kind: str, *,
               stacked_tables=None, int8_weights: bool = False,
               paged: bool = False):
    """One entry point for every fixed-shape serving step. Returns
    (step_fn, shardings_fn); step_fn carries a ``call_kind`` tag that
    runtime.jaxpr_cost.analyze_call_kinds and the serving engine consume
    for per-kind cost attribution.

    call_kind selects the step:

      * "serve" — plain (B, 1) decode step, ``(params, cache, token)``.
        Tag "decode". int8_weights=True keeps projections in HBM as
        INT8 + per-filter scale (the FTA/DB-PIM serving format),
        dequantized in-graph so the dequant fuses into the matmuls —
        halving decode weight traffic. Mutually exclusive with
        stacked_tables (the tables carry their own payload).
      * "decode" — the serving engine's slot decode step,
        ``(params, cache, token, active)``: inactive slots (free,
        draining, or mid-prefill while their neighbors decode) compute
        alongside the batch but their cache writes and position advances
        are discarded (models.decode.merge_slots) — continuous batching
        with ZERO per-request recompilation. Positions come from
        cache["pos"], a (B,) vector of per-slot depths. Tag "decode".
      * "prefill_chunk" — chunked cache-filling prefill,
        ``(params, cache, tokens, n_valid)``: C prompt tokens per slot
        in ONE fixed-shape device call (models.decode.decode_chunk), so
        time-to-first-token is ceil(P/C) steps instead of P. n_valid (B,)
        carries each slot's real token count this chunk (0 = slot not
        prefilling; its cache is untouched). Tag "prefill_parallel" when
        SSM segments run the parallel SSD chunk form (one read of the
        stacked in/out projections per chunk;
        models.ssm.prefill_ssm_parallel), "prefill_chunk_exact" when
        every segment's chunk math is bit-identical to sequential decode
        (attention chunks always are; SSM with cfg.prefill_exact).
        Recovery-by-replay re-prefills run THIS executable too; the
        engine meters them under "<call_kind>+replay" (REPLAY_TAG).

    paged=True switches "decode"/"prefill_chunk" to the PAGED cache
    (pooled {"pk","pv"} leaves from models.init_cache(n_pages=...)): the
    steps take one extra trailing operand ``ptab`` (n_slots, max_pages)
    int32 — the host allocator's page table — through which every KV
    gather/scatter resolves in-graph. The table is a fixed-shape
    per-call operand (never cache-resident), so page churn between ticks
    costs ZERO recompiles. The "decode" step routes ``active`` into the
    attention write mask (pooled leaves have no batch dim for
    merge_slots to select on — inactive slots' writes are dropped at the
    scatter). "serve" (lock-step, no allocator) stays contiguous.

    stacked_tables (sparsity.sparse_linear.SegmentedKernelTables, from
    build_stacked_tables(params, cfg)): per-segment uniform-MAXB
    joint-sparse weight packs riding each segment's layer scan, so every
    projection of every layer runs the DB-PIM Pallas kernel — the
    compiled serving HLO changes (weight traffic (1 - vs) * 0.5 of dense
    bf16 for joint; (1 - vs) for the bf16-payload value tables).
    """
    if call_kind not in SERVE_CALL_KINDS:
        raise ValueError(f"call_kind {call_kind!r} not in "
                         f"{SERVE_CALL_KINDS}")
    if int8_weights and stacked_tables is not None:
        raise ValueError("int8_weights and stacked_tables are mutually "
                         "exclusive serving formats")
    if int8_weights and call_kind != "serve":
        raise ValueError("int8_weights is a 'serve' step format")
    if paged and call_kind == "serve":
        raise ValueError("paged cache is a serving-engine format; the "
                         "lock-step 'serve' step stays contiguous")

    if call_kind == "serve":
        def step_fn(params, cache, token):
            if int8_weights:
                from repro.sparsity.sparse_linear import \
                    dequant_params_for_serving
                params = dequant_params_for_serving(params)
            return decode_step(params, cache, token, cfg,
                               tables=stacked_tables)
        step_fn.call_kind = "decode"

        def shardings(params, cache, token):
            pspec = _serving_param_specs(params, mesh)
            cspec = shr.cache_specs(cache, cfg, mesh)
            tspec = shr.batch_specs({"token": token}, mesh)["token"]
            return pspec, cspec, tspec

    elif call_kind == "decode" and paged:
        def step_fn(params, cache, token, active, ptab):
            logits, new_cache = decode_step(params, cache, token, cfg,
                                            tables=stacked_tables,
                                            ptab=ptab, write_mask=active)
            return logits, merge_slots(new_cache, cache, active, cfg)
        step_fn.call_kind = "decode"

        def shardings(params, cache, token, active, ptab):
            pspec = _serving_param_specs(params, mesh)
            cspec = shr.cache_specs(cache, cfg, mesh)
            bspec = shr.batch_specs({"token": token, "active": active},
                                    mesh)
            # page table: tiny int32, replicated — sharding it would
            # only add a gather before every pool lookup
            return pspec, cspec, bspec["token"], bspec["active"], P()

    elif call_kind == "decode":
        def step_fn(params, cache, token, active):
            logits, new_cache = decode_step(params, cache, token, cfg,
                                            tables=stacked_tables)
            return logits, merge_slots(new_cache, cache, active, cfg)
        step_fn.call_kind = "decode"

        def shardings(params, cache, token, active):
            pspec = _serving_param_specs(params, mesh)
            cspec = shr.cache_specs(cache, cfg, mesh)
            bspec = shr.batch_specs({"token": token, "active": active},
                                    mesh)
            return pspec, cspec, bspec["token"], bspec["active"]

    elif paged:                            # "prefill_chunk", paged
        def step_fn(params, cache, tokens, n_valid, ptab):
            return decode_chunk(params, cache, tokens, n_valid, cfg,
                                tables=stacked_tables, ptab=ptab)
        caps = cfg.serving_capabilities()
        step_fn.call_kind = (
            "prefill_parallel"
            if caps.parallel_prefill and not cfg.prefill_exact
            else "prefill_chunk_exact")

        def shardings(params, cache, tokens, n_valid, ptab):
            pspec = _serving_param_specs(params, mesh)
            cspec = shr.cache_specs(cache, cfg, mesh)
            bspec = shr.batch_specs({"tokens": tokens, "n_valid": n_valid},
                                    mesh)
            return (pspec, cspec, bspec["tokens"], bspec["n_valid"],
                    P())

    else:                                  # "prefill_chunk"
        def step_fn(params, cache, tokens, n_valid):
            return decode_chunk(params, cache, tokens, n_valid, cfg,
                                tables=stacked_tables)
        caps = cfg.serving_capabilities()
        step_fn.call_kind = (
            "prefill_parallel"
            if caps.parallel_prefill and not cfg.prefill_exact
            else "prefill_chunk_exact")

        def shardings(params, cache, tokens, n_valid):
            pspec = _serving_param_specs(params, mesh)
            cspec = shr.cache_specs(cache, cfg, mesh)
            bspec = shr.batch_specs({"tokens": tokens, "n_valid": n_valid},
                                    mesh)
            return pspec, cspec, bspec["tokens"], bspec["n_valid"]

    # which model family compiled this step — paired with call_kind it
    # forms the recompile sentinel's registry key and the tracer's
    # call-span arch attribute
    step_fn.arch = cfg.name
    return step_fn, shardings


def build_serve_step(cfg: ModelConfig, mesh: Mesh,
                     int8_weights: bool = False, stacked_tables=None):
    """Thin wrapper over build_step(call_kind="serve")."""
    return build_step(cfg, mesh, "serve", stacked_tables=stacked_tables,
                      int8_weights=int8_weights)


def build_slot_decode_step(cfg: ModelConfig, mesh: Mesh,
                           stacked_tables=None):
    """Thin wrapper over build_step(call_kind="decode")."""
    return build_step(cfg, mesh, "decode", stacked_tables=stacked_tables)


def build_prefill_chunk_step(cfg: ModelConfig, mesh: Mesh,
                             stacked_tables=None):
    """Thin wrapper over build_step(call_kind="prefill_chunk")."""
    return build_step(cfg, mesh, "prefill_chunk",
                      stacked_tables=stacked_tables)


def _serving_param_specs(params, mesh: Mesh):
    # Serving keeps weights RESIDENT (TP-sharded, replicated over DP):
    # FSDP would re-all-gather the full model every decoded token.
    # Only models whose TP shard exceeds the HBM budget (arctic-class)
    # keep FSDP and pay the gathers.
    pbytes = sum(
        leaf.size * getattr(leaf.dtype, "itemsize", 2)
        for leaf in jax.tree_util.tree_leaves(params))
    tp = mesh.shape.get("model", 1)
    fsdp = (pbytes / tp) > 12e9
    return shr.param_specs(params, mesh, fsdp=fsdp)


def build_prefill_step(cfg: ModelConfig, mesh: Mesh):
    from repro.models import prefill

    def prefill_step(params, batch):
        return prefill(params, batch["tokens"], cfg,
                       frames=batch.get("frames"))

    def shardings(params, batch):
        return (shr.param_specs(params, mesh,
                                fsdp=_needs_fsdp(params, mesh)),
                shr.batch_specs(batch, mesh))

    return prefill_step, shardings
