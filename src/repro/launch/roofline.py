"""Roofline analysis over the dry-run records.

Per (arch x shape x mesh) cell, derive the three roofline terms for the
TPU v5e target:

  compute term    = HLO_FLOPs / (chips x 197e12 FLOP/s bf16)
  memory term     = HBM_bytes / (chips x 819e9 B/s)
  collective term = per-device collective bytes / 50e9 B/s per ICI link
                    (the dry-run HLO is the partitioned per-device program,
                    so its collective bytes are already per-chip; dividing
                    global bytes by chips — the spec formula — is the same
                    number)

FLOPs/bytes come from the trip-aware jaxpr walker (XLA-CPU cost_analysis
counts scan bodies once — see EXPERIMENTS.md); collective bytes from the
while-aware HLO parser. MODEL_FLOPS reference: 6*N*D for training
(N = active params, D = tokens), 2*N*D for prefill/decode forward.
"""

from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,       # one token per sequence
    "long_500k": 1,
}


@dataclass
class RooflineCell:
    arch: str
    shape: str
    mesh: str
    chips: int
    status: str
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    model_flops: float = 0.0
    hlo_flops: float = 0.0
    useful_ratio: float = 0.0
    pim_frac: float = 0.0        # share of HBM bytes moved by DB-PIM
                                 # Pallas kernels (joint/value/bit paths)
    bottleneck: str = ""
    roofline_fraction: float = 0.0
    temp_gb: float = 0.0
    args_gb: float = 0.0
    reason: str = ""

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def model_flops(rec: dict) -> float:
    n = rec.get("active_params", rec.get("params", 0))
    tokens = SHAPE_TOKENS[rec["shape"]]
    if rec["shape"] == "train_4k":
        return 6.0 * n * tokens
    return 2.0 * n * tokens


def analyze_record(rec: dict) -> RooflineCell:
    chips = 1
    for v in rec.get("mesh_shape", {}).values():
        chips *= v
    cell = RooflineCell(arch=rec["arch"], shape=rec["shape"],
                        mesh=rec["mesh"], chips=chips,
                        status=rec["status"],
                        reason=rec.get("reason", ""))
    if rec["status"] != "ok":
        return cell
    jc = rec.get("jaxpr_cost", {})
    cell.hlo_flops = float(jc.get("dot_flops", 0.0))
    total_flops = float(jc.get("flops", cell.hlo_flops))
    bytes_ = float(jc.get("bytes", 0.0)) + float(jc.get("arg_bytes", 0.0))
    coll = float(rec.get("collectives", {}).get("total", 0.0))

    cell.pim_frac = (float(jc.get("pallas_bytes", 0.0)) / bytes_
                     if bytes_ else 0.0)
    cell.compute_s = total_flops / (chips * PEAK_FLOPS)
    cell.memory_s = bytes_ / (chips * HBM_BW)
    cell.collective_s = coll / ICI_BW
    cell.model_flops = model_flops(rec)
    cell.useful_ratio = (cell.model_flops / cell.hlo_flops
                         if cell.hlo_flops else 0.0)
    terms = {"compute": cell.compute_s, "memory": cell.memory_s,
             "collective": cell.collective_s}
    cell.bottleneck = max(terms, key=terms.get)
    # roofline fraction: useful-model-FLOPs rate achievable at the
    # bottleneck-imposed step time vs the chips' peak.
    if cell.step_s > 0:
        cell.roofline_fraction = (cell.model_flops / cell.step_s
                                  / (chips * PEAK_FLOPS))
    mem = rec.get("memory_analysis", {})
    cell.temp_gb = mem.get("temp_size_in_bytes", 0) / 1e9
    cell.args_gb = mem.get("argument_size_in_bytes", 0) / 1e9
    return cell


def load_cells(dryrun_dir: str,
               include_variants: bool = False) -> List[RooflineCell]:
    cells = []
    for path in sorted(Path(dryrun_dir).glob("*.json")):
        rec = json.loads(path.read_text())
        if rec.get("variant") and not include_variants:
            continue
        cells.append(analyze_record(rec))
    return cells


def format_table(cells: List[RooflineCell], mesh: str = "single") -> str:
    hdr = (f"{'arch':<16}{'shape':<13}{'comp_ms':>9}{'mem_ms':>9}"
           f"{'coll_ms':>9}{'bound':>6}{'MF/HF':>7}{'roofline%':>10}"
           f"{'temp_GB':>9}{'pim%':>6}")
    lines = [hdr, "-" * len(hdr)]
    for c in cells:
        if c.mesh != mesh:
            continue
        if c.status != "ok":
            lines.append(f"{c.arch:<16}{c.shape:<13}{'SKIP':>9} "
                         f"({c.reason[:60]})")
            continue
        lines.append(
            f"{c.arch:<16}{c.shape:<13}{c.compute_s*1e3:>9.2f}"
            f"{c.memory_s*1e3:>9.2f}{c.collective_s*1e3:>9.2f}"
            f"{c.bottleneck[:4]:>6}{c.useful_ratio:>7.2f}"
            f"{c.roofline_fraction*100:>10.1f}{c.temp_gb:>9.1f}"
            f"{c.pim_frac*100:>6.1f}")
    return "\n".join(lines)
