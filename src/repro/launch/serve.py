"""Serving CLI — a thin shell over serving.engine.ServeEngine.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --reduced --dbpim-mode joint --prefill-chunk 16

The engine runs an admission queue over a static batch of ``--batch``
cache slots (QUEUED -> PREFILLING -> DECODING -> DONE), with chunked
cache-filling prefill interleaved between decode steps. ``--paged``
switches the KV cache from per-slot worst-case strips to a shared pool
of fixed-size pages (``--n-pages`` x ``--page-size`` tokens): slots
borrow pages as their sequences grow, admission is gated on free pages,
and under oversubscription the youngest request is preempted (pages
released, later re-admitted head-of-line and resumed bitwise from its
journaled record). Decode outputs are bitwise identical to the
contiguous cache; SSM/conv states stay slot-resident. A new request's
prompt advances ``--prefill-chunk`` tokens per device call while
in-flight requests keep emitting a token every tick. All steps are
fixed-shape and compiled once — no recompilation per request.

``--dbpim-mode joint`` packs every layer's projections into the
uniform-MAXB joint-sparse stacked layout once at startup and threads
them through BOTH the decode scan and the prefill chunks — the whole
network serves off the DB-PIM kernel ((1 - value_sparsity) * 0.5 of
dense bf16 weight traffic). ``--dbpim-mode value`` serves the bf16-
payload variant of the same layout ((1 - vs), value level only).

SSM prefill chunks default to the parallel SSD form — one read of the
stacked in/out projections per chunk instead of per token
(models.ssm.prefill_ssm_parallel; tolerance-equivalent to decode) —
``--prefill-exact`` restores the bit-identical per-token recurrence.
``--schedule spf`` admits shortest-prompt-first (starvation bounded by
``--spf-age-cap``) instead of FIFO.

Load is a deterministic trace (serving.workload): Poisson arrivals at
``--arrival-rate`` requests/tick, prompt lengths from ``--prompt-len LO
HI`` under ``--dist``, fixed ``--seed`` — no wall-clock in the trace.

Fault tolerance / SLO (serving.faults, serving.engine): ``--fault-rate
R`` injects a seeded fault schedule (step exceptions, NaN logits,
corrupted slot caches) — faulted slots quarantine and recover by
replaying their durable record, bitwise on exact prefill paths.
``--deadline-slack K`` gives every request the SLO ``arrival + K``
ticks; requests that can no longer meet it are shed (recorded, never
raised), and ``--queue-cap`` bounds the admission queue with explicit
load-shedding. ``--strict-admission`` restores the hard ValueError on
oversized requests instead of a recorded rejection.

Observability: ``--trace-out trace.jsonl`` records the full two-clock
span/event stream (repro.obs.Tracer) plus the per-call-kind weight
waterfall and dumps it as JSONL — render with ``python -m
repro.launch.report trace.jsonl`` or convert for Perfetto with
``--chrome``. Tracing is passive: outputs and device-call count are
bitwise identical to an untraced run.

Durability (serving.journal, serving.snapshot): ``--journal PATH``
appends a CRC-framed write-ahead record of every request transition
(fsync'd once per tick); ``--snapshot-dir DIR --snapshot-every N``
writes an atomic engine snapshot every N ticks. After a crash,
``--restore`` (with the same journal/snapshot flags) rebuilds the
engine from the latest snapshot + journal tail and resumes every
stream bitwise where the dead process left off (``--prefill-exact``
required for bitwise SSM restarts). Both layers are passive: with
them on, outputs and device-call count are identical to a bare run.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.models.transformer import encode
from repro.serving import ServeEngine, WorkloadSpec, make_trace


def _spec_from(args) -> WorkloadSpec:
    return WorkloadSpec(n_requests=args.requests,
                        arrival_rate=args.arrival_rate,
                        prompt_len=tuple(args.prompt_len),
                        gen_len=(args.gen_len, args.gen_len),
                        dist=args.dist,
                        gen_dist=getattr(args, "gen_dist", "uniform"),
                        seed=args.seed,
                        deadline_slack=getattr(args, "deadline_slack",
                                               None))


def build_engine_and_trace(args, cfg):
    """Shared by the CLI and benchmarks: engine + trace from parsed args."""
    params = init_params(cfg, jax.random.PRNGKey(args.seed))

    stacked_tables = None
    if cfg.dbpim and cfg.dbpim_mode != "dense":
        from repro.sparsity.sparse_linear import (build_stacked_tables,
                                                  strip_packed_projections)
        stacked_tables = build_stacked_tables(
            params, cfg, value_sparsity=args.value_sparsity)
        if stacked_tables is None:
            print(f"[serve] {cfg.name}: no stacked path for this "
                  f"family/mode; serving dense")
        else:
            # the packed tables now serve these matmuls — drop the dense
            # copies so serving HBM shrinks instead of doubling
            params = strip_packed_projections(params, cfg)
            nbytes = sum(int(a.size * a.dtype.itemsize)
                         for t in stacked_tables.arrays.values()
                         for a in t.values())
            print(f"[serve] dbpim_mode={cfg.dbpim_mode}: "
                  f"{len(stacked_tables.arrays)} projection families "
                  f"packed, {nbytes/1e6:.2f} MB stacked tables "
                  f"(dense copies stripped)")

    enc_out = None
    if cfg.is_encdec:
        rng = np.random.default_rng(args.seed)
        frames = jnp.asarray(rng.normal(
            0, 1, (args.batch, cfg.encoder_seq, cfg.d_model)), jnp.bfloat16)
        enc_out = encode(params, frames, cfg)

    fault_plan = None
    if getattr(args, "fault_rate", 0.0) > 0:
        from repro.serving import FaultPlan
        fault_plan = FaultPlan.generate(
            seed=args.fault_seed, n_ticks=args.fault_ticks,
            rate=args.fault_rate, n_slots=args.batch)
        print(f"[serve] fault plan: {len(fault_plan.events)} events over "
              f"{args.fault_ticks} ticks (seed={args.fault_seed}, "
              f"rate={args.fault_rate})")

    tracer = None
    if getattr(args, "trace_out", None):
        from repro.obs import Tracer
        # path= makes EngineStuckError dump the trace pre-raise, so a
        # wedged run is diagnosable after the process is gone
        tracer = Tracer(arch=cfg.name, meta={
            "n_slots": args.batch, "prefill_chunk": args.prefill_chunk,
            "schedule": args.schedule, "seed": args.seed},
            path=args.trace_out)

    if getattr(args, "restore", False):
        if not getattr(args, "snapshot_dir", None):
            raise SystemExit("--restore requires --snapshot-dir")
        engine = ServeEngine.restore(
            cfg, params, snapshot_dir=args.snapshot_dir,
            journal_path=getattr(args, "journal", None),
            stacked_tables=stacked_tables, enc_out=enc_out,
            fault_plan=fault_plan, tracer=tracer)
        print(f"[serve] restored from snapshot step "
              f"{engine.restore_stats['from_step']}: "
              f"{engine.restore_stats}")
        return engine, make_trace(
            _spec_from(args), cfg.vocab_size)

    engine = ServeEngine(cfg, params, n_slots=args.batch,
                         max_len=args.max_len,
                         prefill_chunk=args.prefill_chunk,
                         prefill_mode=args.prefill_mode,
                         schedule=args.schedule,
                         spf_age_cap=args.spf_age_cap,
                         stacked_tables=stacked_tables, enc_out=enc_out,
                         strict=getattr(args, "strict_admission", False),
                         queue_cap=getattr(args, "queue_cap", None),
                         fault_plan=fault_plan,
                         max_step_retries=getattr(args, "max_step_retries",
                                                  2),
                         max_replays=getattr(args, "max_replays", 3),
                         tracer=tracer,
                         paged=getattr(args, "paged", False),
                         page_size=getattr(args, "page_size", 16),
                         n_pages=getattr(args, "n_pages", None),
                         journal=getattr(args, "journal", None),
                         snapshot_dir=getattr(args, "snapshot_dir", None),
                         snapshot_every=getattr(args, "snapshot_every", 0),
                         snapshot_keep=getattr(args, "snapshot_keep", 2))
    return engine, make_trace(_spec_from(args), cfg.vocab_size)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="engine slots (static decode batch)")
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="prompt tokens per chunked-prefill device call")
    ap.add_argument("--prefill-mode", default="chunked",
                    choices=["chunked", "full"],
                    help="'full' = token-by-token baseline prefill")
    ap.add_argument("--prefill-exact", action="store_true",
                    help="SSM chunks: force the exact per-token recurrence "
                         "(bit-identical to decode, C x the projection "
                         "traffic) instead of the default parallel SSD "
                         "form (one stacked-weight read per chunk, "
                         "tolerance-equivalent)")
    ap.add_argument("--schedule", default="fifo", choices=["fifo", "spf"],
                    help="admission order: fifo, or shortest-prompt-first "
                         "(spf; starvation bounded by --spf-age-cap)")
    ap.add_argument("--spf-age-cap", type=int, default=8,
                    help="spf: max times a request may be queue-jumped "
                         "before it becomes urgent")
    ap.add_argument("--prompt-len", type=int, nargs=2, default=[4, 24],
                    metavar=("LO", "HI"))
    ap.add_argument("--deadline-slack", type=float, default=None,
                    help="SLO: every request must complete within this "
                         "many ticks of its arrival or be shed (recorded "
                         "in metrics, never raised); default: no SLO")
    ap.add_argument("--queue-cap", type=int, default=None,
                    help="bounded admission queue: submissions beyond the "
                         "cap are rejected (recorded load-shedding)")
    ap.add_argument("--strict-admission", action="store_true",
                    help="raise ValueError on oversized requests instead "
                         "of recording a rejection")
    ap.add_argument("--fault-rate", type=float, default=0.0,
                    help="inject a deterministic fault schedule: per-tick "
                         "probability of one fault (step exception, NaN "
                         "logits, or corrupted slot cache); faulted slots "
                         "quarantine and recover by replay")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed of the injected fault schedule")
    ap.add_argument("--fault-ticks", type=int, default=1000,
                    help="horizon (ticks) the fault schedule covers")
    ap.add_argument("--max-step-retries", type=int, default=2,
                    help="bounded retry of a failed device call before "
                         "every participating slot quarantines")
    ap.add_argument("--max-replays", type=int, default=3,
                    help="per-request fault budget: past it the request "
                         "is shed instead of replayed again")
    ap.add_argument("--arrival-rate", type=float, default=0.5,
                    help="Poisson arrivals per engine tick (0 = all at t0)")
    ap.add_argument("--dist", default="uniform",
                    choices=["uniform", "bimodal", "fixed", "lognormal",
                             "zipf"],
                    help="prompt-length distribution; lognormal/zipf give "
                         "the long-tail mixes that make paged pools win")
    ap.add_argument("--gen-dist", default="uniform",
                    choices=["uniform", "bimodal", "fixed", "lognormal",
                             "zipf"],
                    help="generation-length distribution over --gen-len")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache + continuous batching: slots "
                         "borrow fixed-size pages from a shared pool "
                         "(admission gated on free pages, decode bitwise "
                         "the contiguous path)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (must divide --max-len)")
    ap.add_argument("--n-pages", type=int, default=None,
                    help="pool size in pages; < batch * max_len/page_size "
                         "oversubscribes (page pressure preempts the "
                         "youngest request, bitwise resume later); "
                         "default: full static capacity")
    ap.add_argument("--dbpim-mode", default=None,
                    choices=["dense", "value", "bit", "joint"],
                    help="serve through the DB-PIM kernel path (joint = "
                         "value x bit sparse, the paper's headline config)")
    ap.add_argument("--value-sparsity", type=float, default=None,
                    help="tile-granular value sparsity for --dbpim-mode "
                         "joint/value (default: cfg.dbpim_value_sparsity)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="dump the structured two-clock trace (spans, "
                         "events, slot intervals, weight waterfall) as "
                         "JSONL; render with python -m repro.launch.report")
    ap.add_argument("--journal", default=None, metavar="PATH",
                    help="write-ahead request journal (CRC-framed JSONL, "
                         "fsync'd once per tick); with --restore, the "
                         "journal to fold over the snapshot")
    ap.add_argument("--snapshot-dir", default=None, metavar="DIR",
                    help="directory for periodic atomic engine snapshots "
                         "(cache + state machine + queue + metrics)")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="snapshot cadence in ticks (0 = never); bounds "
                         "post-crash redo work to this many tokens per "
                         "active slot")
    ap.add_argument("--snapshot-keep", type=int, default=2,
                    help="published snapshots retained on disk")
    ap.add_argument("--restore", action="store_true",
                    help="warm-restart: rebuild from the latest snapshot "
                         "in --snapshot-dir plus the --journal tail and "
                         "resume every stream bitwise (skips submission "
                         "— the trace is already in the journal)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced,
                     dbpim_mode=args.dbpim_mode,
                     prefill_exact=args.prefill_exact or None)
    engine, trace = build_engine_and_trace(args, cfg)
    if engine.prefill_mode != args.prefill_mode:
        print(f"[serve] {cfg.name}: chunked prefill unsupported for this "
              f"family; falling back to stepwise (full) prefill")
    if engine.prefill_kind is not None:
        print(f"[serve] prefill chunk math: {engine.prefill_kind} "
              f"(schedule={engine.schedule})")

    outputs = engine.resume() if args.restore else engine.run(trace)
    s = engine.metrics.summary()
    print(f"[serve] {s['n_completed']}/{s['n_requests']} requests, "
          f"{s['generated_tokens']} tokens in {s['engine_ticks']} ticks / "
          f"{s['device_calls']} device calls "
          f"({s['decode_calls']} decode + {s['prefill_calls']} prefill)")
    ttft = (f"mean={s['ttft_ticks_mean']:.1f} p95={s['ttft_ticks_p95']}"
            if s["ttft_ticks_mean"] is not None else "n/a")
    print(f"[serve] tokens/step={s['tokens_per_step']:.3f}  "
          f"ttft_ticks {ttft}  queue_depth "
          f"mean={s['queue_depth_mean']:.2f} max={s['queue_depth_max']}")
    if s["tokens_per_sec"]:
        print(f"[serve] wall {s['wall_s']:.2f}s  "
              f"{s['tokens_per_sec']:.1f} tok/s  "
              f"{s['per_token_latency_ms']:.2f} ms/token")
    if s["n_faults"] or s["n_rejected"] or s["n_shed"]:
        print(f"[serve] goodput {s['goodput']:.2f}  faults {s['faults']}  "
              f"retries {s['retries']}  replays {s['replays']}  "
              f"rejected {s['n_rejected']}  shed {s['n_shed']}  "
              f"straggler_ticks {s['straggler_ticks']}")
    if engine.paged:
        pu = (f"{s['pages_used_mean']:.2f}"
              if s["pages_used_mean"] is not None else "n/a")
        print(f"[serve] page pool: {engine.n_pages} x "
              f"{engine.page_size}-token pages  "
              f"used mean={pu} max={s['pages_used_max']}  "
              f"preemptions {s['n_preemptions']}  "
              f"alloc_failures {s['page_alloc_failures']}")
    if s["slot_busy_frac"] is not None:
        print(f"[serve] slot_busy_frac {s['slot_busy_frac']:.2f}  "
              f"per-slot "
              f"{[round(o, 2) for o in s['slot_occupancy']]}")
    for kind, h in s["call_latency_ms"].items():
        print(f"[serve] latency {kind}: p50={h['p50_ms']:.2f} "
              f"p95={h['p95_ms']:.2f} p99={h['p99_ms']:.2f} ms "
              f"({h['count']} calls)")
    if engine.sentinel is not None:
        print(f"[serve] recompile sentinel: {engine.sentinel.counts()}")
    if engine.tracer is not None:
        from repro.obs import engine_waterfall
        for kind, wf in engine_waterfall(engine).items():
            engine.tracer.waterfall(kind, wf["rows"], wf["total"])
        engine.tracer.dump(args.trace_out)
        print(f"[serve] trace: {len(engine.tracer.records)} records -> "
              f"{args.trace_out} (render: python -m repro.launch.report "
              f"{args.trace_out})")
    for rid in sorted(outputs):
        print(f"  req{rid}: {outputs[rid][:8]}...")
    return outputs


if __name__ == "__main__":
    main()
