"""Batched serving driver: continuous-batching decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --reduced --batch 4 --max-len 64 --requests 8

A static decode batch of `batch` slots runs lock-step single-token steps
(the TPU-efficient regime); finished slots (EOS or length budget) are
refilled from the request queue — continuous batching with a fixed-shape
program, no re-compilation per request.

``--dbpim-mode joint`` packs every layer's projections into the
uniform-MAXB joint-sparse stacked layout once at startup and threads
them through the decode scan — the whole network serves off the DB-PIM
kernel ((1 - value_sparsity) * 0.5 of dense bf16 weight traffic).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_test_mesh
from repro.launch.steps import build_serve_step
from repro.models import init_cache, init_params
from repro.models.transformer import encode
from repro.runtime import sharding as shr


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dbpim-mode", default=None,
                    choices=["dense", "value", "bit", "joint"],
                    help="serve through the DB-PIM kernel path (joint = "
                         "value x bit sparse, the paper's headline config)")
    ap.add_argument("--value-sparsity", type=float, default=None,
                    help="tile-granular value sparsity for --dbpim-mode "
                         "joint (default: cfg.dbpim_value_sparsity)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced,
                     dbpim_mode=args.dbpim_mode)
    mesh = make_test_mesh()
    rng = np.random.default_rng(args.seed)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))

    stacked_tables = None
    if cfg.dbpim and cfg.dbpim_mode != "dense":
        from repro.sparsity.sparse_linear import (build_stacked_tables,
                                                  strip_packed_projections)
        stacked_tables = build_stacked_tables(
            params, cfg, value_sparsity=args.value_sparsity)
        if stacked_tables is None:
            print(f"[serve] {args.arch}: no stacked joint path for this "
                  f"family/mode; serving dense")
        else:
            # the packed tables now serve these matmuls — drop the dense
            # copies so serving HBM shrinks instead of doubling
            params = strip_packed_projections(params, cfg)
            nbytes = sum(int(a.size * a.dtype.itemsize)
                         for t in stacked_tables.arrays.values()
                         for a in t.values())
            print(f"[serve] dbpim_mode={cfg.dbpim_mode}: "
                  f"{len(stacked_tables.arrays)} projection families "
                  f"packed, {nbytes/1e6:.2f} MB stacked tables "
                  f"(dense copies stripped)")

    enc_out = None
    if cfg.is_encdec:
        frames = jnp.asarray(rng.normal(
            0, 1, (args.batch, cfg.encoder_seq, cfg.d_model)), jnp.bfloat16)
        enc_out = encode(params, frames, cfg)

    with mesh:
        cache = init_cache(cfg, args.batch, args.max_len, enc_out=enc_out)
        step_fn, shard_fn = build_serve_step(cfg, mesh,
                                             stacked_tables=stacked_tables)
        token0 = jnp.zeros((args.batch, 1), jnp.int32)
        pspec, cspec, tspec = shard_fn(params, cache, token0)
        jitted = jax.jit(step_fn,
                         in_shardings=(shr.named(pspec, mesh),
                                       shr.named(cspec, mesh),
                                       shr.named(tspec, mesh)),
                         donate_argnums=(1,))

        # continuous batching over a fixed-slot decode batch
        pending = list(rng.integers(1, cfg.vocab_size,
                                    (args.requests,)).tolist())
        slots = [None] * args.batch          # (request_id, tokens_so_far)
        outputs = {}
        next_id = 0
        tokens = np.zeros((args.batch, 1), np.int32)
        t0 = time.time()
        steps = 0
        while len(outputs) < args.requests:
            for s in range(args.batch):
                if slots[s] is None and pending:
                    prompt = pending.pop(0)
                    slots[s] = (next_id, [int(prompt)])
                    tokens[s, 0] = prompt
                    next_id += 1
            logits, cache = jitted(params, cache,
                                   jnp.asarray(tokens))
            steps += 1
            nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1))
            for s in range(args.batch):
                if slots[s] is None:
                    continue
                rid, toks = slots[s]
                toks.append(int(nxt[s]))
                tokens[s, 0] = nxt[s]
                if len(toks) >= args.gen_len:
                    outputs[rid] = toks
                    slots[s] = None
        dt = time.time() - t0
    tput = args.requests * args.gen_len / dt
    print(f"[serve] {args.requests} requests x {args.gen_len} tokens in "
          f"{dt:.2f}s ({tput:.1f} tok/s, {steps} decode steps)")
    for rid in sorted(outputs):
        print(f"  req{rid}: {outputs[rid][:8]}...")
    return outputs


if __name__ == "__main__":
    main()
