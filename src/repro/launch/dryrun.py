import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
cell on the production meshes and record memory / cost / collective
statistics for the roofline analysis.

MUST be run as its own process (the XLA_FLAGS line above has to execute
before any other jax import in the interpreter):

    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b \
        --shape train_4k --mesh single

Results land in experiments/dryrun/<arch>.<shape>.<mesh>.json; benchmarks/
roofline_table.py and EXPERIMENTS.md read them.
"""

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (build_prefill_step, build_serve_step,
                                build_train_step)
from repro.models import init_cache, init_params
from repro.models.config import SHAPES, param_count, active_param_count
from repro.models.inputs import decode_token_spec, train_batch_spec
from repro.optim import adamw_init
from repro.runtime import sharding as shr
from repro.runtime import jaxpr_cost
from repro.runtime.hlo_collectives import collective_bytes as hlo_collective_bytes

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

# Grad-accumulation microbatch count per arch for train_4k (activation
# memory control on 16 GB/chip targets).
MICROBATCHES = {
    "arctic-480b": 8, "jamba-v0.1-52b": 8, "mixtral-8x7b": 8,
    "pixtral-12b": 8, "qwen3-8b": 8, "gemma-7b": 8, "tinyllama-1.1b": 8,
    "stablelm-1.6b": 8, "mamba2-1.3b": 8, "whisper-base": 8,
}

COLLECTIVE_RE = re.compile(
    r"(f8e4m3fn|f8e5m2|bf16|f16|f32|f64|u8|u16|u32|u64|s8|s16|s32|s64|pred)"
    r"\[([0-9,]*)\][^ ]* (all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)")

DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
               "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
               "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8}


def collective_bytes(hlo_text: str):
    """Sum result-shape bytes of collective ops in the optimized HLO, per
    collective kind. (Result bytes ~= moved bytes for all-reduce/permute;
    an upper bound for all-gather where the result includes local shards.)"""
    out = {}
    for m in COLLECTIVE_RE.finditer(hlo_text):
        dt, dims, kind = m.group(1), m.group(2), m.group(3)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out[kind] = out.get(kind, 0) + n * DTYPE_BYTES[dt]
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def should_skip(arch: str, shape_name: str, cfg) -> str:
    if shape_name == "long_500k" and not cfg.supports_long_context:
        return ("full-attention arch: O(S^2) prefill / full 500k cache "
                "decode excluded by design (DESIGN.md long_500k table)")
    return ""


def abstract_state(cfg, spec):
    """ShapeDtypeStruct pytrees for params / optimizer / cache: nothing is
    allocated (jax.eval_shape all the way)."""
    params = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.random.PRNGKey(0))
    opt = jax.eval_shape(adamw_init, params)
    return params, opt


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             out_dir: Path = OUT_DIR, verbose: bool = True,
             variant: str = "") -> dict:
    """variant="int8serve": decode cells store projections INT8 in HBM
    (the DB-PIM/FTA serving format) — §Perf hillclimb for weight-bound
    decode."""
    cfg = get_config(arch)
    if variant == "dotsremat":
        cfg = cfg.scaled(remat_policy="dots")
    spec = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "mesh_shape": dict(mesh.shape), "status": "ok", "variant": variant,
        "params": param_count(cfg), "active_params": active_param_count(cfg),
    }
    skip = should_skip(arch, shape_name, cfg)
    if skip:
        rec["status"] = "skip"
        rec["reason"] = skip
        _write(rec, out_dir)
        return rec

    t0 = time.time()
    try:
        params_abs, opt_abs = abstract_state(cfg, spec)
        with mesh:
            if spec.kind == "train":
                mb = MICROBATCHES.get(arch, 1) if shape_name == "train_4k" else 1
                step, shard_fn = build_train_step(cfg, mesh, microbatches=mb)
                batch_abs = train_batch_spec(cfg, spec.global_batch,
                                             spec.seq_len)
                pspec, ospec, bspec = shard_fn(params_abs, opt_abs, batch_abs)
                jitted = jax.jit(
                    step,
                    in_shardings=(shr.named(pspec, mesh),
                                  shr.named(ospec, mesh),
                                  shr.named(bspec, mesh)),
                    donate_argnums=(0, 1))
                lowered = jitted.lower(params_abs, opt_abs, batch_abs)
                rec["microbatches"] = mb
                rec["jaxpr_cost"] = jaxpr_cost.analyze(
                    step, params_abs, opt_abs, batch_abs)
            elif spec.kind == "prefill":
                step, shard_fn = build_prefill_step(cfg, mesh)
                batch_abs = train_batch_spec(cfg, spec.global_batch,
                                             spec.seq_len)
                batch_abs.pop("labels")
                pspec, bspec = shard_fn(params_abs, batch_abs)
                jitted = jax.jit(step,
                                 in_shardings=(shr.named(pspec, mesh),
                                               shr.named(bspec, mesh)))
                lowered = jitted.lower(params_abs, batch_abs)
                rec["jaxpr_cost"] = jaxpr_cost.analyze(
                    step, params_abs, batch_abs)
            else:  # decode
                step, shard_fn = build_serve_step(
                    cfg, mesh, int8_weights=(variant == "int8serve"))
                if variant == "int8serve":
                    from repro.sparsity.sparse_linear import \
                        quantize_params_for_serving
                    params_abs = jax.eval_shape(quantize_params_for_serving,
                                                params_abs)
                enc_abs = None
                if cfg.is_encdec:
                    enc_abs = jax.ShapeDtypeStruct(
                        (spec.global_batch, cfg.encoder_seq, cfg.d_model),
                        jnp.bfloat16)
                cache_abs = jax.eval_shape(
                    lambda: init_cache(cfg, spec.global_batch, spec.seq_len,
                                       enc_out=enc_abs))
                token_abs = jax.ShapeDtypeStruct((spec.global_batch, 1),
                                                 jnp.int32)
                pspec, cspec, tspec = shard_fn(params_abs, cache_abs,
                                               token_abs)
                jitted = jax.jit(step,
                                 in_shardings=(shr.named(pspec, mesh),
                                               shr.named(cspec, mesh),
                                               shr.named(tspec, mesh)),
                                 donate_argnums=(1,))
                lowered = jitted.lower(params_abs, cache_abs, token_abs)
                rec["jaxpr_cost"] = jaxpr_cost.analyze(
                    step, params_abs, cache_abs, token_abs)

            rec["lower_s"] = round(time.time() - t0, 1)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 1)

            mem = compiled.memory_analysis()
            rec["memory_analysis"] = {
                k: int(getattr(mem, k)) for k in
                ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(mem, k)}
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0] if ca else {}
            rec["cost_analysis"] = {
                k: float(v) for k, v in dict(ca or {}).items()
                if isinstance(v, (int, float)) and (
                    k in ("flops", "bytes accessed", "transcendentals")
                    or k.startswith("bytes accessed"))}
            hlo = compiled.as_text()
            rec["collectives_once"] = collective_bytes(hlo)
            rec["collectives"] = hlo_collective_bytes(hlo)
            rec["hlo_bytes"] = len(hlo)
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 1)
    _write(rec, out_dir)
    if verbose:
        msg = rec["status"]
        if rec["status"] == "ok":
            flops = rec.get("jaxpr_cost", {}).get("dot_flops", 0)
            msg += (f" flops={flops:.3e} "
                    f"coll={rec['collectives'].get('total', 0):.3e}B "
                    f"compile={rec.get('compile_s')}s")
        print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}: {msg}",
              flush=True)
    return rec


def _write(rec, out_dir: Path):
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = f".{rec['variant']}" if rec.get("variant") else ""
    path = out_dir / f"{rec['arch']}.{rec['shape']}.{rec['mesh']}{suffix}.json"
    slim = {k: v for k, v in rec.items() if k != "traceback"}
    path.write_text(json.dumps(slim, indent=1))
    if "traceback" in rec:
        (out_dir / (path.stem + ".err.txt")).write_text(rec["traceback"])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default=None, choices=[None, "single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--variant", default="")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [args.mesh] if args.mesh else ["single", "multi"]

    n_ok = n_err = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                path = OUT_DIR / f"{arch}.{shape}.{mesh_kind}.json"
                if args.skip_existing and path.exists():
                    prev = json.loads(path.read_text())
                    if prev.get("status") in ("ok", "skip"):
                        continue
                rec = run_cell(arch, shape, mesh_kind,
                               variant=args.variant)
                if rec["status"] == "error":
                    n_err += 1
                else:
                    n_ok += 1
    print(f"[dryrun] done: {n_ok} ok/skip, {n_err} errors", flush=True)
    raise SystemExit(1 if n_err else 0)


if __name__ == "__main__":
    main()
