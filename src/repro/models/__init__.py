"""Unified model zoo for the 10 assigned architectures."""

from .config import ModelConfig, ShapeSpec, SHAPES, param_count  # noqa: F401
from .transformer import init_params, forward, loss_fn, encode  # noqa: F401
from .decode import (decode_chunk, decode_step, init_cache, merge_slots,
                     prefill, reset_slots)  # noqa: F401
