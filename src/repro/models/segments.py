"""Segment-descriptor API: the layer stack as a list of per-kind segments.

A **segment** is a contiguous run of same-kind layers (same mixer, same
FFN flavor, same cross-attention presence). Every family's decoder is an
ordered tuple of segments, each executed as its OWN `lax.scan` over its
own stacked params / cache slices / packed-table xs:

  * dense / MoE / VLM  -> 1 segment  ("blocks":   attn + mlp-or-moe)
  * SSM (mamba2)       -> 1 segment  ("blocks":   ssm, no FFN)
  * enc-dec (whisper)  -> 1 segment  ("blocks":   attn + cross + mlp)
  * hybrid (jamba)     -> N segments ("seg00"...: the attn_period /
                          attn_index / moe_every sublayer pattern,
                          run-length-encoded into same-kind runs)

This is what converts family support from an enumerated matrix into a
compositional property: `build_stacked_tables` packs each segment
independently (its own shared MAXB), and the forward/decode/prefill
loops in models.transformer / models.decode iterate segments instead of
switching on cfg.family — ANY composition of attention / SSM / MoE /
cross-attention sublayers serves through the joint-sparse Pallas path.

`ServingCapabilities` (returned by ModelConfig.serving_capabilities())
is the single source of truth the old boolean properties
(`supports_stacked_tables` / `supports_chunked_prefill` /
`supports_parallel_prefill`) now delegate to as thin deprecated shims.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Tuple

if TYPE_CHECKING:  # pragma: no cover - typing only, avoids import cycle
    from .config import ModelConfig


@dataclass(frozen=True)
class Segment:
    """One contiguous run of same-kind layers.

    name:   key of the stacked param subtree (params[name]) and, for
            multi-segment stacks, of the cache subtree.
    mixer:  "attn" | "ssm" — the sequence-mixing sublayer.
    length: number of layers in the run (leading axis of the stacked
            params / cache slices).
    ffn:    "mlp" | "moe" | "none" — the feed-forward sublayer.
    cross:  cross-attention sublayer between mixer and FFN (whisper
            decoder).
    cache:  key of the cache subtree. Single-segment stacks keep the
            historical "attn"/"ssm" keys so existing cache consumers
            (sharding rules, serving engine, tests) see the same tree;
            multi-segment stacks use the segment name.
    """
    name: str
    mixer: str
    length: int
    ffn: str = "none"
    cross: bool = False
    cache: str = "attn"


def _layer_kinds(cfg: "ModelConfig"):
    """(mixer, ffn, cross) per decoder layer, in stack order."""
    if cfg.family == "ssm":
        return [("ssm", "none", False)] * cfg.n_layers
    if cfg.family == "hybrid":
        kinds = []
        for i in range(cfg.n_layers):
            j = i % cfg.attn_period
            mixer = "attn" if j == cfg.attn_index else "ssm"
            ffn = ("moe" if cfg.n_experts
                   and j % cfg.moe_every == cfg.moe_every - 1 else "mlp")
            kinds.append((mixer, ffn, False))
        return kinds
    ffn = "moe" if cfg.n_experts else "mlp"
    return [("attn", ffn, cfg.is_encdec)] * cfg.n_layers


def decoder_layout(cfg: "ModelConfig") -> Tuple[Segment, ...]:
    """Run-length-encode the decoder's layer kinds into segments."""
    kinds = _layer_kinds(cfg)
    runs = []
    for kind in kinds:
        if runs and runs[-1][0] == kind:
            runs[-1][1] += 1
        else:
            runs.append([kind, 1])
    if len(runs) == 1:
        (mixer, ffn, cross), n = runs[0]
        return (Segment("blocks", mixer, n, ffn, cross,
                        cache="ssm" if mixer == "ssm" else "attn"),)
    segs = []
    for i, ((mixer, ffn, cross), n) in enumerate(runs):
        name = f"seg{i:02d}"
        segs.append(Segment(name, mixer, n, ffn, cross, cache=name))
    return tuple(segs)


def encoder_layout(cfg: "ModelConfig") -> Tuple[Segment, ...]:
    """Whisper encoder: one homogeneous non-causal attention segment.
    (The encoder runs once per request, not per decoded token, so it is
    not packed for serving — decode-step weight traffic never reads it.)
    """
    if not cfg.is_encdec:
        return ()
    return (Segment("enc_blocks", "attn", cfg.encoder_layers, "mlp",
                    cross=False, cache="enc"),)


def packable_projections(seg: Segment, cfg: "ModelConfig"):
    """dense_fn hook names of the projections a segment's stacked tables
    pack, in pack order. These are the `name` strings the model bodies
    pass to the hook (attention "wq".."wo", cross-attention
    "xattn/wq".."xattn/wo", MLP "w_gate"/"w_up"/"w_down", MoE experts
    "moe/*" — bare MLP names inside a MoE segment are the arctic dense
    residual). Routers/norms stay dense (tiny, accuracy-critical — same
    reasoning as the paper's dw-conv exclusion)."""
    names = []
    if seg.mixer == "attn":
        names += ["wq", "wk", "wv", "wo"]
        if seg.cross:
            names += ["xattn/wq", "xattn/wk", "xattn/wv", "xattn/wo"]
    else:
        names += ["in_proj", "out_proj"]
    mlp_names = (["w_gate", "w_up", "w_down"]
                 if cfg.mlp_type in ("swiglu", "geglu")
                 else ["w_up", "w_down"])
    if seg.ffn == "moe":
        names += [f"moe/{n}" for n in mlp_names]
        if cfg.dense_residual:
            names += mlp_names
    elif seg.ffn == "mlp":
        names += mlp_names
    return names


def projection_param_path(seg: Segment, name: str) -> str:
    """Full '/'-joined param-tree path of a packable projection (the
    exact-path key strip_packed_projections / reconstruct_stacked_params
    match on — exact paths, so a whisper decoder pack never touches the
    dense encoder's identically-suffixed copies)."""
    if name in ("wq", "wk", "wv", "wo"):
        return f"{seg.name}/attn/{name}"
    if name.startswith("xattn/") or name.startswith("moe/"):
        return f"{seg.name}/{name}"
    if name in ("in_proj", "out_proj"):
        return f"{seg.name}/ssm/{name}"
    # bare MLP names: the plain MLP sublayer, or the dense residual MLP
    # riding next to the experts (arctic)
    if seg.ffn == "moe":
        return f"{seg.name}/moe/dense_mlp/{name}"
    return f"{seg.name}/mlp/{name}"


@dataclass(frozen=True)
class ServingCapabilities:
    """What the serving stack can do for one config — the single source
    of truth behind the deprecated ModelConfig.supports_* shims.

    segments:         decoder segment layout (stack order).
    stacked_tables:   joint-sparse stacked packs can ride every decoder
                      scan (True for every family since the segmented
                      refactor closed the matrix).
    chunked_prefill:  decode_chunk reproduces sequential decode — needs
                      full causal attention (a sliding-window ring
                      buffer overwrites slots within a chunk).
    parallel_prefill: at least one SSM segment can use the parallel SSD
                      chunk form (one stacked-weight read per chunk).
    prefill_modes:    serving.prefill policies available to the engine.
    packable:         "segment/hook" ids of every packable projection.
    """
    segments: Tuple[Segment, ...]
    stacked_tables: bool
    chunked_prefill: bool
    parallel_prefill: bool
    prefill_modes: Tuple[str, ...]
    packable: Tuple[str, ...]


def serving_capabilities(cfg: "ModelConfig") -> ServingCapabilities:
    segs = decoder_layout(cfg)
    chunked = cfg.window == 0
    parallel = chunked and any(s.mixer == "ssm" for s in segs)
    packable = tuple(f"{s.name}/{n}" for s in segs
                     for n in packable_projections(s, cfg))
    return ServingCapabilities(
        segments=segs,
        stacked_tables=True,
        chunked_prefill=chunked,
        parallel_prefill=parallel,
        prefill_modes=("chunked", "full") if chunked else ("full",),
        packable=packable)
