"""Unified model builder: decoder LMs (dense/MoE/VLM), SSM (Mamba2),
hybrid (Jamba), encoder-decoder (Whisper).

Layer stacks are parameter-stacked (leading layer axis) and executed with
`lax.scan` so the HLO stays O(1) in depth — essential for compiling 480B
configs on a 1-core container. `jax.vmap(init_block)` over split keys
creates the stacked params; under `jax.eval_shape` this allocates nothing.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from .config import ModelConfig
from .layers import (apply_mlp, apply_norm, cross_entropy, dtype_of,
                     embed_tokens, init_embeddings, init_mlp, init_norm,
                     logits_from_hidden)
from .segments import Segment, decoder_layout, encoder_layout


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------

def _init_block(cfg: ModelConfig, key, seg: Segment):
    """One layer of a segment: norm + mixer, optional cross-attention,
    optional FFN — the kind is the segment descriptor, not cfg.family."""
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"norm1": init_norm(cfg, cfg.d_model)}
    if seg.mixer == "attn":
        p["attn"] = attn_mod.init_attention(cfg, k1)
    else:
        p["ssm"] = ssm_mod.init_ssm(cfg, k1)
    if seg.cross:
        p["norm_x"] = init_norm(cfg, cfg.d_model)
        p["xattn"] = attn_mod.init_attention(cfg, k3)
    if seg.ffn == "moe":
        p["norm2"] = init_norm(cfg, cfg.d_model)
        p["moe"] = moe_mod.init_moe_block(cfg, k2)
    elif seg.ffn == "mlp":
        p["norm2"] = init_norm(cfg, cfg.d_model)
        p["mlp"] = init_mlp(cfg, k2, cfg.d_model, cfg.d_ff)
    return p


def _stacked(init_fn, n: int, key):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def init_params(cfg: ModelConfig, key) -> Dict:
    ke, kb, kenc = jax.random.split(key, 3)
    params = {"embed": init_embeddings(cfg, ke),
              "final_norm": init_norm(cfg, cfg.d_model)}
    segs = decoder_layout(cfg)
    for seg, sk in zip(segs, jax.random.split(kb, len(segs))):
        params[seg.name] = _stacked(lambda k, s=seg: _init_block(cfg, k, s),
                                    seg.length, sk)
    if cfg.is_encdec:
        enc_seg = encoder_layout(cfg)[0]
        params["enc_blocks"] = _stacked(
            lambda k: _init_block(cfg, k, enc_seg), enc_seg.length, kenc)
        params["enc_final_norm"] = init_norm(cfg, cfg.d_model)
    if cfg.frontend == "vision_stub":
        # projection of precomputed patch embeddings into the LM stream
        params["patch_proj"] = (jax.random.normal(
            jax.random.fold_in(key, 9), (cfg.d_model, cfg.d_model))
            * cfg.d_model ** -0.5).astype(dtype_of(cfg))
    return params


# ---------------------------------------------------------------------------
# Forward passes (training / prefill)
# ---------------------------------------------------------------------------

def _sinusoidal(S: int, d: int, dtype):
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (dim / d))
    pe = jnp.zeros((S, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang)).at[:, 1::2].set(jnp.cos(ang))
    return pe.astype(dtype)


def _block_tail(seg: Segment, p, h, cfg: ModelConfig, mm=None,
                enc_out=None, per_position: bool = False):
    """The sublayers after the mixer, shared by every execution mode
    (train forward / decode step / prefill chunk): optional
    cross-attention over the encoder output, then the FFN. per_position
    groups MoE capacity dispatch by chunk position (prefill chunks) so
    each position's token pool competes exactly like one decode step."""
    if seg.cross:
        hx = apply_norm(p["norm_x"], h, cfg)
        h = h + attn_mod.cross_attention(p["xattn"], hx, enc_out, cfg,
                                         dense_fn=mm)
    if seg.ffn == "moe":
        y, _aux = moe_mod.apply_moe_block(
            p["moe"], apply_norm(p["norm2"], h, cfg), cfg, dense_fn=mm,
            per_position=per_position)
        h = h + y
    elif seg.ffn == "mlp":
        h = h + apply_mlp(p["mlp"], apply_norm(p["norm2"], h, cfg), cfg,
                          dense_fn=mm)
    return h


def segment_tables(tables, segs, cfg: ModelConfig):
    """Per-segment table lookup for a segment layout. Returns {} for
    dense serving; raises when the tables were packed for a different
    segment layout (e.g. a single-"blocks" pack handed to a hybrid
    stack) — a shape mismatch would otherwise surface as a cryptic scan
    error deep inside the kernel."""
    if tables is None:
        return {}
    seg_map = getattr(tables, "segments", None)
    if seg_map is None:
        raise ValueError("stacked tables must be a segmented pack "
                         "(sparsity.sparse_linear.build_stacked_tables)")
    missing = [s.name for s in segs if s.name not in seg_map]
    if missing:
        raise ValueError(f"stacked tables do not match {cfg.name}'s "
                         f"segment layout: missing segments {missing} "
                         f"(packed: {sorted(seg_map)})")
    return seg_map


def _scan_stack(blocks, x, body, remat: bool, policy: str = "full",
                tables=None):
    """Scan the stacked layer params through `body(layer_params, h, mm)`.

    `tables` (sparsity.sparse_linear.StackedKernelTables) rides the scan
    as extra xs: each step receives its layer's slice of the uniform-MAXB
    packed weights and rebuilds the dense_fn hook, so the joint DB-PIM
    kernel serves EVERY layer while the HLO stays O(1) in depth. mm is
    None on the plain (dense) path.
    """
    def wrapped(layer_params, carry, slices):
        mm = tables.dense_fn(slices) if tables is not None else None
        return body(layer_params, carry, mm)
    if remat and policy == "dots":
        fn = jax.checkpoint(
            wrapped,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    elif remat:
        fn = jax.checkpoint(wrapped)
    else:
        fn = wrapped
    xs = (blocks, tables.arrays if tables is not None else None)
    def step(carry, inp):
        layer_params, slices = inp
        return fn(layer_params, carry, slices), None
    out, _ = jax.lax.scan(step, x, xs)
    return out


def encode(params, frames, cfg: ModelConfig):
    """Whisper encoder over stub frame embeddings (B, Se, D)."""
    x = frames + _sinusoidal(frames.shape[1], cfg.d_model, frames.dtype)

    def body(p, h, mm):
        hn = apply_norm(p["norm1"], h, cfg)
        h = h + attn_mod.attention(p["attn"], hn, cfg,
                                   jnp.zeros(h.shape[:2], jnp.int32),
                                   causal=False)
        return h + apply_mlp(p["mlp"], apply_norm(p["norm2"], h, cfg), cfg)

    x = _scan_stack(params["enc_blocks"], x, body, cfg.remat,
                    cfg.remat_policy)
    return apply_norm(params["enc_final_norm"], x, cfg)


def forward(params, tokens, cfg: ModelConfig,
            frontend_embeds: Optional[jnp.ndarray] = None,
            enc_out: Optional[jnp.ndarray] = None,
            last_only: bool = False,
            tables=None):
    """Full-sequence forward to logits.

    frontend_embeds: VLM patch embeddings (B, n_patches, D) prepended to
    the token stream (pixtral) — logits are returned for token positions
    only. enc_out: whisper encoder output for cross-attention.
    last_only: unembed only the final position (prefill) — at 150k vocab,
    unembedding all 32k positions would dominate prefill compute/memory.
    tables: sparsity.sparse_linear.SegmentedKernelTables — per-segment
    uniform-MAXB joint-sparse projections that ride each segment's scan
    as xs, so the DB-PIM kernel serves every layer of every family (MoE
    expert stacks dispatch per packed expert slice; hybrid segments and
    enc-dec cross-attention pack too).
    """
    B, S = tokens.shape
    x = embed_tokens(params["embed"], tokens, cfg)
    n_front = 0
    if cfg.frontend == "vision_stub" and frontend_embeds is not None:
        fe = frontend_embeds @ params["patch_proj"]
        x = jnp.concatenate([fe.astype(x.dtype), x], axis=1)
        n_front = frontend_embeds.shape[1]
    if cfg.rope_pct == 0:
        x = x + _sinusoidal(x.shape[1], cfg.d_model, x.dtype)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32),
                                 (B, x.shape[1]))

    segs = decoder_layout(cfg)
    seg_tables = segment_tables(tables, segs, cfg)
    for seg in segs:
        def body(p, h, mm, seg=seg):
            hn = apply_norm(p["norm1"], h, cfg)
            if seg.mixer == "attn":
                h = h + attn_mod.attention(p["attn"], hn, cfg, positions,
                                           dense_fn=mm)
            else:
                h = h + ssm_mod.apply_ssm(p["ssm"], hn, cfg, dense_fn=mm)
            return _block_tail(seg, p, h, cfg, mm, enc_out)
        x = _scan_stack(params[seg.name], x, body, cfg.remat,
                        cfg.remat_policy, tables=seg_tables.get(seg.name))

    x = apply_norm(params["final_norm"], x, cfg)
    if n_front:
        x = x[:, n_front:]
    if last_only:
        x = x[:, -1:]
    return logits_from_hidden(params["embed"], x, cfg)


def loss_fn(params, batch, cfg: ModelConfig):
    logits = forward(params, batch["tokens"], cfg,
                     frontend_embeds=batch.get("frontend"),
                     enc_out=(encode(params, batch["frames"], cfg)
                              if cfg.is_encdec else None))
    return cross_entropy(logits, batch["labels"])
