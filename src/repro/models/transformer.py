"""Unified model builder: decoder LMs (dense/MoE/VLM), SSM (Mamba2),
hybrid (Jamba), encoder-decoder (Whisper).

Layer stacks are parameter-stacked (leading layer axis) and executed with
`lax.scan` so the HLO stays O(1) in depth — essential for compiling 480B
configs on a 1-core container. `jax.vmap(init_block)` over split keys
creates the stacked params; under `jax.eval_shape` this allocates nothing.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from .config import ModelConfig
from .layers import (apply_mlp, apply_norm, cross_entropy, dtype_of,
                     embed_tokens, init_embeddings, init_mlp, init_norm,
                     logits_from_hidden)


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------

def _init_dense_block(cfg: ModelConfig, key):
    k1, k2 = jax.random.split(key)
    p = {"norm1": init_norm(cfg, cfg.d_model),
         "norm2": init_norm(cfg, cfg.d_model),
         "attn": attn_mod.init_attention(cfg, k1)}
    if cfg.n_experts:
        p["moe"] = moe_mod.init_moe_block(cfg, k2)
    else:
        p["mlp"] = init_mlp(cfg, k2, cfg.d_model, cfg.d_ff)
    return p


def _init_ssm_block(cfg: ModelConfig, key):
    return {"norm1": init_norm(cfg, cfg.d_model),
            "ssm": ssm_mod.init_ssm(cfg, key)}


def _init_hybrid_period(cfg: ModelConfig, key):
    """One Jamba period: `attn_period` sublayers, attention at attn_index,
    Mamba elsewhere; MoE on every `moe_every`-th sublayer, dense MLP on the
    rest. Each sublayer keeps its own FFN."""
    P = cfg.attn_period
    keys = jax.random.split(key, 2 * P)
    subs = []
    for i in range(P):
        mixer_key, ffn_key = keys[2 * i], keys[2 * i + 1]
        sub = {"norm1": init_norm(cfg, cfg.d_model),
               "norm2": init_norm(cfg, cfg.d_model)}
        if i == cfg.attn_index:
            sub["attn"] = attn_mod.init_attention(cfg, mixer_key)
        else:
            sub["ssm"] = ssm_mod.init_ssm(cfg, mixer_key)
        if cfg.n_experts and (i % cfg.moe_every == cfg.moe_every - 1):
            sub["moe"] = moe_mod.init_moe_block(cfg, ffn_key)
        else:
            sub["mlp"] = init_mlp(cfg, ffn_key, cfg.d_model, cfg.d_ff)
        subs.append(sub)
    return {f"sub{i}": s for i, s in enumerate(subs)}


def _init_encdec_block(cfg: ModelConfig, key, cross: bool):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"norm1": init_norm(cfg, cfg.d_model),
         "norm2": init_norm(cfg, cfg.d_model),
         "attn": attn_mod.init_attention(cfg, k1),
         "mlp": init_mlp(cfg, k2, cfg.d_model, cfg.d_ff)}
    if cross:
        p["norm_x"] = init_norm(cfg, cfg.d_model)
        p["xattn"] = attn_mod.init_attention(cfg, k3)
    return p


def _stacked(init_fn, n: int, key):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def init_params(cfg: ModelConfig, key) -> Dict:
    ke, kb, kenc = jax.random.split(key, 3)
    params = {"embed": init_embeddings(cfg, ke),
              "final_norm": init_norm(cfg, cfg.d_model)}
    if cfg.family == "ssm":
        params["blocks"] = _stacked(lambda k: _init_ssm_block(cfg, k),
                                    cfg.n_layers, kb)
    elif cfg.family == "hybrid":
        n_periods = cfg.n_layers // cfg.attn_period
        params["periods"] = _stacked(lambda k: _init_hybrid_period(cfg, k),
                                     n_periods, kb)
    elif cfg.is_encdec:
        params["blocks"] = _stacked(
            lambda k: _init_encdec_block(cfg, k, cross=True),
            cfg.n_layers, kb)
        params["enc_blocks"] = _stacked(
            lambda k: _init_encdec_block(cfg, k, cross=False),
            cfg.encoder_layers, kenc)
        params["enc_final_norm"] = init_norm(cfg, cfg.d_model)
    else:
        params["blocks"] = _stacked(lambda k: _init_dense_block(cfg, k),
                                    cfg.n_layers, kb)
    if cfg.frontend == "vision_stub":
        # projection of precomputed patch embeddings into the LM stream
        params["patch_proj"] = (jax.random.normal(
            jax.random.fold_in(key, 9), (cfg.d_model, cfg.d_model))
            * cfg.d_model ** -0.5).astype(dtype_of(cfg))
    return params


# ---------------------------------------------------------------------------
# Forward passes (training / prefill)
# ---------------------------------------------------------------------------

def _sinusoidal(S: int, d: int, dtype):
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (dim / d))
    pe = jnp.zeros((S, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang)).at[:, 1::2].set(jnp.cos(ang))
    return pe.astype(dtype)


def _dense_block_fwd(p, x, cfg: ModelConfig, positions, mm=None):
    h = x + attn_mod.attention(p["attn"], apply_norm(p["norm1"], x, cfg),
                               cfg, positions, dense_fn=mm)
    hn = apply_norm(p["norm2"], h, cfg)
    if cfg.n_experts:
        y, _aux = moe_mod.apply_moe_block(p["moe"], hn, cfg, dense_fn=mm)
    else:
        y = apply_mlp(p["mlp"], hn, cfg, dense_fn=mm)
    return h + y


def _ssm_block_fwd(p, x, cfg: ModelConfig, mm=None):
    return x + ssm_mod.apply_ssm(p["ssm"], apply_norm(p["norm1"], x, cfg),
                                 cfg, dense_fn=mm)


def _hybrid_period_fwd(p, x, cfg: ModelConfig, positions):
    # Each sublayer is itself rematerialized: the 8-sublayer period body
    # otherwise keeps every sublayer's intermediates live as residuals
    # (jamba train temp was 80 GB/dev with period-level remat only).
    def sublayer(i, sub, h):
        hn = apply_norm(sub["norm1"], h, cfg)
        if i == cfg.attn_index:
            h = h + attn_mod.attention(sub["attn"], hn, cfg, positions)
        else:
            h = h + ssm_mod.apply_ssm(sub["ssm"], hn, cfg)
        hn2 = apply_norm(sub["norm2"], h, cfg)
        if "moe" in sub:
            y, _aux = moe_mod.apply_moe_block(sub["moe"], hn2, cfg)
        else:
            y = apply_mlp(sub["mlp"], hn2, cfg)
        return h + y

    for i in range(cfg.attn_period):
        fn = jax.checkpoint(functools.partial(sublayer, i)) if cfg.remat \
            else functools.partial(sublayer, i)
        x = fn(p[f"sub{i}"], x)
    return x


def _scan_stack(blocks, x, body, remat: bool, policy: str = "full",
                tables=None):
    """Scan the stacked layer params through `body(layer_params, h, mm)`.

    `tables` (sparsity.sparse_linear.StackedKernelTables) rides the scan
    as extra xs: each step receives its layer's slice of the uniform-MAXB
    packed weights and rebuilds the dense_fn hook, so the joint DB-PIM
    kernel serves EVERY layer while the HLO stays O(1) in depth. mm is
    None on the plain (dense) path.
    """
    def wrapped(layer_params, carry, slices):
        mm = tables.dense_fn(slices) if tables is not None else None
        return body(layer_params, carry, mm)
    if remat and policy == "dots":
        fn = jax.checkpoint(
            wrapped,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    elif remat:
        fn = jax.checkpoint(wrapped)
    else:
        fn = wrapped
    xs = (blocks, tables.arrays if tables is not None else None)
    def step(carry, inp):
        layer_params, slices = inp
        return fn(layer_params, carry, slices), None
    out, _ = jax.lax.scan(step, x, xs)
    return out


def encode(params, frames, cfg: ModelConfig):
    """Whisper encoder over stub frame embeddings (B, Se, D)."""
    x = frames + _sinusoidal(frames.shape[1], cfg.d_model, frames.dtype)

    def body(p, h, mm):
        hn = apply_norm(p["norm1"], h, cfg)
        h = h + attn_mod.attention(p["attn"], hn, cfg,
                                   jnp.zeros(h.shape[:2], jnp.int32),
                                   causal=False)
        return h + apply_mlp(p["mlp"], apply_norm(p["norm2"], h, cfg), cfg)

    x = _scan_stack(params["enc_blocks"], x, body, cfg.remat,
                    cfg.remat_policy)
    return apply_norm(params["enc_final_norm"], x, cfg)


def forward(params, tokens, cfg: ModelConfig,
            frontend_embeds: Optional[jnp.ndarray] = None,
            enc_out: Optional[jnp.ndarray] = None,
            last_only: bool = False,
            tables=None):
    """Full-sequence forward to logits.

    frontend_embeds: VLM patch embeddings (B, n_patches, D) prepended to
    the token stream (pixtral) — logits are returned for token positions
    only. enc_out: whisper encoder output for cross-attention.
    last_only: unembed only the final position (prefill) — at 150k vocab,
    unembedding all 32k positions would dominate prefill compute/memory.
    tables: sparsity.sparse_linear.StackedKernelTables — uniform-MAXB
    joint-sparse projections that ride the layer scan as xs, so the
    DB-PIM kernel serves every layer (dense / MoE / SSM families; MoE
    expert stacks dispatch per packed expert slice).
    """
    B, S = tokens.shape
    x = embed_tokens(params["embed"], tokens, cfg)
    n_front = 0
    if cfg.frontend == "vision_stub" and frontend_embeds is not None:
        fe = frontend_embeds @ params["patch_proj"]
        x = jnp.concatenate([fe.astype(x.dtype), x], axis=1)
        n_front = frontend_embeds.shape[1]
    if cfg.rope_pct == 0:
        x = x + _sinusoidal(x.shape[1], cfg.d_model, x.dtype)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32),
                                 (B, x.shape[1]))

    if tables is not None and not cfg.supports_stacked_tables:
        raise ValueError(f"stacked kernel tables are not supported for the "
                         f"{cfg.family} family yet (mixed-sublayer "
                         f"hybrid/enc-dec scans)")

    if cfg.family == "ssm":
        body = lambda p, h, mm: _ssm_block_fwd(p, h, cfg, mm)
        x = _scan_stack(params["blocks"], x, body, cfg.remat,
                        cfg.remat_policy, tables=tables)
    elif cfg.family == "hybrid":
        body = lambda p, h, mm: _hybrid_period_fwd(p, h, cfg, positions)
        x = _scan_stack(params["periods"], x, body, cfg.remat, cfg.remat_policy)
    elif cfg.is_encdec:
        def body(p, h, mm):
            hn = apply_norm(p["norm1"], h, cfg)
            h = h + attn_mod.attention(p["attn"], hn, cfg, positions)
            hx = apply_norm(p["norm_x"], h, cfg)
            h = h + attn_mod.cross_attention(p["xattn"], hx, enc_out, cfg)
            return h + apply_mlp(p["mlp"], apply_norm(p["norm2"], h, cfg), cfg)
        x = _scan_stack(params["blocks"], x, body, cfg.remat, cfg.remat_policy)
    else:
        body = lambda p, h, mm: _dense_block_fwd(p, h, cfg, positions, mm)
        x = _scan_stack(params["blocks"], x, body, cfg.remat,
                        cfg.remat_policy, tables=tables)

    x = apply_norm(params["final_norm"], x, cfg)
    if n_front:
        x = x[:, n_front:]
    if last_only:
        x = x[:, -1:]
    return logits_from_hidden(params["embed"], x, cfg)


def loss_fn(params, batch, cfg: ModelConfig):
    logits = forward(params, batch["tokens"], cfg,
                     frontend_embeds=batch.get("frontend"),
                     enc_out=(encode(params, batch["frames"], cfg)
                              if cfg.is_encdec else None))
    return cross_entropy(logits, batch["labels"])
