"""Attention: GQA / MQA / MHA with RoPE, qk-norm, sliding windows (SWA),
cross-attention, and a static-shape KV cache for prefill/decode.

Shapes: x (B, S, D); q (B, S, Hq, hd); k/v (B, S, Hkv, hd).
Cache: {"k","v"} (B, S_max, Hkv, hd) + integer write index — or, paged,
a pooled {"pk","pv"} (n_pages, page_size, Hkv, hd) indexed through a
per-slot page table (init_paged_cache; serving.paging owns the table).
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import apply_rope, dtype_of, rms_head_norm, rope_frequencies
from repro.runtime.act_sharding import constrain


def init_attention(cfg: ModelConfig, key, cross: bool = False):
    dt = dtype_of(cfg)
    d = cfg.d_model
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d ** -0.5
    p = {"wq": (jax.random.normal(k1, (d, cfg.q_dim)) * s).astype(dt),
         "wk": (jax.random.normal(k2, (d, cfg.kv_dim)) * s).astype(dt),
         "wv": (jax.random.normal(k3, (d, cfg.kv_dim)) * s).astype(dt),
         "wo": (jax.random.normal(k4, (cfg.q_dim, d))
                * cfg.q_dim ** -0.5).astype(dt)}
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.hd,), jnp.float32)
        p["k_norm"] = jnp.ones((cfg.hd,), jnp.float32)
    return p


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def _repeat_kv(k, n_rep):
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=2)


def _sdpa(q, k, v, mask, dtype):
    """q (B,Sq,H,hd), k/v (B,Skv,H,hd), mask broadcast (B,1,Sq,Skv).

    The probs @ v contraction is written as a plain batched matmul
    (``bhqk,bhkd->bhqd`` on pre-transposed v) rather than the fused
    ``bhqk,bkhd->bqhd`` form: the fused output transpose makes XLA pick
    Sq-dependent loop orders, so a 1-token decode and a C-token prefill
    chunk would disagree in the last float bit. The batched-matmul form
    is row-stable across Sq — what lets chunked prefill reproduce
    sequential decode bit-for-bit.
    """
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(dtype)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, jnp.moveaxis(v, 2, 1))
    return jnp.moveaxis(out, 1, 2)


CHUNKED_ATTN_THRESHOLD = 16384


def _chunked_sdpa(q, k, v, cfg: ModelConfig, dtype, chunk: int = 2048):
    """Flash-style two-level blocked attention with online softmax.

    Never materializes (S, S): outer scan over query chunks, inner scan
    over key chunks with running (max, sum, acc). Causal masking at block
    granularity (upper-triangular blocks are masked, not skipped — the 2x
    block waste is a recorded §Perf item). q/k/v: (B, S, H, hd).
    """
    B, S, H, hd = q.shape
    nq = S // chunk
    scale = hd ** -0.5
    qc = jnp.moveaxis(q.reshape(B, nq, chunk, H, hd), 1, 0)
    kc = jnp.moveaxis(k.reshape(B, nq, chunk, H, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nq, chunk, H, hd), 1, 0)

    base = jnp.arange(chunk)

    def q_block(_, qi_q):
        qi, qb = qi_q
        qpos = qi * chunk + base

        def kv_block(carry, kj_kv):
            m_prev, l_prev, acc = carry
            kj, kb, vb = kj_kv
            kpos = kj * chunk + base
            s = jnp.einsum("bqhd,bkhd->bhqk", qb, kb).astype(jnp.float32)
            s = s * scale
            mask = kpos[None, :] <= qpos[:, None]
            if cfg.window:
                mask &= kpos[None, :] > qpos[:, None] - cfg.window
            s = jnp.where(mask[None, None], s, -1e30)
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(dtype), vb)
            acc = acc * corr[..., None].astype(dtype) + pv
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, H, chunk), -1e30, jnp.float32)
        l0 = jnp.zeros((B, H, chunk), jnp.float32)
        a0 = jnp.zeros((B, H, chunk, hd), dtype)
        (m, l, acc), _ = jax.lax.scan(
            kv_block, (m0, l0, a0),
            (jnp.arange(nq), kc, vc))
        out = acc / jnp.maximum(l, 1e-30)[..., None].astype(dtype)
        return None, jnp.moveaxis(out, 1, 2)        # (B, chunk, H, hd)

    _, blocks = jax.lax.scan(q_block, None, (jnp.arange(nq), qc))
    return jnp.moveaxis(blocks, 0, 1).reshape(B, S, H, hd)


def causal_mask(sq: int, skv: int, window: int = 0):
    """(1, 1, sq, skv) bool; offsets assume q positions are the last sq of
    skv (prefill: sq == skv)."""
    qpos = jnp.arange(sq)[:, None] + (skv - sq)
    kpos = jnp.arange(skv)[None, :]
    m = kpos <= qpos
    if window:
        m &= kpos > qpos - window
    return m[None, None]


def attention(p, x, cfg: ModelConfig, positions, causal: bool = True,
              dense_fn=None):
    """Full-sequence attention (training / encoder). positions (B, S)."""
    mm = dense_fn or (lambda w, v, name: v @ w)
    B, S, _ = x.shape
    q = _split_heads(mm(p["wq"], x, "wq"), cfg.n_heads, cfg.hd)
    k = _split_heads(mm(p["wk"], x, "wk"), cfg.n_kv_heads, cfg.hd)
    v = _split_heads(mm(p["wv"], x, "wv"), cfg.n_kv_heads, cfg.hd)
    if cfg.qk_norm:
        q = rms_head_norm(p["q_norm"], q)
        k = rms_head_norm(p["k_norm"], k)
    if cfg.rope_pct > 0:
        cos, sin = rope_frequencies(cfg, positions)
        q = apply_rope(q, cos, sin, cfg)
        k = apply_rope(k, cos, sin, cfg)
    k = _repeat_kv(k, cfg.n_heads // cfg.n_kv_heads)
    v = _repeat_kv(v, cfg.n_heads // cfg.n_kv_heads)
    q = constrain(q, "dp", None, "tp", None)
    k = constrain(k, "dp", None, "tp", None)
    v = constrain(v, "dp", None, "tp", None)
    if causal and S >= CHUNKED_ATTN_THRESHOLD and S % 2048 == 0:
        out = _chunked_sdpa(q, k, v, cfg, x.dtype)
    else:
        if causal:
            mask = causal_mask(S, S, cfg.window)
        else:
            mask = jnp.ones((1, 1, S, S), bool)
        out = _sdpa(q, k, v, mask, x.dtype)
    return mm(p["wo"], out.reshape(B, S, cfg.q_dim), "wo")


def cross_attention(p, x, enc_out, cfg: ModelConfig, dense_fn=None):
    """Decoder cross-attention over encoder output (whisper). Hook names
    carry the "xattn/" prefix: a decoder block's self- and cross-
    attention projections pack as distinct table entries within the same
    segment, so the dense_fn lookup must not collide."""
    mm = dense_fn or (lambda w, v, name: v @ w)
    B, S, _ = x.shape
    Se = enc_out.shape[1]
    q = _split_heads(mm(p["wq"], x, "xattn/wq"), cfg.n_heads, cfg.hd)
    k = _split_heads(mm(p["wk"], enc_out, "xattn/wk"),
                     cfg.n_kv_heads, cfg.hd)
    v = _split_heads(mm(p["wv"], enc_out, "xattn/wv"),
                     cfg.n_kv_heads, cfg.hd)
    k = _repeat_kv(k, cfg.n_heads // cfg.n_kv_heads)
    v = _repeat_kv(v, cfg.n_heads // cfg.n_kv_heads)
    mask = jnp.ones((1, 1, S, Se), bool)
    out = _sdpa(q, k, v, mask, x.dtype)
    return mm(p["wo"], out.reshape(B, S, cfg.q_dim), "xattn/wo")


# ------------------------------------------------------------- cache -------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, n_layers: int):
    """Stacked KV cache for a layer stack. SWA archs allocate only the
    window (ring buffer) — that is what makes long_500k decode O(window)."""
    dt = dtype_of(cfg)
    alloc = min(max_len, cfg.window) if cfg.window else max_len
    shape = (n_layers, batch, alloc, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt),
            "pos": jnp.zeros((), jnp.int32)}


def init_paged_cache(cfg: ModelConfig, n_pages: int, page_size: int,
                     n_layers: int):
    """Paged KV pool for a layer stack: ``{"pk","pv"}`` of shape
    (n_layers, n_pages, page_size, Hkv, hd). There is no batch dim — a
    slot's cache is whatever pages its page-table row points at, which
    is what lets short requests stop reserving max_len worth of HBM.
    The page table itself is HOST state (serving.paging.PageAllocator)
    passed into each step as a fixed-shape operand, never cache-resident.

    Sliding-window archs keep the contiguous ring cache: the ring
    overwrite pattern is already O(window) and pages would only re-add
    the indirection without saving memory."""
    if cfg.window:
        raise ValueError(f"paged KV cache does not support sliding-window "
                         f"ring caches ({cfg.name}); serve contiguous")
    dt = dtype_of(cfg)
    shape = (n_layers, n_pages, page_size, cfg.n_kv_heads, cfg.hd)
    return {"pk": jnp.zeros(shape, dt), "pv": jnp.zeros(shape, dt)}


def _paged_view(pool, ptab, n_kv: int, hd: int):
    """Gather a per-slot contiguous view (B, MP*PS, Hkv, hd) out of the
    page pool through the page table. Unallocated entries (-1) clamp to
    page 0 — their columns are beyond every query's position, so the
    causal mask zeroes them exactly (softmax of -1e30 underflows to
    0.0f) and the garbage values never reach an output bit."""
    n_pages = pool.shape[0]
    gid = jnp.clip(ptab, 0, n_pages - 1)                 # (B, MP)
    view = pool[gid]                                     # (B, MP, PS, H, hd)
    B, MP, PS = view.shape[0], view.shape[1], view.shape[2]
    return view.reshape(B, MP * PS, n_kv, hd)


def _per_slot_pos(pos, B: int):
    """Normalize a cache position to per-slot (B,) int32. Serving keeps a
    scalar position for lock-step batches and a vector when slots hold
    requests at different depths (the serving engine's continuous-batching
    regime); both shapes flow through the same vectorized math."""
    return jnp.broadcast_to(jnp.atleast_1d(jnp.asarray(pos, jnp.int32)),
                            (B,))


def decode_attention(p, x, cache_k, cache_v, pos, cfg: ModelConfig,
                     dense_fn=None, ptab=None, write_mask=None):
    """Single-token decode against one layer's cache slice.

    x (B, 1, D); cache_k/v (B, A, Hkv, hd) with A = alloc len; pos = number
    of tokens already in the cache — a scalar (lock-step batch) or a (B,)
    vector (per-slot depths). Returns (out, new_k, new_v).

    PAGED mode (ptab is not None): cache_k/v are instead one layer's
    page POOL (n_pages, page_size, Hkv, hd) shared by every slot, and
    ptab (B, max_pages) int32 maps each slot's token positions to pages
    (-1 = unallocated). The write scatters through the table (negative
    page ids route to the out-of-range sentinel and are DROPPED —
    ``write_mask`` lets the serving engine drop inactive slots' writes
    in-step, since merge_slots cannot select per-slot on a pooled leaf);
    the read gathers the slot's pages back into a contiguous
    (B, max_pages * page_size, Hkv, hd) view. When max_pages * page_size
    equals the contiguous alloc A, the post-gather math is LITERALLY the
    contiguous computation — same values, same shapes, same reduction
    order — so paged decode is bitwise-identical to the contiguous path.
    """
    if ptab is not None and cfg.window:
        raise ValueError("paged attention does not support sliding-window "
                         "ring caches; serve contiguous")
    mm = dense_fn or (lambda w, v, name: v @ w)
    B = x.shape[0]
    posv = _per_slot_pos(pos, B)                                   # (B,)
    q = _split_heads(mm(p["wq"], x, "wq"), cfg.n_heads, cfg.hd)
    k = _split_heads(mm(p["wk"], x, "wk"), cfg.n_kv_heads, cfg.hd)
    v = _split_heads(mm(p["wv"], x, "wv"), cfg.n_kv_heads, cfg.hd)
    if cfg.qk_norm:
        q = rms_head_norm(p["q_norm"], q)
        k = rms_head_norm(p["k_norm"], k)
    if cfg.rope_pct > 0:
        cos, sin = rope_frequencies(cfg, posv[:, None])
        q = apply_rope(q, cos, sin, cfg)
        k = apply_rope(k, cos, sin, cfg)
    rep = cfg.n_heads // cfg.n_kv_heads
    if ptab is None:
        A = cache_k.shape[1]
        slot = jnp.mod(posv, A) if cfg.window else jnp.minimum(posv, A - 1)
        rows = jnp.arange(B)
        new_k = cache_k.at[rows, slot].set(k[:, 0])
        new_v = cache_v.at[rows, slot].set(v[:, 0])
        kk = _repeat_kv(new_k, rep)
        vv = _repeat_kv(new_v, rep)
        kpos = jnp.arange(A)[None, :]                              # (1, A)
        if cfg.window:
            # ring buffer: all valid once full
            valid = (kpos <= slot[:, None]) | (posv[:, None] >= A)
        else:
            valid = kpos <= posv[:, None]
    else:
        NP, PS = cache_k.shape[0], cache_k.shape[1]
        A = ptab.shape[1] * PS
        wpos = jnp.minimum(posv, A - 1)
        pid = ptab[jnp.arange(B), wpos // PS]                      # (B,)
        ok = pid >= 0
        if write_mask is not None:
            ok &= write_mask
        pid_w = jnp.where(ok, pid, NP)         # NP = out of range: dropped
        new_k = cache_k.at[pid_w, wpos % PS].set(k[:, 0], mode="drop")
        new_v = cache_v.at[pid_w, wpos % PS].set(v[:, 0], mode="drop")
        kk = _repeat_kv(_paged_view(new_k, ptab, cfg.n_kv_heads, cfg.hd),
                        rep)
        vv = _repeat_kv(_paged_view(new_v, ptab, cfg.n_kv_heads, cfg.hd),
                        rep)
        valid = jnp.arange(A)[None, :] <= posv[:, None]
    mask = valid[:, None, None, :]                                 # (B,1,1,A)
    out = _sdpa(q, kk, vv, mask, x.dtype)
    return mm(p["wo"], out.reshape(B, 1, cfg.q_dim), "wo"), new_k, new_v


def prefill_attention(p, x, cache_k, cache_v, pos, n_valid,
                      cfg: ModelConfig, dense_fn=None, ptab=None):
    """Chunked cache-filling attention: C prompt tokens in one step.

    x (B, C, D); cache_k/v (B, A, Hkv, hd); pos (B,) tokens already in the
    cache per slot; n_valid (B,) in [0, C] real tokens in this chunk (the
    tail chunk of a prompt is ragged; slots not prefilling pass 0).
    Writes the valid tokens' k/v at positions pos..pos+n_valid-1 (invalid
    columns scatter out of range and are DROPPED, so inactive slots' cache
    slices are untouched) and attends each query to every cached position
    <= its own — bit-identical per token to running `decode_attention`
    n_valid times, but one MXU-shaped step. Returns (out, new_k, new_v).

    Requires cfg.window == 0: a sliding-window ring buffer overwrites
    slots within the chunk, which only a sequential walk reproduces.

    PAGED mode (ptab is not None): cache_k/v are the page pool
    (n_pages, page_size, Hkv, hd); writes scatter through the table
    (invalid chunk columns and unallocated pages route to the sentinel
    row and drop — the same mode="drop" idiom as the contiguous path),
    reads gather the per-slot contiguous view. Bitwise-identical to the
    contiguous chunk when max_pages * page_size == A.
    """
    if cfg.window:
        raise ValueError("chunked prefill does not support sliding-window "
                         "ring caches; use stepwise (full-forward) prefill")
    mm = dense_fn or (lambda w, v, name: v @ w)
    B, C, _ = x.shape
    A = cache_k.shape[1] if ptab is None else ptab.shape[1] * cache_k.shape[1]
    posv = _per_slot_pos(pos, B)                                   # (B,)
    qpos = posv[:, None] + jnp.arange(C)[None, :]                  # (B, C)
    q = _split_heads(mm(p["wq"], x, "wq"), cfg.n_heads, cfg.hd)
    k = _split_heads(mm(p["wk"], x, "wk"), cfg.n_kv_heads, cfg.hd)
    v = _split_heads(mm(p["wv"], x, "wv"), cfg.n_kv_heads, cfg.hd)
    if cfg.qk_norm:
        q = rms_head_norm(p["q_norm"], q)
        k = rms_head_norm(p["k_norm"], k)
    if cfg.rope_pct > 0:
        cos, sin = rope_frequencies(cfg, qpos)
        q = apply_rope(q, cos, sin, cfg)
        k = apply_rope(k, cos, sin, cfg)
    # scatter the valid chunk tokens into the cache; invalid columns get
    # row index A (out of range) and are dropped by the scatter
    tok_valid = jnp.arange(C)[None, :] < n_valid[:, None]          # (B, C)
    if ptab is None:
        write_rows = jnp.where(tok_valid, jnp.minimum(qpos, A - 1), A)
        b_idx = jnp.broadcast_to(jnp.arange(B)[:, None], (B, C))
        new_k = cache_k.at[b_idx, write_rows].set(k, mode="drop")
        new_v = cache_v.at[b_idx, write_rows].set(v, mode="drop")
        kk = _repeat_kv(new_k, cfg.n_heads // cfg.n_kv_heads)
        vv = _repeat_kv(new_v, cfg.n_heads // cfg.n_kv_heads)
    else:
        NP, PS = cache_k.shape[0], cache_k.shape[1]
        wpos = jnp.minimum(qpos, A - 1)                            # (B, C)
        pid = jnp.take_along_axis(ptab, wpos // PS, axis=1)        # (B, C)
        pid_w = jnp.where(tok_valid & (pid >= 0), pid, NP)
        new_k = cache_k.at[pid_w, wpos % PS].set(k, mode="drop")
        new_v = cache_v.at[pid_w, wpos % PS].set(v, mode="drop")
        kk = _repeat_kv(_paged_view(new_k, ptab, cfg.n_kv_heads, cfg.hd),
                        cfg.n_heads // cfg.n_kv_heads)
        vv = _repeat_kv(_paged_view(new_v, ptab, cfg.n_kv_heads, cfg.hd),
                        cfg.n_heads // cfg.n_kv_heads)
    kpos = jnp.arange(A)[None, None, :]                            # (1,1,A)
    mask = kpos <= qpos[:, :, None]                                # (B,C,A)
    out = _sdpa(q, kk, vv, mask[:, None], x.dtype)
    return mm(p["wo"], out.reshape(B, C, cfg.q_dim), "wo"), new_k, new_v
