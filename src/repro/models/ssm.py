"""Mamba2 — State Space Duality (SSD) block, chunked matmul form.

Follows arXiv:2405.21060: inputs are projected to per-head x, scalar decay
A per head, input/output projections B/C shared across heads (n_groups=1),
with a depthwise causal conv on (x, B, C) channels and a gated RMSNorm
before the output projection.

The chunked algorithm runs `lax.scan` over chunks of length Q carrying the
inter-chunk state (B, H, P, N): per chunk the intra-chunk quadratic term is
(B, H, Q, Q) — bounded memory, matmul-heavy (MXU-friendly), O(L) overall.

Decode is the O(1) recurrent update: s = s * exp(dt*A) + dt * (B outer x).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dtype_of


def ssm_dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    nh = d_in // cfg.ssm_head_dim
    return d_in, nh, cfg.ssm_state, cfg.ssm_head_dim


def init_ssm(cfg: ModelConfig, key):
    dt = dtype_of(cfg)
    d = cfg.d_model
    d_in, nh, N, P = ssm_dims(cfg)
    conv_ch = d_in + 2 * N
    k = jax.random.split(key, 4)
    s = d ** -0.5
    return {
        "in_proj": (jax.random.normal(k[0], (d, 2 * d_in + 2 * N + nh))
                    * s).astype(dt),
        "conv_w": (jax.random.normal(k[1], (cfg.ssm_conv_width, conv_ch))
                   * 0.2).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm_scale": jnp.ones((d_in,), jnp.float32),
        "out_proj": (jax.random.normal(k[3], (d_in, d))
                     * d_in ** -0.5).astype(dt),
    }


def _split_proj(proj, cfg: ModelConfig):
    d_in, nh, N, _ = ssm_dims(cfg)
    z, xbc_dt = jnp.split(proj, [d_in], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [d_in + 2 * N], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv along time. xbc (B, L, C); w (W, C)."""
    W = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(W))
    return jax.nn.silu(out + b)


def _gated_norm(y, z, scale, eps=1e-6):
    yf = (y * jax.nn.silu(z)).astype(jnp.float32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps) * scale).astype(y.dtype)


def apply_ssm(p, x, cfg: ModelConfig, dense_fn=None):
    """Training / prefill forward. x (B, L, D) -> (B, L, D).

    dense_fn(w, x, name) intercepts the in/out projections (the DB-PIM
    sparse serving path); the chunked state scan itself is projection-free
    so the hook wraps it cleanly on both sides."""
    mm = dense_fn or (lambda w, v, name: v @ w)
    Bsz, L, _ = x.shape
    d_in, nh, N, P = ssm_dims(cfg)
    Q = min(cfg.ssm_chunk, L)
    assert L % Q == 0, f"seq {L} not divisible by chunk {Q}"
    nc = L // Q

    z, xbc, dt_raw = _split_proj(mm(p["in_proj"], x, "in_proj"), cfg)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs, Bmat, Cmat = jnp.split(xbc, [d_in, d_in + N], axis=-1)
    xs = xs.reshape(Bsz, L, nh, P)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,L,nh)
    A = -jnp.exp(p["A_log"])                                          # (nh,)
    dA = dt * A                                                       # (B,L,nh)

    # chunk views: (nc, B, Q, ...)
    def chunkify(t):
        return jnp.moveaxis(t.reshape(Bsz, nc, Q, *t.shape[2:]), 0, 1)
    xs_c, B_c, C_c = chunkify(xs), chunkify(Bmat), chunkify(Cmat)
    dt_c, dA_c = chunkify(dt), chunkify(dA)

    def chunk_step(state, inp):
        xq, bq, cq, dtq, daq = inp          # (B,Q,...)
        cum = jnp.cumsum(daq, axis=1)       # (B,Q,nh)
        # intra-chunk: y_i += sum_{j<=i} exp(cum_i - cum_j) dt_j (c_i.b_j) x_j
        seg = cum[:, :, None, :] - cum[:, None, :, :]          # (B,Q,Q,nh)
        tri = jnp.tril(jnp.ones((Q, Q), bool))
        decay = jnp.where(tri[None, :, :, None], jnp.exp(seg), 0.0)
        cb = jnp.einsum("bqn,bkn->bqk", cq.astype(jnp.float32),
                        bq.astype(jnp.float32))                # (B,Q,Q)
        w = cb[:, :, :, None] * decay * dtq[:, None, :, :]     # (B,Q,Q,nh)
        y = jnp.einsum("bqkh,bkhp->bqhp", w, xs_f(xq))
        # inter-chunk: contribution of the carried state
        dec0 = jnp.exp(cum)                                    # (B,Q,nh)
        y += jnp.einsum("bqn,bqh,bhpn->bqhp", cq.astype(jnp.float32),
                        dec0, state)
        # state update
        decT = jnp.exp(cum[:, -1:, :] - cum)                   # (B,Q,nh)
        contrib = jnp.einsum("bqh,bqn,bqhp->bhpn",
                             decT * dtq, bq.astype(jnp.float32), xs_f(xq))
        new_state = state * jnp.exp(cum[:, -1, :])[:, :, None, None] + contrib
        return new_state, y

    def xs_f(t):
        return t.astype(jnp.float32)

    state0 = jnp.zeros((Bsz, nh, P, N), jnp.float32)
    # remat the chunk body: its (B, Q, Q, nh) f32 intra-chunk tensors
    # otherwise persist as backward residuals for EVERY chunk (~70 GB/dev
    # for jamba train_4k).
    _, ys = jax.lax.scan(jax.checkpoint(chunk_step), state0,
                         (xs_c, B_c, C_c, dt_c, dA_c))
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, L, nh, P)
    y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(Bsz, L, d_in).astype(x.dtype)
    return mm(p["out_proj"], _gated_norm(y, z, p["norm_scale"]), "out_proj")


# ------------------------------------------------------------ decode -------

def init_ssm_cache(cfg: ModelConfig, batch: int, n_layers: int):
    d_in, nh, N, P = ssm_dims(cfg)
    conv_ch = d_in + 2 * N
    return {
        "conv": jnp.zeros((n_layers, batch, cfg.ssm_conv_width - 1, conv_ch),
                          dtype_of(cfg)),
        "state": jnp.zeros((n_layers, batch, nh, P, N), jnp.float32),
    }


def decode_ssm(p, x, conv_state, ssm_state, cfg: ModelConfig,
               dense_fn=None):
    """One-token decode. x (B, 1, D); conv_state (B, W-1, C);
    ssm_state (B, nh, P, N). Returns (y, new_conv, new_state)."""
    mm = dense_fn or (lambda w, v, name: v @ w)
    Bsz = x.shape[0]
    d_in, nh, N, P = ssm_dims(cfg)
    z, xbc, dt_raw = _split_proj(mm(p["in_proj"], x[:, 0], "in_proj"), cfg)
    window = jnp.concatenate([conv_state, xbc[:, None, :]], axis=1)
    conv = jnp.sum(window * p["conv_w"][None], axis=1) + p["conv_b"]
    xbc_t = jax.nn.silu(conv)
    xs, Bv, Cv = jnp.split(xbc_t, [d_in, d_in + N], axis=-1)
    xs = xs.reshape(Bsz, nh, P).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,nh)
    A = -jnp.exp(p["A_log"])
    da = jnp.exp(dt * A)                                              # (B,nh)
    contrib = jnp.einsum("bh,bn,bhp->bhpn", dt, Bv.astype(jnp.float32), xs)
    new_state = ssm_state * da[:, :, None, None] + contrib
    y = jnp.einsum("bn,bhpn->bhp", Cv.astype(jnp.float32), new_state)
    y = y + xs * p["D"][None, :, None]
    y = y.reshape(Bsz, 1, d_in).astype(x.dtype)
    out = mm(p["out_proj"], _gated_norm(y, z[:, None, :], p["norm_scale"]),
             "out_proj")
    return out, window[:, 1:], new_state


def prefill_ssm(p, x, conv_state, ssm_state, n_valid, cfg: ModelConfig,
                dense_fn=None):
    """Chunked cache-filling prefill: C prompt tokens through the exact
    decode recurrence in one step.

    x (B, C, D); conv_state (B, W-1, Ch); ssm_state (B, nh, P, N);
    n_valid (B,) in [0, C] real tokens per slot. The chunk runs an inner
    `lax.scan` of `decode_ssm` token steps — bit-identical state/conv
    trajectories to n_valid sequential decode calls (the chunked-matmul
    training form reorders the f32 accumulation) — with per-slot validity
    gating so ragged tail chunks and idle slots leave their caches
    untouched. Returns (y (B, C, D), new_conv, new_state).
    """
    C = x.shape[1]

    def step(carry, inp):
        conv, state = carry
        xt, t = inp                                    # (B, 1, D), scalar
        y, new_conv, new_state = decode_ssm(p, xt, conv, state, cfg,
                                            dense_fn=dense_fn)
        keep = (t < n_valid)                           # (B,)
        conv = jnp.where(keep[:, None, None], new_conv, conv)
        state = jnp.where(keep[:, None, None, None], new_state, state)
        return (conv, state), y

    xs = jnp.moveaxis(x[:, :, None, :], 1, 0)          # (C, B, 1, D)
    (conv, state), ys = jax.lax.scan(
        step, (conv_state, ssm_state), (xs, jnp.arange(C)))
    y = jnp.moveaxis(ys[:, :, 0, :], 0, 1)             # (B, C, D)
    return y, conv, state
