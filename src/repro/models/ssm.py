"""Mamba2 — State Space Duality (SSD) block, chunked matmul form.

Follows arXiv:2405.21060: inputs are projected to per-head x, scalar decay
A per head, input/output projections B/C shared across heads (n_groups=1),
with a depthwise causal conv on (x, B, C) channels and a gated RMSNorm
before the output projection.

The chunked algorithm runs `lax.scan` over chunks of length Q carrying the
inter-chunk state (B, H, P, N): per chunk the intra-chunk quadratic term is
(B, H, Q, Q) — bounded memory, matmul-heavy (MXU-friendly), O(L) overall.

Decode is the O(1) recurrent update: s = s * exp(dt*A) + dt * (B outer x).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dtype_of


def ssm_dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    nh = d_in // cfg.ssm_head_dim
    return d_in, nh, cfg.ssm_state, cfg.ssm_head_dim


def init_ssm(cfg: ModelConfig, key):
    dt = dtype_of(cfg)
    d = cfg.d_model
    d_in, nh, N, P = ssm_dims(cfg)
    conv_ch = d_in + 2 * N
    k = jax.random.split(key, 4)
    s = d ** -0.5
    return {
        "in_proj": (jax.random.normal(k[0], (d, 2 * d_in + 2 * N + nh))
                    * s).astype(dt),
        "conv_w": (jax.random.normal(k[1], (cfg.ssm_conv_width, conv_ch))
                   * 0.2).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm_scale": jnp.ones((d_in,), jnp.float32),
        "out_proj": (jax.random.normal(k[3], (d_in, d))
                     * d_in ** -0.5).astype(dt),
    }


def _split_proj(proj, cfg: ModelConfig):
    d_in, nh, N, _ = ssm_dims(cfg)
    z, xbc_dt = jnp.split(proj, [d_in], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [d_in + 2 * N], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv along time. xbc (B, L, C); w (W, C)."""
    W = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(W))
    return jax.nn.silu(out + b)


def _gated_norm(y, z, scale, eps=1e-6):
    yf = (y * jax.nn.silu(z)).astype(jnp.float32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps) * scale).astype(y.dtype)


def _ssd_chunk(state, xq, bq, cq, dtq, A):
    """One parallel-form SSD chunk (arXiv:2405.21060 §6): Q tokens in
    matrix form against a carried state.

    state (B, H, P, N) f32; xq (B, Q, H, P); bq/cq (B, Q, N);
    dtq (B, Q, H) f32 (already softplus'd — a token with dtq == 0 is an
    exact identity on the state and contributes nothing, which is how the
    prefill path masks invalid slots); A (H,) f32. Returns
    (new_state, y (B, Q, H, P) f32). Shared by the training forward
    (apply_ssm) and the serving parallel prefill (prefill_ssm_parallel).
    """
    Q = dtq.shape[1]
    cum = jnp.cumsum(dtq * A, axis=1)                      # (B,Q,H)
    # intra-chunk: y_i += sum_{j<=i} exp(cum_i - cum_j) dt_j (c_i.b_j) x_j
    seg = cum[:, :, None, :] - cum[:, None, :, :]          # (B,Q,Q,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(tri[None, :, :, None], jnp.exp(seg), 0.0)
    cb = jnp.einsum("bqn,bkn->bqk", cq.astype(jnp.float32),
                    bq.astype(jnp.float32))                # (B,Q,Q)
    w = cb[:, :, :, None] * decay * dtq[:, None, :, :]     # (B,Q,Q,H)
    xf = xq.astype(jnp.float32)
    y = jnp.einsum("bqkh,bkhp->bqhp", w, xf)
    # inter-chunk: contribution of the carried state
    dec0 = jnp.exp(cum)                                    # (B,Q,H)
    y += jnp.einsum("bqn,bqh,bhpn->bqhp", cq.astype(jnp.float32),
                    dec0, state)
    # state update
    decT = jnp.exp(cum[:, -1:, :] - cum)                   # (B,Q,H)
    contrib = jnp.einsum("bqh,bqn,bqhp->bhpn",
                         decT * dtq, bq.astype(jnp.float32), xf)
    new_state = state * jnp.exp(cum[:, -1, :])[:, :, None, None] + contrib
    return new_state, y


def apply_ssm(p, x, cfg: ModelConfig, dense_fn=None):
    """Training / prefill forward. x (B, L, D) -> (B, L, D).

    dense_fn(w, x, name) intercepts the in/out projections (the DB-PIM
    sparse serving path); the chunked state scan itself is projection-free
    so the hook wraps it cleanly on both sides."""
    mm = dense_fn or (lambda w, v, name: v @ w)
    Bsz, L, _ = x.shape
    d_in, nh, N, P = ssm_dims(cfg)
    Q = min(cfg.ssm_chunk, L)
    assert L % Q == 0, f"seq {L} not divisible by chunk {Q}"
    nc = L // Q

    z, xbc, dt_raw = _split_proj(mm(p["in_proj"], x, "in_proj"), cfg)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs, Bmat, Cmat = jnp.split(xbc, [d_in, d_in + N], axis=-1)
    xs = xs.reshape(Bsz, L, nh, P)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,L,nh)
    A = -jnp.exp(p["A_log"])                                          # (nh,)

    # chunk views: (nc, B, Q, ...)
    def chunkify(t):
        return jnp.moveaxis(t.reshape(Bsz, nc, Q, *t.shape[2:]), 0, 1)
    xs_c, B_c, C_c = chunkify(xs), chunkify(Bmat), chunkify(Cmat)
    dt_c = chunkify(dt)

    def chunk_step(state, inp):
        xq, bq, cq, dtq = inp               # (B,Q,...)
        return _ssd_chunk(state, xq, bq, cq, dtq, A)

    state0 = jnp.zeros((Bsz, nh, P, N), jnp.float32)
    # remat the chunk body: its (B, Q, Q, nh) f32 intra-chunk tensors
    # otherwise persist as backward residuals for EVERY chunk (~70 GB/dev
    # for jamba train_4k).
    _, ys = jax.lax.scan(jax.checkpoint(chunk_step), state0,
                         (xs_c, B_c, C_c, dt_c))
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, L, nh, P)
    y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(Bsz, L, d_in).astype(x.dtype)
    return mm(p["out_proj"], _gated_norm(y, z, p["norm_scale"]), "out_proj")


# ------------------------------------------------------------ decode -------

def init_ssm_cache(cfg: ModelConfig, batch: int, n_layers: int):
    d_in, nh, N, P = ssm_dims(cfg)
    conv_ch = d_in + 2 * N
    return {
        "conv": jnp.zeros((n_layers, batch, cfg.ssm_conv_width - 1, conv_ch),
                          dtype_of(cfg)),
        "state": jnp.zeros((n_layers, batch, nh, P, N), jnp.float32),
    }


def decode_ssm(p, x, conv_state, ssm_state, cfg: ModelConfig,
               dense_fn=None):
    """One-token decode. x (B, 1, D); conv_state (B, W-1, C);
    ssm_state (B, nh, P, N). Returns (y, new_conv, new_state)."""
    mm = dense_fn or (lambda w, v, name: v @ w)
    Bsz = x.shape[0]
    d_in, nh, N, P = ssm_dims(cfg)
    z, xbc, dt_raw = _split_proj(mm(p["in_proj"], x[:, 0], "in_proj"), cfg)
    window = jnp.concatenate([conv_state, xbc[:, None, :]], axis=1)
    conv = jnp.sum(window * p["conv_w"][None], axis=1) + p["conv_b"]
    xbc_t = jax.nn.silu(conv)
    xs, Bv, Cv = jnp.split(xbc_t, [d_in, d_in + N], axis=-1)
    xs = xs.reshape(Bsz, nh, P).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,nh)
    A = -jnp.exp(p["A_log"])
    da = jnp.exp(dt * A)                                              # (B,nh)
    contrib = jnp.einsum("bh,bn,bhp->bhpn", dt, Bv.astype(jnp.float32), xs)
    new_state = ssm_state * da[:, :, None, None] + contrib
    y = jnp.einsum("bn,bhpn->bhp", Cv.astype(jnp.float32), new_state)
    y = y + xs * p["D"][None, :, None]
    y = y.reshape(Bsz, 1, d_in).astype(x.dtype)
    out = mm(p["out_proj"], _gated_norm(y, z[:, None, :], p["norm_scale"]),
             "out_proj")
    return out, window[:, 1:], new_state


def prefill_ssm(p, x, conv_state, ssm_state, n_valid, cfg: ModelConfig,
                dense_fn=None):
    """Chunked cache-filling prefill: C prompt tokens through the exact
    decode recurrence in one step.

    x (B, C, D); conv_state (B, W-1, Ch); ssm_state (B, nh, P, N);
    n_valid (B,) in [0, C] real tokens per slot. The chunk runs an inner
    `lax.scan` of `decode_ssm` token steps — bit-identical state/conv
    trajectories to n_valid sequential decode calls (the chunked-matmul
    training form reorders the f32 accumulation) — with per-slot validity
    gating so ragged tail chunks and idle slots leave their caches
    untouched. Returns (y (B, C, D), new_conv, new_state).
    """
    C = x.shape[1]

    def step(carry, inp):
        conv, state = carry
        xt, t = inp                                    # (B, 1, D), scalar
        y, new_conv, new_state = decode_ssm(p, xt, conv, state, cfg,
                                            dense_fn=dense_fn)
        keep = (t < n_valid)                           # (B,)
        conv = jnp.where(keep[:, None, None], new_conv, conv)
        state = jnp.where(keep[:, None, None, None], new_state, state)
        return (conv, state), y

    xs = jnp.moveaxis(x[:, :, None, :], 1, 0)          # (C, B, 1, D)
    (conv, state), ys = jax.lax.scan(
        step, (conv_state, ssm_state), (xs, jnp.arange(C)))
    y = jnp.moveaxis(ys[:, :, 0, :], 0, 1)             # (B, C, D)
    return y, conv, state


#: Equivalence contract of the parallel-form prefill: max |logit delta|
#: against the sequential decode recurrence over a full prompt, keyed by
#: activation dtype. The parallel chunk reassociates the f32 state
#: accumulation (exp(cum_i - cum_j) segment products instead of a running
#: product), so results are tolerance-equal, not bitwise. Guarded by
#: tests/test_parallel_prefill.py and benchmarks/serve_engine_bench.py;
#: cfg.prefill_exact=True restores bit-identity at C x the weight traffic.
#: bf16 headroom: logits of O(10) magnitude have ~0.0625 ulp, and the two
#: accumulation orders legitimately land a few ulps apart (0.25 observed
#: on the reduced mamba2 config).
PARALLEL_PREFILL_ATOL = {"float32": 2e-4, "bfloat16": 0.5}


def prefill_ssm_parallel(p, x, conv_state, ssm_state, n_valid,
                         cfg: ModelConfig, dense_fn=None):
    """Parallel-form (SSD) chunked prefill: C prompt tokens with ONE read
    of the in/out projections, instead of the C reads the exact per-token
    recurrence (prefill_ssm) pays.

    Same signature and cache semantics as prefill_ssm: x (B, C, D);
    conv_state (B, W-1, Ch); ssm_state (B, nh, P, N); n_valid (B,) in
    [0, C]. The in-projection runs as one batched matmul over the whole
    chunk (through the stacked joint tables when dense_fn is hooked — the
    packed weights stream from HBM once per chunk), the causal conv slides
    over [conv_state ++ chunk], and the recurrence is evaluated in the
    training-style matrix form (_ssd_chunk) seeded with the carried
    state. Invalid positions (>= n_valid, incl. idle slots with 0) are
    masked by zeroing dt — an exact identity on the state — and the new
    conv window is gathered at each slot's n_valid cursor, so ragged
    tails and idle slots leave their caches untouched, exactly like the
    exact path. Numerics: tolerance-equal to sequential decode
    (PARALLEL_PREFILL_ATOL), not bitwise — the f32 accumulation is
    reassociated. Returns (y (B, C, D), new_conv, new_state).
    """
    mm = dense_fn or (lambda w, v, name: v @ w)
    Bsz, C, _ = x.shape
    d_in, nh, N, P = ssm_dims(cfg)
    n_valid = jnp.asarray(n_valid, jnp.int32)

    z, xbc, dt_raw = _split_proj(mm(p["in_proj"], x, "in_proj"), cfg)
    # causal conv over the carried prefix: window[t + i] for i in [0, W)
    # reproduces decode's per-token ring window at position t
    W = p["conv_w"].shape[0]
    window = jnp.concatenate([conv_state, xbc.astype(conv_state.dtype)],
                             axis=1)                   # (B, W-1+C, Ch)
    conv = sum(window[:, i:i + C, :] * p["conv_w"][i] for i in range(W))
    xbc_t = jax.nn.silu(conv + p["conv_b"])
    # new conv window ends at the last VALID token: indices
    # n_valid .. n_valid+W-2 of `window` (n_valid=0 -> conv_state back
    # unchanged; gathers never read past xbc[n_valid-1], so invalid-slot
    # garbage can't leak into the cache)
    gather = n_valid[:, None] + jnp.arange(W - 1)[None, :]     # (B, W-1)
    new_conv = jnp.take_along_axis(window, gather[:, :, None], axis=1)

    xs, Bmat, Cmat = jnp.split(xbc_t, [d_in, d_in + N], axis=-1)
    xs = xs.reshape(Bsz, C, nh, P)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    valid = jnp.arange(C)[None, :] < n_valid[:, None]          # (B, C)
    dt = jnp.where(valid[:, :, None], dt, 0.0)   # dt=0: state identity
    A = -jnp.exp(p["A_log"])
    new_state, y = _ssd_chunk(ssm_state, xs, Bmat, Cmat, dt, A)
    y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(Bsz, C, d_in).astype(x.dtype)
    out = mm(p["out_proj"], _gated_norm(y, z, p["norm_scale"]), "out_proj")
    return out, new_conv, new_state
