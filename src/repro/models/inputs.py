"""Input specs: ShapeDtypeStruct stand-ins for the dry-run (no allocation)
and concrete random batches for smoke tests / examples.

`decode_*` shapes feed `serve_step` (one new token against a cache of
seq_len); `train_*`/`prefill_*` feed full-sequence steps.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig, ShapeSpec
from .layers import dtype_of


def train_batch_spec(cfg: ModelConfig, batch: int, seq: int) -> Dict:
    spec = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }
    if cfg.is_encdec:
        spec["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.encoder_seq, cfg.d_model), dtype_of(cfg))
    if cfg.frontend == "vision_stub":
        spec["frontend"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_patches, cfg.d_model), dtype_of(cfg))
    return spec


def decode_token_spec(cfg: ModelConfig, batch: int) -> Dict:
    return {"token": jax.ShapeDtypeStruct((batch, 1), jnp.int32)}


def make_train_batch(cfg: ModelConfig, batch: int, seq: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    out = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32),
        "labels": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32),
    }
    if cfg.is_encdec:
        out["frames"] = jnp.asarray(
            rng.normal(0, 1, (batch, cfg.encoder_seq, cfg.d_model)),
            dtype_of(cfg))
    if cfg.frontend == "vision_stub":
        out["frontend"] = jnp.asarray(
            rng.normal(0, 1, (batch, cfg.n_patches, cfg.d_model)),
            dtype_of(cfg))
    return out


def make_decode_token(cfg: ModelConfig, batch: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, 1)), jnp.int32)
