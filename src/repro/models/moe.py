"""Mixture-of-Experts with sort-based capacity dispatch.

Design goals (in order):
  1. Static shapes (pjit/dry-run friendly).
  2. FLOPs proportional to top_k/n_experts (roofline-faithful) — the
     dispatch is scatter/gather, NOT a (T, E, C) einsum, so the compiled
     compute term reflects the real expert math.
  3. Expert-parallel shardable: the (E, C, D) buffers carry the expert dim
     explicitly; the sharding rules put E (or the FFN dim) on the model
     axis and XLA inserts the all-to-all-style collectives.

Tokens beyond an expert's capacity C = ceil(T * top_k / E * cap_factor)
are dropped (standard Switch behaviour); the combine step re-normalizes
gates over surviving assignments.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import apply_mlp, dtype_of, init_mlp
from repro.runtime.act_sharding import constrain_any


def init_moe(cfg: ModelConfig, key):
    dt = dtype_of(cfg)
    kr, ke = jax.random.split(key)
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    s = d ** -0.5
    p = {"router": (jax.random.normal(kr, (d, E)) * s).astype(jnp.float32)}
    keys = jax.random.split(ke, 3)
    if cfg.mlp_type in ("swiglu", "geglu"):
        p["w_gate"] = (jax.random.normal(keys[0], (E, d, f)) * s).astype(dt)
        p["w_up"] = (jax.random.normal(keys[1], (E, d, f)) * s).astype(dt)
    else:
        p["w_up"] = (jax.random.normal(keys[1], (E, d, f)) * s).astype(dt)
    p["w_down"] = (jax.random.normal(keys[2], (E, f, d))
                   * f ** -0.5).astype(dt)
    return p


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    """Per-expert token slots. Rounded up to a multiple of 8 (sublane
    alignment) but never beyond the total assignment count: an expert can
    receive at most every (token, choice) pair, so a tiny decode batch
    (n_tokens * top_k < 8) allocates exactly that many slots instead of 8
    phantom ones per expert."""
    assignments = n_tokens * cfg.top_k
    c = int(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return min(max(8, -(-c // 8) * 8), assignments)


def _expert_matmul(w, x, name, expert_fn=None):
    """Per-expert contraction x (..., E, C, K) @ w (E, K, F) ->
    (..., E, C, F). ``expert_fn`` (sparse_linear.StackedKernelTables
    dense_fn().expert) reroutes it through one joint-kernel call per
    packed expert slice — the DB-PIM serving path for grouped expert
    stacks."""
    if expert_fn is not None:
        return expert_fn(w, x, name)
    return jnp.einsum("...eck,ekf->...ecf", x, w)


def _group_dispatch(xt, gate_idx, gate_vals, E: int, C: int):
    """Per-group dispatch (Tg tokens). Returns (xin (E,C,D), slot, w).

    vmapped over groups: the scatter then carries an explicit batch dim
    aligned with the token sharding, so GSPMD partitions it instead of
    replicating (the flat global scatter forced involuntary full
    rematerialization — see EXPERIMENTS.md §Perf iteration 2)."""
    Tg, D = xt.shape
    K = gate_idx.shape[-1]
    flat_e = gate_idx.reshape(-1)                          # (Tg*K,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos_in_e = (jnp.cumsum(onehot, axis=0) - onehot)       # exclusive rank
    rank = jnp.take_along_axis(pos_in_e, flat_e[:, None], 1)[:, 0]
    keep = rank < C
    # dropped assignments get an out-of-bounds slot: scatter mode="drop"
    # discards them, gather mode="fill" returns zeros.
    slot = jnp.where(keep, flat_e * C + rank, E * C)
    buf = jnp.zeros((E * C, D), dtype=xt.dtype)
    updates = jnp.broadcast_to(xt[:, None, :], (Tg, K, D)).reshape(Tg * K, D)
    buf = buf.at[slot].set(updates, mode="drop", unique_indices=True)
    w = gate_vals * keep.reshape(Tg, K)
    return buf.reshape(E, C, D), slot, w


def _group_combine(out_ec, slot, w, Tg: int):
    """Inverse gather for one group. out_ec (E, C, D) -> (Tg, D)."""
    E, C, D = out_ec.shape
    flat = out_ec.reshape(E * C, D)
    gathered = jnp.take(flat, slot, axis=0, mode="fill",
                        fill_value=0).reshape(Tg, -1, D)
    return jnp.einsum("tkd,tk->td", gathered, w.astype(out_ec.dtype))


def apply_moe(p, x, cfg: ModelConfig, expert_fn=None,
              per_position: bool = False):
    """x (B, S, D) -> (B, S, D), plus aux losses dict.

    Grouped dispatch: tokens are split into G = B groups (sequences) with
    per-group capacity; dispatch/combine are vmapped so every scatter/
    gather is local to a data shard. Expert compute runs as one batched
    einsum over (G, E, C, D) with the FFN dim tensor-parallel — or, when
    ``expert_fn`` is hooked (stacked joint-sparse serving), as one
    DB-PIM kernel call per packed expert slice.

    per_position=True (chunked prefill) groups by SEQUENCE POSITION
    instead: G = S groups of the B slot tokens at that position, with
    capacity(cfg, B) — exactly the pool one serving decode step routes
    against, so a C-token chunk reproduces C decode steps' expert
    assignments whenever capacity covers all assignments (it always does
    at decode-batch scale, where capacity() clamps to B * top_k).
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    if per_position:
        G, Tg = S, B
        xg = jnp.swapaxes(x, 0, 1)                         # (S, B, D)
    elif S >= 64:
        G, Tg = B, S
        xg = x
    else:
        # Decode (S small): one flat group — per-sequence groups of 1
        # token would pad every expert's capacity to the minimum and
        # waste E*C_min slots per token (512x for arctic).
        G, Tg = 1, B * S
        xg = x.reshape(G, Tg, D)
    C = capacity(cfg, Tg)

    logits = (xg.astype(jnp.float32) @ p["router"])        # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)          # (G, Tg, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    xin, slot, w = jax.vmap(
        lambda a, b, c: _group_dispatch(a, b, c, E, C))(xg, gate_idx,
                                                        gate_vals)
    # E-sharded (expert parallel) when E divides the model axis (arctic,
    # jamba), else tokens-only (mixtral keeps E whole, F tensor-parallel).
    xin = constrain_any(xin, ("dp", "tp", None, None),
                        ("dp", None, None, None))          # (G, E, C, D)

    out = _expert_ffn_grouped(p, xin, cfg, expert_fn)      # (G, E, C, D)
    out = constrain_any(out, ("dp", "tp", None, None),
                        ("dp", None, None, None))

    yg = jax.vmap(lambda a, b, c: _group_combine(a, b, c, Tg))(out, slot, w)

    # load-balancing auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(gate_idx[..., 0], E, dtype=jnp.float32),
                  axis=(0, 1))
    keep_frac = jnp.mean((slot < E * C).astype(jnp.float32))
    aux = {"load_balance": E * jnp.sum(me * ce),
           "dropped_frac": 1.0 - keep_frac}
    y = jnp.swapaxes(yg, 0, 1) if per_position else yg.reshape(B, S, D)
    return y, aux


def _expert_ffn_grouped(p, xin, cfg: ModelConfig, expert_fn=None):
    """xin (G, E, C, D) -> (G, E, C, D); experts sharded over `model`
    when E divides it, otherwise the FFN dim is tensor-parallel."""
    mm = lambda w, x, name: _expert_matmul(w, x, name, expert_fn)
    cst = lambda t: constrain_any(t, ("dp", "tp", None, None),
                                  ("dp", None, None, "tp"))
    if cfg.mlp_type in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp_type == "swiglu" else \
            (lambda v: jax.nn.gelu(v, approximate=True))
        g = act(cst(mm(p["w_gate"], xin, "moe/w_gate")))
        h = g * cst(mm(p["w_up"], xin, "moe/w_up"))
    else:
        h = jax.nn.gelu(cst(mm(p["w_up"], xin, "moe/w_up")),
                        approximate=True)
    return mm(p["w_down"], h, "moe/w_down")


def apply_moe_block(p, x, cfg: ModelConfig, dense_fn=None,
                    per_position: bool = False):
    """MoE (+ optional arctic dense residual MLP in parallel).

    ``dense_fn`` is the per-layer DB-PIM hook
    (StackedKernelTables.dense_fn(slices) on the serving path): its
    ``expert`` attribute serves the grouped expert projections through
    the joint kernel, and the hook itself serves the arctic dense
    residual MLP. Plain None keeps every matmul dense. per_position
    groups capacity dispatch by sequence position (chunked prefill —
    see apply_moe)."""
    y, aux = apply_moe(p, x, cfg,
                       expert_fn=getattr(dense_fn, "expert", None),
                       per_position=per_position)
    if cfg.dense_residual:
        y = y + apply_mlp(p["dense_mlp"], x, cfg, dense_fn)
    return y, aux


def init_moe_block(cfg: ModelConfig, key):
    p = init_moe(cfg, key)
    if cfg.dense_residual:
        p["dense_mlp"] = init_mlp(cfg, jax.random.fold_in(key, 7),
                                  cfg.d_model, cfg.d_ff)
    return p
