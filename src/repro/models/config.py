"""Model configuration for the 10 assigned architectures.

One frozen dataclass drives parameter creation, the forward pass, sharding
rules, DB-PIM sparsity instrumentation, and the dry-run input specs.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | vlm | audio | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None        # default d_model // n_heads
    qk_norm: bool = False                 # qwen3
    mlp_type: str = "swiglu"              # swiglu | geglu | gelu
    norm_type: str = "rmsnorm"            # rmsnorm | layernorm
    norm_plus_one: bool = False           # gemma's (1 + w) RMSNorm
    embed_scale: bool = False             # gemma scales embeddings by sqrt(d)
    rope_theta: float = 10000.0
    rope_pct: float = 1.0                 # stablelm partial rotary
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 0
    dense_residual: bool = False          # arctic: dense FFN + MoE in parallel
    moe_every: int = 1                    # jamba: MoE on every 2nd layer
    capacity_factor: float = 1.25

    # SSM (mamba2 / jamba)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    attn_period: int = 0                  # jamba: 1 attn layer per `period`
    attn_index: int = 0                   # position of attn inside the period

    # attention windowing (mixtral SWA)
    window: int = 0                       # 0 = full causal attention

    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0                  # stub frontend output length
    frontend: str = "none"                # none | audio_stub | vision_stub
    n_patches: int = 0                    # vlm stub patch count

    dtype: str = "bfloat16"

    # DB-PIM integration
    dbpim: bool = False                   # FTA-quantized projections
    dbpim_value_sparsity: float = 0.6
    dbpim_mode: str = "joint"             # dense | value | bit | joint:
                                          # which sparsity level(s) the
                                          # serving kernels exploit

    # serving prefill: False (default) lets SSM chunked prefill use the
    # parallel SSD form — one in/out projection read per chunk instead of
    # per token, tolerance-equivalent to sequential decode (models.ssm.
    # PARALLEL_PREFILL_ATOL); True forces the exact per-token recurrence
    # (bit-identical to decode, C x the projection traffic)
    prefill_exact: bool = False

    # training
    remat: bool = True
    remat_policy: str = "full"    # full | dots (save matmul outputs)

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.hd

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.hd

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic support: SSM, hybrid, or sliding-window attention."""
        return self.family in ("ssm", "hybrid") or self.window > 0

    def serving_capabilities(self):
        """What the serving stack supports for this config, derived from
        the segment layout (models.segments.ServingCapabilities): segment
        descriptors, packable projections, prefill modes. Single source
        of truth — the supports_* properties below are thin shims over
        it, kept for callers written against the old boolean API."""
        from .segments import serving_capabilities
        return serving_capabilities(self)

    @property
    def supports_stacked_tables(self) -> bool:
        """Deprecated shim — use serving_capabilities().stacked_tables.
        True for every family since the segmented per-kind layer scans:
        each segment (attention / SSM / MoE / cross-attention run) packs
        independently and rides its own scan, so hybrid periods and
        enc-dec stacks serve through the joint kernel too."""
        return self.serving_capabilities().stacked_tables

    @property
    def supports_chunked_prefill(self) -> bool:
        """Deprecated shim — use serving_capabilities().chunked_prefill.
        True whenever attention is full-causal (window == 0): sliding-
        window ring buffers overwrite slots within a chunk, which only a
        sequential walk reproduces. MoE chunks dispatch expert capacity
        per chunk position (each position competes exactly like one
        decode step's token pool), and hybrid / enc-dec chunks walk the
        segment list — so those families chunk too."""
        return self.serving_capabilities().chunked_prefill

    @property
    def supports_parallel_prefill(self) -> bool:
        """Deprecated shim — use serving_capabilities().parallel_prefill.
        True when an SSM segment exists (ssm / hybrid families): its
        chunk can use the parallel SSD form, reading the stacked in/out
        projections ONCE per chunk instead of per token
        (models.ssm.prefill_ssm_parallel). Attention chunks already
        project the whole chunk in one matmul."""
        return self.serving_capabilities().parallel_prefill

    def scaled(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode
    microbatches: int = 1


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def param_count(cfg: ModelConfig) -> int:
    """Analytic parameter count (embeddings + per-layer weights)."""
    d, f = cfg.d_model, cfg.d_ff
    attn = (d * cfg.q_dim + 2 * d * cfg.kv_dim + cfg.q_dim * d) \
        if cfg.n_heads else 0
    if cfg.mlp_type in ("swiglu", "geglu"):
        mlp = 3 * d * f
    else:
        mlp = 2 * d * f
    per_layer = 0
    if cfg.family == "ssm":
        d_in = cfg.ssm_expand * d
        nh = d_in // cfg.ssm_head_dim
        conv_ch = d_in + 2 * cfg.ssm_state
        per_layer = (d * (2 * d_in + 2 * cfg.ssm_state + nh)
                     + conv_ch * cfg.ssm_conv_width + 2 * nh + d_in
                     + d_in * d) * cfg.n_layers
    elif cfg.family == "hybrid":
        n_attn = cfg.n_layers // cfg.attn_period
        n_ssm = cfg.n_layers - n_attn
        d_in = cfg.ssm_expand * d
        nh = d_in // cfg.ssm_head_dim
        ssm_l = (d * (2 * d_in + 2 * cfg.ssm_state + nh)
                 + (d_in + 2 * cfg.ssm_state) * cfg.ssm_conv_width
                 + 2 * nh + d_in + d_in * d)
        n_moe = cfg.n_layers // cfg.moe_every if cfg.n_experts else 0
        n_dense = cfg.n_layers - n_moe
        per_layer = (n_attn * attn + n_ssm * ssm_l
                     + n_moe * cfg.n_experts * mlp + n_dense * mlp)
    elif cfg.n_experts:
        moe = cfg.n_experts * mlp + d * cfg.n_experts
        if cfg.dense_residual:
            moe += mlp
        per_layer = (attn + moe) * cfg.n_layers
    else:
        per_layer = (attn + mlp) * cfg.n_layers
    emb = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    enc = 0
    if cfg.is_encdec:
        enc = cfg.encoder_layers * (attn + mlp)
        per_layer += cfg.n_layers * (d * cfg.q_dim + 2 * d * cfg.kv_dim
                                     + cfg.q_dim * d)   # cross-attention
    return per_layer + emb + enc


def active_param_count(cfg: ModelConfig) -> int:
    """Parameters touched per token (MoE: top_k of n_experts)."""
    if not cfg.n_experts:
        return param_count(cfg)
    full = param_count(cfg)
    d, f = cfg.d_model, cfg.d_ff
    mlp = (3 if cfg.mlp_type in ("swiglu", "geglu") else 2) * d * f
    if cfg.family == "hybrid":
        n_moe = cfg.n_layers // cfg.moe_every
    else:
        n_moe = cfg.n_layers
    inactive = n_moe * (cfg.n_experts - cfg.top_k) * mlp
    return full - inactive
