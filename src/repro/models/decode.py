"""Serving path: cache init, prefill, and single-token decode for every
family (dense/MoE/VLM, SSM, hybrid, enc-dec).

Decode scans over the stacked layer params with the per-layer cache slices
as scan inputs/outputs, so the HLO is O(1) in depth. Caches are static-
shape; SWA archs allocate only the window (ring buffer).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from .config import ModelConfig
from .layers import (apply_mlp, apply_norm, embed_tokens, logits_from_hidden)
from .transformer import _sinusoidal, encode


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               enc_out: Optional[jnp.ndarray] = None) -> Dict:
    cache: Dict = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.family == "ssm":
        cache["ssm"] = ssm_mod.init_ssm_cache(cfg, batch, cfg.n_layers)
    elif cfg.family == "hybrid":
        n_periods = cfg.n_layers // cfg.attn_period
        cache["attn"] = attn_mod.init_cache(cfg, batch, max_len, n_periods)
        cache["ssm"] = ssm_mod.init_ssm_cache(
            cfg, batch, n_periods * (cfg.attn_period - 1))
        # reshape ssm stacks to (n_periods, period-1, ...)
        cache["ssm"] = jax.tree_util.tree_map(
            lambda t: t.reshape((n_periods, cfg.attn_period - 1)
                                + t.shape[1:]), cache["ssm"])
    else:
        cache["attn"] = attn_mod.init_cache(cfg, batch, max_len, cfg.n_layers)
    if cfg.is_encdec and enc_out is not None:
        cache["enc_out"] = enc_out
    return cache


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------

def decode_step(params, cache, token, cfg: ModelConfig, tables=None):
    """token (B, 1) int32 -> (logits (B, 1, V), new cache).

    tables: sparsity.sparse_linear.StackedKernelTables — uniform-MAXB
    joint-sparse projection packs whose arrays ride the layer scan as xs
    (next to the per-layer cache slices), so every decode-step projection
    runs the DB-PIM kernel. Supported for the dense-attention (incl. MoE:
    grouped expert packs dispatch one kernel call per expert slice) and
    SSM family scans; None keeps the plain matmuls.
    """
    if tables is not None and not cfg.supports_stacked_tables:
        raise ValueError(f"stacked kernel tables are not supported for "
                         f"{cfg.name} (mixed-sublayer hybrid/enc-dec "
                         f"scan)")

    def layer_mm(slices):
        return tables.dense_fn(slices) if tables is not None else None

    txs = tables.arrays if tables is not None else None
    pos = cache["pos"]
    x = embed_tokens(params["embed"], token, cfg)
    if cfg.rope_pct == 0:
        # sinusoidal position embedding at position `pos` (scalar, or (B,)
        # when slots decode at different depths)
        B = token.shape[0]
        d = cfg.d_model
        posv = attn_mod._per_slot_pos(pos, B).astype(jnp.float32)
        dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
        ang = posv[:, None] / (10000.0 ** (dim / d))               # (B, d/2)
        pe = jnp.zeros((B, d), jnp.float32)
        pe = pe.at[:, 0::2].set(jnp.sin(ang)).at[:, 1::2].set(jnp.cos(ang))
        x = x + pe[:, None].astype(x.dtype)

    new_cache = dict(cache)

    if cfg.family == "ssm":
        def step(h, inp):
            p, conv, state, slices = inp
            hn = apply_norm(p["norm1"], h, cfg)
            y, new_conv, new_state = ssm_mod.decode_ssm(
                p["ssm"], hn, conv, state, cfg, dense_fn=layer_mm(slices))
            return h + y, (new_conv, new_state)
        x, (convs, states) = jax.lax.scan(
            step, x, (params["blocks"], cache["ssm"]["conv"],
                      cache["ssm"]["state"], txs))
        new_cache["ssm"] = {"conv": convs, "state": states}

    elif cfg.family == "hybrid":
        def step(h, inp):
            p, ck, cv, convs, states = inp
            new_convs, new_states = [], []
            ssm_i = 0
            for i in range(cfg.attn_period):
                sub = p[f"sub{i}"]
                hn = apply_norm(sub["norm1"], h, cfg)
                if i == cfg.attn_index:
                    y, ck, cv = attn_mod.decode_attention(
                        sub["attn"], hn, ck, cv, pos, cfg)
                else:
                    y, nc, ns = ssm_mod.decode_ssm(
                        sub["ssm"], hn, convs[ssm_i], states[ssm_i], cfg)
                    new_convs.append(nc)
                    new_states.append(ns)
                    ssm_i += 1
                h = h + y
                hn2 = apply_norm(sub["norm2"], h, cfg)
                if "moe" in sub:
                    y2, _ = moe_mod.apply_moe_block(sub["moe"], hn2, cfg)
                else:
                    y2 = apply_mlp(sub["mlp"], hn2, cfg)
                h = h + y2
            return h, (ck, cv, jnp.stack(new_convs), jnp.stack(new_states))
        x, (cks, cvs, convs, states) = jax.lax.scan(
            step, x, (params["periods"], cache["attn"]["k"],
                      cache["attn"]["v"], cache["ssm"]["conv"],
                      cache["ssm"]["state"]))
        new_cache["attn"] = {"k": cks, "v": cvs, "pos": pos + 1}
        new_cache["ssm"] = {"conv": convs, "state": states}

    elif cfg.is_encdec:
        enc_out = cache["enc_out"]
        def step(h, inp):
            p, ck, cv = inp
            hn = apply_norm(p["norm1"], h, cfg)
            y, ck, cv = attn_mod.decode_attention(p["attn"], hn, ck, cv,
                                                  pos, cfg)
            h = h + y
            hx = apply_norm(p["norm_x"], h, cfg)
            h = h + attn_mod.cross_attention(p["xattn"], hx, enc_out, cfg)
            h = h + apply_mlp(p["mlp"], apply_norm(p["norm2"], h, cfg), cfg)
            return h, (ck, cv)
        x, (cks, cvs) = jax.lax.scan(
            step, x, (params["blocks"], cache["attn"]["k"],
                      cache["attn"]["v"]))
        new_cache["attn"] = {"k": cks, "v": cvs, "pos": pos + 1}

    else:
        def step(h, inp):
            p, ck, cv, slices = inp
            mm = layer_mm(slices)
            hn = apply_norm(p["norm1"], h, cfg)
            y, ck, cv = attn_mod.decode_attention(p["attn"], hn, ck, cv,
                                                  pos, cfg, dense_fn=mm)
            h = h + y
            hn2 = apply_norm(p["norm2"], h, cfg)
            if cfg.n_experts:
                y2, _ = moe_mod.apply_moe_block(p["moe"], hn2, cfg,
                                                dense_fn=mm)
            else:
                y2 = apply_mlp(p["mlp"], hn2, cfg, dense_fn=mm)
            return h + y2, (ck, cv)
        x, (cks, cvs) = jax.lax.scan(
            step, x, (params["blocks"], cache["attn"]["k"],
                      cache["attn"]["v"], txs))
        new_cache["attn"] = {"k": cks, "v": cvs, "pos": pos + 1}

    new_cache["pos"] = pos + 1
    x = apply_norm(params["final_norm"], x, cfg)
    return logits_from_hidden(params["embed"], x, cfg), new_cache


def decode_chunk(params, cache, tokens, n_valid, cfg: ModelConfig,
                 tables=None):
    """Chunked cache-filling prefill: C prompt tokens per slot, one step.

    tokens (B, C) int32; n_valid (B,) int32 in [0, C] — the number of real
    prompt tokens per slot this chunk (ragged tail chunks and idle slots
    pass fewer/0; their cache slices are left untouched). cache["pos"] is
    the per-slot fill depth ((B,) vector, or a scalar broadcast).

    Returns (logits (B, 1, V) of each slot's LAST VALID token — the
    first-generated-token logits when the chunk completes a prompt — and
    the cache advanced by n_valid per slot). The chunk is one fixed-shape
    device step: time-to-first-token is ceil(P/C) steps instead of P, and
    the unembedding runs once per chunk instead of once per prompt token.

    Per-token math vs running `decode_step` n_valid times: bit-identical
    for attention families and for SSM with cfg.prefill_exact=True. The
    default SSM path is the parallel SSD form (ssm.prefill_ssm_parallel)
    — the in/out projections are read ONCE per chunk instead of once per
    token, at the cost of tolerance-level (ssm.PARALLEL_PREFILL_ATOL)
    instead of bitwise equivalence.

    Like decode_step, `tables` threads the uniform-MAXB joint-sparse packs
    through the layer scan, so prompt chunks run the DB-PIM kernel too.
    """
    if not cfg.supports_chunked_prefill:
        raise ValueError(f"chunked prefill is not supported for {cfg.name} "
                         f"(windowed/MoE/hybrid/enc-dec); use stepwise "
                         f"prefill")
    if tables is not None and not cfg.supports_stacked_tables:
        raise ValueError(f"stacked kernel tables are not supported for "
                         f"{cfg.name}")
    B, C = tokens.shape
    pos = attn_mod._per_slot_pos(cache["pos"], B)
    n_valid = jnp.asarray(n_valid, jnp.int32)

    def layer_mm(slices):
        return tables.dense_fn(slices) if tables is not None else None

    txs = tables.arrays if tables is not None else None
    x = embed_tokens(params["embed"], tokens, cfg)
    new_cache = dict(cache)

    if cfg.family == "ssm":
        ssm_prefill = (ssm_mod.prefill_ssm if cfg.prefill_exact
                       else ssm_mod.prefill_ssm_parallel)

        def step(h, inp):
            p, conv, state, slices = inp
            hn = apply_norm(p["norm1"], h, cfg)
            y, new_conv, new_state = ssm_prefill(
                p["ssm"], hn, conv, state, n_valid, cfg,
                dense_fn=layer_mm(slices))
            return h + y, (new_conv, new_state)
        x, (convs, states) = jax.lax.scan(
            step, x, (params["blocks"], cache["ssm"]["conv"],
                      cache["ssm"]["state"], txs))
        new_cache["ssm"] = {"conv": convs, "state": states}
    else:
        def step(h, inp):
            p, ck, cv, slices = inp
            mm = layer_mm(slices)
            hn = apply_norm(p["norm1"], h, cfg)
            y, ck, cv = attn_mod.prefill_attention(
                p["attn"], hn, ck, cv, pos, n_valid, cfg, dense_fn=mm)
            h = h + y
            hn2 = apply_norm(p["norm2"], h, cfg)
            y2 = apply_mlp(p["mlp"], hn2, cfg, dense_fn=mm)
            return h + y2, (ck, cv)
        x, (cks, cvs) = jax.lax.scan(
            step, x, (params["blocks"], cache["attn"]["k"],
                      cache["attn"]["v"], txs))
        new_cache["attn"] = {"k": cks, "v": cvs, "pos": pos + n_valid}

    new_cache["pos"] = pos + n_valid
    x = apply_norm(params["final_norm"], x, cfg)
    last = jnp.clip(n_valid - 1, 0, C - 1)
    x_last = x[jnp.arange(B), last][:, None]                  # (B, 1, D)
    return logits_from_hidden(params["embed"], x_last, cfg), new_cache


# ---------------------------------------------------------------------------
# Per-slot cache surgery (the serving engine's slot scheduler)
# ---------------------------------------------------------------------------

def _select_batch(mask, new, old, axis: int):
    shape = [1] * new.ndim
    shape[axis] = mask.shape[0]
    return jnp.where(mask.reshape(shape), new, old)


def merge_slots(new_cache, old_cache, keep_mask, cfg: ModelConfig):
    """Per-slot cache select: slots where keep_mask (B,) is True take the
    updated cache, the rest keep their previous contents and position.

    This is what lets ONE fixed-shape decode step serve a batch where
    only some slots are actively decoding (others are mid-prefill, free,
    or draining): the step computes updates for every slot, and the merge
    discards the writes of inactive ones. Positions come out as (B,)
    vectors regardless of input shape. Encoder output (enc-dec) is shared
    across the batch and passes through unchanged."""
    B = keep_mask.shape[0]

    def sel_pos(new, old):
        return jnp.where(keep_mask, attn_mod._per_slot_pos(new, B),
                         attn_mod._per_slot_pos(old, B))

    out = dict(new_cache)
    out["pos"] = sel_pos(new_cache["pos"], old_cache["pos"])
    if "attn" in new_cache:
        a = dict(new_cache["attn"])
        axis = 1                       # (L, B, A, Hkv, hd) / hybrid periods
        for kname in ("k", "v"):
            a[kname] = _select_batch(keep_mask, new_cache["attn"][kname],
                                     old_cache["attn"][kname], axis)
        if "pos" in a:
            a["pos"] = sel_pos(new_cache["attn"]["pos"],
                               old_cache["attn"]["pos"])
        out["attn"] = a
    if "ssm" in new_cache:
        axis = 2 if cfg.family == "hybrid" else 1
        out["ssm"] = jax.tree_util.tree_map(
            lambda n, o: _select_batch(keep_mask, n, o, axis),
            new_cache["ssm"], old_cache["ssm"])
    return out


def reset_slots(cache, slot_mask, cfg: ModelConfig):
    """Zero the KV/SSM cache slices and position of the slots where
    slot_mask (B,) is True — the admission step before a freed slot takes
    a new request. Without this, a refilled slot's attention would still
    mask correctly (pos restarts at 0) but SSM states and ring buffers
    carry the PREVIOUS request's activations into the new one. Encoder
    output (enc-dec) is shared and not per-request; callers that rotate
    enc-dec requests must swap it themselves."""
    zeroed = {}
    for key, val in cache.items():
        if key == "enc_out":
            zeroed[key] = val
        else:
            zeroed[key] = jax.tree_util.tree_map(jnp.zeros_like, val)
    return merge_slots(cache, zeroed, ~slot_mask, cfg)


def prefill(params, tokens, cfg: ModelConfig,
            frames: Optional[jnp.ndarray] = None, tables=None):
    """Prefill returns last-position logits. (The dry-run lowers the full
    forward; serving fills caches through the engine — chunked
    `decode_chunk` steps, or stepwise decode for families without chunked
    support. See serving.prefill.)"""
    from .transformer import forward
    enc_out = encode(params, frames, cfg) if cfg.is_encdec else None
    return forward(params, tokens, cfg, enc_out=enc_out, last_only=True,
                   tables=tables)
