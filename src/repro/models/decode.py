"""Serving path: cache init, prefill, and single-token decode for every
family (dense/MoE/VLM, SSM, hybrid, enc-dec).

The decoder is a list of per-kind segments (models.segments): decode
walks it, scanning each segment's stacked layer params with that
segment's cache slices (and packed-table slices) as scan xs — the HLO
stays O(segments) in depth, and every composition of attention / SSM /
MoE / cross-attention sublayers flows through the same four bodies.
Caches are static-shape; SWA archs allocate only the window (ring
buffer).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from .config import ModelConfig
from .layers import (apply_mlp, apply_norm, embed_tokens, logits_from_hidden)
from .segments import decoder_layout
from .transformer import _block_tail, _sinusoidal, encode, segment_tables


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               enc_out: Optional[jnp.ndarray] = None, *,
               n_pages: Optional[int] = None,
               page_size: Optional[int] = None) -> Dict:
    """Per-segment caches: attention segments hold stacked (L_seg, B, A,
    Hkv, hd) k/v, SSM segments stacked (L_seg, B, ...) conv/state — the
    batch axis is 1 EVERYWHERE (the old hybrid layout nested SSM slices
    as (periods, P-1, B, ...), which forced family-switched axis math in
    merge_slots). Single-segment stacks keep the historical "attn"/"ssm"
    cache keys; hybrid stacks key by segment name.

    PAGED mode (n_pages + page_size set): attention segments hold a
    pooled {"pk","pv"} (L_seg, n_pages, page_size, Hkv, hd) instead —
    every attention segment indexes the SAME page-id space through the
    per-slot page table the serving engine passes into each step. SSM
    conv/state are O(1) per slot and stay slot-resident unchanged; so
    does "pos"."""
    paged = n_pages is not None
    if paged and page_size is None:
        raise ValueError("paged init_cache needs both n_pages and "
                         "page_size")
    cache: Dict = {"pos": jnp.zeros((), jnp.int32)}
    for seg in decoder_layout(cfg):
        if seg.mixer == "attn":
            if paged:
                cache[seg.cache] = attn_mod.init_paged_cache(
                    cfg, n_pages, page_size, seg.length)
                continue
            c = attn_mod.init_cache(cfg, batch, max_len, seg.length)
            if seg.cache != "attn":
                # multi-segment stacks track one global position only
                c.pop("pos")
            cache[seg.cache] = c
        else:
            cache[seg.cache] = ssm_mod.init_ssm_cache(cfg, batch,
                                                      seg.length)
    if cfg.is_encdec and enc_out is not None:
        cache["enc_out"] = enc_out
    return cache


def _sinusoidal_at(positions, d: int):
    """Sinusoidal position embedding at explicit positions (B, S) ->
    (B, S, d) float32. Same per-element math whether S is 1 (decode
    step) or a chunk — what keeps chunked prefill bit-identical to
    stepwise decode for rope_pct == 0 archs (whisper)."""
    posf = positions.astype(jnp.float32)
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)
    ang = posf[..., None] / (10000.0 ** (dim / d))
    pe = jnp.zeros(posf.shape + (d,), jnp.float32)
    return pe.at[..., 0::2].set(jnp.sin(ang)).at[..., 1::2].set(jnp.cos(ang))


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------

def decode_step(params, cache, token, cfg: ModelConfig, tables=None,
                ptab=None, write_mask=None):
    """token (B, 1) int32 -> (logits (B, 1, V), new cache).

    tables: sparsity.sparse_linear.SegmentedKernelTables — per-segment
    uniform-MAXB joint-sparse projection packs whose arrays ride each
    segment's layer scan as xs (next to the per-layer cache slices), so
    every decode-step projection of every family runs the DB-PIM kernel
    (MoE: grouped expert packs dispatch one kernel call per expert
    slice; enc-dec: cross-attention packs next to self-attention; hybrid
    segments pack independently). None keeps the plain matmuls.

    ptab (B, max_pages) int32 + write_mask (B,) bool switch attention
    segments to the PAGED cache (pooled {"pk","pv"} leaves): KV writes
    route through the page table and inactive slots' writes are dropped
    in-step (merge_slots cannot per-slot-select a pooled leaf — the
    write_mask replaces it for pools, while SSM/"pos" leaves still merge
    the old way). The table rides every segment's scan as a broadcast
    operand: one global page-id space across segments.
    """
    segs = decoder_layout(cfg)
    seg_tables = segment_tables(tables, segs, cfg)
    pos = cache["pos"]
    B = token.shape[0]
    x = embed_tokens(params["embed"], token, cfg)
    if cfg.rope_pct == 0:
        posv = attn_mod._per_slot_pos(pos, B)
        x = x + _sinusoidal_at(posv[:, None], cfg.d_model).astype(x.dtype)
    enc_out = cache.get("enc_out")
    new_cache = dict(cache)

    for seg in segs:
        st = seg_tables.get(seg.name)
        txs = st.arrays if st is not None else None
        mk = (lambda slices, st=st:
              st.dense_fn(slices) if st is not None else None)
        c = cache[seg.cache]
        if seg.mixer == "attn":
            paged = "pk" in c
            if paged and ptab is None:
                raise ValueError("paged cache requires a page table "
                                 "(ptab) operand")
            def step(h, inp, seg=seg, mk=mk, paged=paged):
                p, ck, cv, slices = inp
                mm = mk(slices)
                hn = apply_norm(p["norm1"], h, cfg)
                y, ck, cv = attn_mod.decode_attention(
                    p["attn"], hn, ck, cv, pos, cfg, dense_fn=mm,
                    ptab=ptab if paged else None,
                    write_mask=write_mask if paged else None)
                h = _block_tail(seg, p, h + y, cfg, mm, enc_out)
                return h, (ck, cv)
            kk, vk = ("pk", "pv") if paged else ("k", "v")
            x, (cks, cvs) = jax.lax.scan(
                step, x, (params[seg.name], c[kk], c[vk], txs))
            nc = {kk: cks, vk: cvs}
            if "pos" in c:
                nc["pos"] = pos + 1
            new_cache[seg.cache] = nc
        else:
            def step(h, inp, seg=seg, mk=mk):
                p, conv, state, slices = inp
                mm = mk(slices)
                hn = apply_norm(p["norm1"], h, cfg)
                y, conv, state = ssm_mod.decode_ssm(
                    p["ssm"], hn, conv, state, cfg, dense_fn=mm)
                h = _block_tail(seg, p, h + y, cfg, mm, enc_out)
                return h, (conv, state)
            x, (convs, states) = jax.lax.scan(
                step, x, (params[seg.name], c["conv"], c["state"], txs))
            new_cache[seg.cache] = {"conv": convs, "state": states}

    new_cache["pos"] = pos + 1
    x = apply_norm(params["final_norm"], x, cfg)
    return logits_from_hidden(params["embed"], x, cfg), new_cache


def decode_chunk(params, cache, tokens, n_valid, cfg: ModelConfig,
                 tables=None, ptab=None):
    """Chunked cache-filling prefill: C prompt tokens per slot, one step.

    ptab (B, max_pages) int32 switches attention segments to the PAGED
    cache ({"pk","pv"} pool leaves) — chunk writes scatter through the
    page table with the same drop-sentinel idiom as the contiguous path
    (idle slots' n_valid = 0 already gates their writes, so no separate
    write mask is needed here).

    tokens (B, C) int32; n_valid (B,) int32 in [0, C] — the number of real
    prompt tokens per slot this chunk (ragged tail chunks and idle slots
    pass fewer/0; their cache slices are left untouched). cache["pos"] is
    the per-slot fill depth ((B,) vector, or a scalar broadcast).

    Returns (logits (B, 1, V) of each slot's LAST VALID token — the
    first-generated-token logits when the chunk completes a prompt — and
    the cache advanced by n_valid per slot). The chunk is one fixed-shape
    device step: time-to-first-token is ceil(P/C) steps instead of P, and
    the unembedding runs once per chunk instead of once per prompt token.

    Per-token math vs running `decode_step` n_valid times: bit-identical
    for attention segments (self- and cross-attention chunks project all
    C tokens in one row-stable matmul), for MoE segments whenever the
    per-position capacity covers every assignment (capacity() clamps to
    B * top_k at decode-batch scale, so it always does — each chunk
    position routes against exactly one decode step's token pool), and
    for SSM segments with cfg.prefill_exact=True. The default SSM path
    is the parallel SSD form (ssm.prefill_ssm_parallel) — the in/out
    projections are read ONCE per chunk instead of once per token, at
    the cost of tolerance-level (ssm.PARALLEL_PREFILL_ATOL) instead of
    bitwise equivalence.

    Requires full causal attention (cfg.window == 0): a sliding-window
    ring buffer overwrites slots within a chunk, which only a sequential
    walk reproduces.

    Like decode_step, `tables` threads the per-segment uniform-MAXB
    joint-sparse packs through each segment's scan, so prompt chunks run
    the DB-PIM kernel too.
    """
    if cfg.window:
        raise ValueError(f"chunked prefill is not supported for {cfg.name}"
                         f": sliding-window ring caches need stepwise "
                         f"prefill")
    segs = decoder_layout(cfg)
    seg_tables = segment_tables(tables, segs, cfg)
    B, C = tokens.shape
    pos = attn_mod._per_slot_pos(cache["pos"], B)
    n_valid = jnp.asarray(n_valid, jnp.int32)

    x = embed_tokens(params["embed"], tokens, cfg)
    if cfg.rope_pct == 0:
        qpos = pos[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
        x = x + _sinusoidal_at(qpos, cfg.d_model).astype(x.dtype)
    enc_out = cache.get("enc_out")
    new_cache = dict(cache)

    ssm_prefill = (ssm_mod.prefill_ssm if cfg.prefill_exact
                   else ssm_mod.prefill_ssm_parallel)

    for seg in segs:
        st = seg_tables.get(seg.name)
        txs = st.arrays if st is not None else None
        mk = (lambda slices, st=st:
              st.dense_fn(slices) if st is not None else None)
        c = cache[seg.cache]
        if seg.mixer == "attn":
            paged = "pk" in c
            if paged and ptab is None:
                raise ValueError("paged cache requires a page table "
                                 "(ptab) operand")
            def step(h, inp, seg=seg, mk=mk, paged=paged):
                p, ck, cv, slices = inp
                mm = mk(slices)
                hn = apply_norm(p["norm1"], h, cfg)
                y, ck, cv = attn_mod.prefill_attention(
                    p["attn"], hn, ck, cv, pos, n_valid, cfg, dense_fn=mm,
                    ptab=ptab if paged else None)
                h = _block_tail(seg, p, h + y, cfg, mm, enc_out,
                                per_position=True)
                return h, (ck, cv)
            kk, vk = ("pk", "pv") if paged else ("k", "v")
            x, (cks, cvs) = jax.lax.scan(
                step, x, (params[seg.name], c[kk], c[vk], txs))
            nc = {kk: cks, vk: cvs}
            if "pos" in c:
                nc["pos"] = pos + n_valid
            new_cache[seg.cache] = nc
        else:
            def step(h, inp, seg=seg, mk=mk):
                p, conv, state, slices = inp
                mm = mk(slices)
                hn = apply_norm(p["norm1"], h, cfg)
                y, conv, state = ssm_prefill(
                    p["ssm"], hn, conv, state, n_valid, cfg, dense_fn=mm)
                h = _block_tail(seg, p, h + y, cfg, mm, enc_out,
                                per_position=True)
                return h, (conv, state)
            x, (convs, states) = jax.lax.scan(
                step, x, (params[seg.name], c["conv"], c["state"], txs))
            new_cache[seg.cache] = {"conv": convs, "state": states}

    new_cache["pos"] = pos + n_valid
    x = apply_norm(params["final_norm"], x, cfg)
    last = jnp.clip(n_valid - 1, 0, C - 1)
    x_last = x[jnp.arange(B), last][:, None]                  # (B, 1, D)
    return logits_from_hidden(params["embed"], x_last, cfg), new_cache


# ---------------------------------------------------------------------------
# Per-slot cache surgery (the serving engine's slot scheduler)
# ---------------------------------------------------------------------------

def _select_batch(mask, new, old, axis: int):
    shape = [1] * new.ndim
    shape[axis] = mask.shape[0]
    return jnp.where(mask.reshape(shape), new, old)


def merge_slots(new_cache, old_cache, keep_mask, cfg: ModelConfig):
    """Per-slot cache select: slots where keep_mask (B,) is True take the
    updated cache, the rest keep their previous contents and position.

    This is what lets ONE fixed-shape decode step serve a batch where
    only some slots are actively decoding (others are mid-prefill, free,
    or draining): the step computes updates for every slot, and the merge
    discards the writes of inactive ones. Positions come out as (B,)
    vectors regardless of input shape. Encoder output (enc-dec) is shared
    across the batch and passes through unchanged.

    The walk is layout-generic: every cache leaf carries the batch on
    axis 1 ((L_seg, B, ...) for k/v, conv, and state alike — the
    segmented layout), "pos" leaves select per-slot scalars, "enc_out"
    passes through. No family switches.

    Paged pool leaves ("pk"/"pv": (L_seg, n_pages, page_size, Hkv, hd))
    have NO batch axis to select on — they pass through updated. Their
    per-slot write gating happened IN-STEP (decode_attention's
    write_mask / prefill's n_valid sentinel-drop), so an inactive slot's
    pages were never touched in the first place."""
    B = keep_mask.shape[0]

    def sel_pos(new, old):
        return jnp.where(keep_mask, attn_mod._per_slot_pos(new, B),
                         attn_mod._per_slot_pos(old, B))

    def visit(path, new, old):
        key = str(getattr(path[-1], "key", path[-1]))
        if key == "pos":
            return sel_pos(new, old)
        if key in ("enc_out", "pk", "pv"):
            return new
        return _select_batch(keep_mask, new, old, axis=1)

    return jax.tree_util.tree_map_with_path(visit, new_cache, old_cache)


def reset_slots(cache, slot_mask, cfg: ModelConfig, ptab=None):
    """Zero the KV/SSM cache slices and position of the slots where
    slot_mask (B,) is True — the admission step before a freed slot takes
    a new request. Without this, a refilled slot's attention would still
    mask correctly (pos restarts at 0) but SSM states and ring buffers
    carry the PREVIOUS request's activations into the new one. Encoder
    output (enc-dec) is shared and not per-request; callers that rotate
    enc-dec requests must swap it themselves.

    Paged caches additionally take ``ptab`` (B, max_pages): the reset is
    PAGE-TABLE SURGERY — only the pages the masked slots' table rows
    point at are zeroed (fixed-shape scatter; -1 rows route to the drop
    sentinel), so admitting one request never touches another slot's
    pages. SSM/"pos" leaves are per-slot and reset the contiguous way."""
    zeroed = {}
    for key, val in cache.items():
        if key == "enc_out":
            zeroed[key] = val
        else:
            zeroed[key] = jax.tree_util.tree_map(jnp.zeros_like, val)
    out = merge_slots(cache, zeroed, ~slot_mask, cfg)
    if ptab is None:
        return out

    sel = slot_mask[:, None] & (ptab >= 0)                   # (B, MP)

    def visit(path, leaf):
        key = str(getattr(path[-1], "key", path[-1]))
        if key not in ("pk", "pv"):
            return leaf
        np_ = leaf.shape[1]
        pids = jnp.where(sel, ptab, np_).reshape(-1)         # (B*MP,)
        return leaf.at[:, pids].set(jnp.zeros((), leaf.dtype),
                                    mode="drop")

    return jax.tree_util.tree_map_with_path(visit, out)


def prefill(params, tokens, cfg: ModelConfig,
            frames: Optional[jnp.ndarray] = None, tables=None):
    """Prefill returns last-position logits. (The dry-run lowers the full
    forward; serving fills caches through the engine — chunked
    `decode_chunk` steps, or stepwise decode for families without chunked
    support. See serving.prefill.)"""
    from .transformer import forward
    enc_out = encode(params, frames, cfg) if cfg.is_encdec else None
    return forward(params, tokens, cfg, enc_out=enc_out, last_only=True,
                   tables=tables)
