"""Shared layer primitives: norms, RoPE, MLPs, embeddings, initializers.

Everything is a pure function over explicit param pytrees (no framework).
Params are created by `init_*` functions that only use jax.random — they
can run under `jax.eval_shape` for the allocation-free dry-run.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .config import ModelConfig
from repro.runtime.act_sharding import constrain


def dtype_of(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ------------------------------------------------------------- norms -------

def init_norm(cfg: ModelConfig, d: int):
    if cfg.norm_type == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32),
                "bias": jnp.zeros((d,), jnp.float32)}
    init = jnp.zeros if cfg.norm_plus_one else jnp.ones
    return {"scale": init((d,), jnp.float32)}


def apply_norm(p, x, cfg: ModelConfig, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        scale = (1.0 + p["scale"]) if cfg.norm_plus_one else p["scale"]
        out = xf * jax.lax.rsqrt(var + eps) * scale
    return out.astype(x.dtype)


def rms_head_norm(scale, x, eps: float = 1e-6):
    """Per-head qk-norm (qwen3): x (..., head_dim)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


# -------------------------------------------------------------- RoPE -------

def rope_frequencies(cfg: ModelConfig, positions):
    """positions (...,) int32 -> (cos, sin) of shape (..., rot_dim//2)."""
    rot = int(cfg.hd * cfg.rope_pct)
    rot -= rot % 2
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, rot, 2, jnp.float32) / rot))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin, cfg: ModelConfig):
    """x (..., H, hd); cos/sin broadcastable (..., rot//2)."""
    rot = 2 * cos.shape[-1]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = jnp.split(xr.astype(jnp.float32), 2, axis=-1)
    c = cos[..., None, :] if x.ndim == cos.ndim + 1 else cos
    s = sin[..., None, :] if x.ndim == sin.ndim + 1 else sin
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


# --------------------------------------------------------------- MLP -------

def init_mlp(cfg: ModelConfig, key, d: int, f: int):
    dt = dtype_of(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    s_in, s_out = d ** -0.5, f ** -0.5
    if cfg.mlp_type in ("swiglu", "geglu"):
        return {"w_gate": (jax.random.normal(k1, (d, f)) * s_in).astype(dt),
                "w_up": (jax.random.normal(k2, (d, f)) * s_in).astype(dt),
                "w_down": (jax.random.normal(k3, (f, d)) * s_out).astype(dt)}
    return {"w_up": (jax.random.normal(k1, (d, f)) * s_in).astype(dt),
            "w_down": (jax.random.normal(k2, (f, d)) * s_out).astype(dt)}


def make_matmul(cfg: ModelConfig, tables=None, interpret: bool = None):
    """dense_fn factory for apply_mlp / attention.

    When ``cfg.dbpim`` is set and packed kernel tables (from
    ``sparsity.sparse_linear.build_kernel_tables``) are supplied, eligible
    projections run on the DB-PIM Pallas kernel selected by
    ``cfg.dbpim_mode`` — "joint" fuses value-level block skipping with
    bit-level INT8 weights in one kernel. Returns None (plain matmuls)
    otherwise, so call sites can pass the result straight through.
    interpret=None uses the backend default (compile on TPU, interpret
    elsewhere; REPRO_PALLAS_INTERPRET overrides).

    Scope note: this is the PER-LAYER hook (single unstacked tables).
    The scan-stacked serving forwards thread
    ``sparsity.sparse_linear.StackedKernelTables`` instead — uniform-MAXB
    stacked packs carried as scan xs (transformer.forward(tables=...),
    decode.decode_step(tables=...)).
    """
    if not getattr(cfg, "dbpim", False) or not tables:
        return None
    from repro.sparsity.sparse_linear import kernel_dense_fn
    return kernel_dense_fn(tables, interpret=interpret)


def apply_mlp(p, x, cfg: ModelConfig, dense_fn=None):
    """dense_fn(w, x, name) lets the DB-PIM sparse path intercept matmuls."""
    mm = dense_fn or (lambda w, v, name: v @ w)
    cst = lambda t: constrain(t, *(["dp"] + [None] * (t.ndim - 2) + ["tp"]))
    if cfg.mlp_type == "swiglu":
        g = jax.nn.silu(cst(mm(p["w_gate"], x, "w_gate")))
        return mm(p["w_down"], g * cst(mm(p["w_up"], x, "w_up")), "w_down")
    if cfg.mlp_type == "geglu":
        g = jax.nn.gelu(cst(mm(p["w_gate"], x, "w_gate")), approximate=True)
        return mm(p["w_down"], g * cst(mm(p["w_up"], x, "w_up")), "w_down")
    h = jax.nn.gelu(cst(mm(p["w_up"], x, "w_up")), approximate=True)
    return mm(p["w_down"], h, "w_down")


# --------------------------------------------------------- embeddings ------

def init_embeddings(cfg: ModelConfig, key):
    dt = dtype_of(cfg)
    k1, k2 = jax.random.split(key)
    p = {"tok": (jax.random.normal(k1, (cfg.vocab_size, cfg.d_model))
                 .astype(dt))}
    if not cfg.tie_embeddings:
        p["out"] = (jax.random.normal(k2, (cfg.d_model, cfg.vocab_size))
                    * cfg.d_model ** -0.5).astype(dt)
    return p


def embed_tokens(p, tokens, cfg: ModelConfig):
    x = jnp.take(p["tok"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def logits_from_hidden(p, x, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return x @ p["tok"].T
    return x @ p["out"]


def cross_entropy(logits, labels):
    """Mean token cross-entropy; labels < 0 are masked.

    The gold logit is extracted with a one-hot CONTRACTION, not
    take_along_axis: a gather across the vocab dim would force GSPMD to
    all-gather vocab-sharded logits (multi-GB per device at 150k vocab),
    while the contraction reduces over the sharded dim with a cheap
    all-reduce."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(jnp.maximum(labels, 0), logits.shape[-1],
                            dtype=jnp.float32)
    gold = jnp.einsum("...v,...v->...", logits, onehot)
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
