"""Request-level serving engine: admission queue + per-slot state machine
+ fixed-shape jitted steps.

The engine owns a static batch of ``n_slots`` cache slots. Each request
moves through

    QUEUED -> PREFILLING -> DECODING -> DONE

with all scheduling host-side and all math in exactly TWO compiled
executables (three with slot reset), fixed-shape so NO recompilation ever
happens per request:

  * decode step   (B, 1) tokens + (B,) active mask
    (launch.steps.build_step("decode") — inactive slots' cache writes
    are discarded by models.decode.merge_slots);
  * prefill chunk (B, C) tokens + (B,) n_valid
    (serving.prefill.build_chunk_step — only in "chunked" mode);
  * slot reset — zeroes a freed slot's KV/SSM cache slices and position
    before admission (models.decode.reset_slots), so a refilled slot is
    indistinguishable from a fresh batch.

One engine TICK = admit -> (prefill chunk, if any slot is prefilling) ->
(decode step, if any slot is decoding). Prefill and decode are separate
device calls, so prefilling a newly admitted request NEVER stalls
in-flight decodes — decoding slots emit a token every tick regardless of
arrivals. In "full" prefill mode (the baseline), prompt tokens instead
ride the decode call one at a time.

Admission order (``schedule``):

  * "fifo" (default) — strictly arrival order from one queue;
  * "spf" — shortest-prompt-first among ARRIVED requests: under mixed
    (bimodal) loads, short prompts stop queueing behind long prefills
    and mean TTFT drops. Starvation is bounded by ``spf_age_cap``:
    every shortest-first admission raises the skip count of every other
    arrived request it passed over; at the cap a request becomes urgent
    and is admitted before any non-urgent request (oldest-arrival
    first; urgent admissions are forced fairness, not jumps, and raise
    no counts). A non-urgent pick only happens when NOBODY is urgent,
    so skips <= spf_age_cap is a hard bound — no request is ever passed
    over by shortest-first picks more than ``spf_age_cap`` times, even
    when every request arrives at once — the invariant
    tests/test_serving_engine.py holds the scheduler to. Admission is
    O(arrived): the queue is arrival-sorted, so the arrived set is a
    prefix, picks are index-based deque deletes within it, and a
    request's skip entry is dropped the moment it is admitted (the
    final count lands in metrics.requests[rid].skips).

Per-slot cache positions: cache["pos"] is a (B,) vector — slots hold
requests at different depths, which is what the vectorized
decode_attention / decode_chunk paths exist for.

Fault tolerance — the contract is **blast radius <= one tick, recovery
bitwise-verifiable** (serving.faults is the injection harness that
holds the engine to it; runtime.fault plays the same role for the
training loop at checkpoint granularity):

  * DETECTION — every device call runs under bounded retry
    (``max_step_retries``); after the call, a finite-guard checks each
    PARTICIPATING slot's logits row and fails only the offending slot
    (non-finite logits are also how corrupted cache state surfaces —
    NaN poison propagates to the slot's next logits, and only that
    slot's, because the batch math is per-slot independent).
  * CONTAINMENT — a faulted slot is QUARANTINED: its tick's token is
    discarded, its cache slices are zeroed, and no other slot's stream
    is touched. If a device call stays down past the retry budget,
    every slot in that call quarantines — still one tick of blast
    radius, per slot.
  * RECOVERY-BY-REPLAY — the quarantined slot re-prefills from its
    durable record (original prompt + tokens emitted so far). Chunked
    prefill is bit-identical to sequential decode (the PR 3 invariant),
    so the replayed cache — and every token after it — is BITWISE what
    a fault-free run would have produced; the chaos benchmark asserts
    exactly that. (On the SSM parallel-SSD prefill path the replay is
    tolerance-equal like any other chunk; serve with
    ``cfg.prefill_exact`` where bitwise recovery must hold.) A request
    that faults more than ``max_replays`` times is shed
    ("fault_budget") instead of livelocking — a deterministically-NaN
    model converges to shedding, never to an infinite replay loop.
  * SLO SHEDDING — requests carry an optional ``deadline`` tick. A
    bounded queue (``queue_cap``) rejects at submit, hopeless queued
    requests (optimistic completion estimate past the deadline) are
    shed before ever taking a slot, and in-flight requests are
    preempted the tick their deadline becomes unreachable. All of it is
    RECORDED (metrics.on_reject / on_shed), never raised mid-trace.
  * A zero-fault plan is free: no extra device calls, bitwise-identical
    outputs (the chaos bench's no-overhead guard).

Per-tick wall time feeds a runtime.fault.StragglerMonitor; outlier
ticks are counted in metrics ("straggler_ticks").

Observability (repro.obs) — all of it PASSIVE; with ``tracer=None``
(default) outputs and device-call count are bitwise identical to a
traced run (the zero-overhead contract the chaos bench guards):

  * ``tracer=Tracer()`` records two-clock spans ("tick" per engine
    tick, "call" per device call with call_kind/arch/occupancy/replay
    attrs), slot lifecycle events (admit / prefill / first_token /
    quarantine / replay / shed / reject / release / fault / retry), and
    the closed
    SlotIntervals — JSONL via tracer.dump, Chrome trace via obs.chrome,
    rendered by ``python -m repro.launch.report``.
  * the RECOMPILE SENTINEL (on by default) registers every jitted step
    under its (call_kind, arch) key and raises obs.RecompileError the
    tick any of them compiles more than once — the fixed-shape
    no-recompile contract above, enforced instead of assumed.
  * every device call's wall latency feeds a log-bucketed per-kind
    histogram (metrics.summary()["call_latency_ms"]: p50/p95/p99
    without storing raw samples).

Durability (serving.journal + serving.snapshot) — crash-safe serving,
PASSIVE like the tracer (``journal=None`` is bitwise/count-identical):

  * ``journal=<path>`` appends a CRC-framed record for every
    request-visible transition (submit/admit/token/done/shed/reject),
    fsync'd ONCE per tick; ``snapshot_dir`` + ``snapshot_every`` write
    periodic atomic snapshots (cache + state machine + queue + metrics)
    via the checkpoint layer's tmp-dir + fsync + os.replace publish.
  * ``ServeEngine.restore(cfg, params, snapshot_dir=...,
    journal_path=...)`` rebuilds from the latest snapshot, folds the
    journal tail over it, and re-prefills each active slot's durable
    record through the PR 7 replay path — then ``resume()`` continues
    the streams BITWISE where the dead process left off (the chunk ==
    decode invariant again; ``cfg.prefill_exact`` for SSM parallel
    prefill). Redone work is bounded by snapshot cadence: at most
    ``snapshot_every`` journal-evidenced tokens per active slot
    (restore_stats["replayed_prefill_tokens"], metered under
    "<kind>+restore").
  * the kill-chaos harness: a FaultPlan ``engine_crash`` event kills
    the engine (EngineCrash) between ticks after the journal commit;
    benchmarks/serve_engine_bench.py's restart case kills/restores at
    seeded ticks and guards stream equality + the replay bound.
"""

from __future__ import annotations

import enum
import math
import time
from collections import deque
from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.mesh import make_test_mesh
from repro.launch.steps import build_step
from repro.models import init_cache, reset_slots
from repro.obs import RecompileSentinel, Tracer
from repro.runtime import sharding as shr
from repro.runtime.fault import StragglerMonitor
from repro.serving.faults import EngineCrash, FaultPlan, corrupt_cache
from repro.serving.journal import Journal
from repro.serving.metrics import MetricsRecorder
from repro.serving.paging import PageAllocator
from repro.serving.prefill import (PREFILL_MODES, assemble_chunk,
                                   build_chunk_step)
from repro.serving.workload import Request


class SlotState(enum.Enum):
    FREE = "free"
    PREFILLING = "prefilling"
    DECODING = "decoding"


@dataclass
class _Slot:
    state: SlotState = SlotState.FREE
    rid: Optional[int] = None
    prompt: Optional[np.ndarray] = None  # current prefill target (replay
    #                                      record after a fault)
    durable: Optional[np.ndarray] = None  # original prompt, never mutated
    cursor: int = 0                      # prompt tokens already in cache
    gen_len: int = 0
    pending_token: int = 0               # next decode input
    deadline: Optional[float] = None
    fault_count: int = 0                 # quarantines charged to this slot
    replay: bool = False                 # prefilling a post-fault record
    #                                      (suppress first-token metrics)
    restore: bool = False                # prefilling a warm-restart record
    #                                      (meter calls under "+restore")
    admit_seq: int = -1                  # monotonic admission order —
    #                                      page-pressure preemption picks
    #                                      the YOUNGEST victim by this


@dataclass
class _Preempted:
    """A request evicted from its slot under page pressure, waiting to
    re-enter. Its emitted tokens stay in ``engine.outputs`` — on
    re-admission the replay record is ``durable + outputs[rid]``, so the
    resumed stream continues BITWISE (the same chunk == decode invariant
    fault recovery and warm restart lean on)."""
    rid: int
    durable: np.ndarray
    gen_len: int
    deadline: Optional[float]
    fault_count: int


@dataclass
class SlotInterval:
    """Audit record: slot s served rid from admit_tick until release_tick
    (exclusive). Tests verify intervals on one slot never overlap."""
    slot: int
    rid: int
    admit_tick: int
    release_tick: Optional[int] = None


class EngineStuckError(RuntimeError):
    """max_ticks exceeded — the scheduler wedged. Carries everything a
    post-mortem needs: completed outputs so far, the slot audit log, the
    metrics summary (the bare RuntimeError used to discard all three),
    and — when the engine was configured with a journal / a tracer that
    knows its dump path — the ON-DISK artifact paths, committed/dumped
    before the raise so the hang is diagnosable after the process is
    gone."""

    def __init__(self, msg: str, *, outputs: Dict[int, List[int]],
                 slot_log: List[SlotInterval], summary: dict,
                 journal_path: Optional[str] = None,
                 trace_path: Optional[str] = None):
        super().__init__(msg)
        self.outputs = outputs
        self.slot_log = slot_log
        self.summary = summary
        self.journal_path = journal_path
        self.trace_path = trace_path


class ServeEngine:
    """See module docstring. Typical use:

        engine = ServeEngine(cfg, params, n_slots=4, max_len=64,
                             prefill_chunk=16, stacked_tables=tables)
        results = engine.run(make_trace(spec, cfg.vocab_size))
        print(engine.metrics.summary())
    """

    SCHEDULES = ("fifo", "spf")

    def __init__(self, cfg, params, *, mesh=None, n_slots: int = 4,
                 max_len: int = 64, prefill_chunk: int = 16,
                 prefill_mode: str = "chunked", schedule: str = "fifo",
                 spf_age_cap: int = 8, stacked_tables=None,
                 enc_out=None, max_ticks: int = 100_000,
                 strict: bool = False, queue_cap: Optional[int] = None,
                 fault_plan: Optional[FaultPlan] = None,
                 max_step_retries: int = 2, max_replays: int = 3,
                 tracer: Optional[Tracer] = None,
                 recompile_sentinel: bool = True,
                 journal=None, snapshot_dir: Optional[str] = None,
                 snapshot_every: int = 0, snapshot_keep: int = 2,
                 paged: bool = False, page_size: int = 16,
                 n_pages: Optional[int] = None):
        if prefill_mode not in PREFILL_MODES:
            raise ValueError(f"prefill_mode {prefill_mode!r} not in "
                             f"{PREFILL_MODES}")
        if schedule not in self.SCHEDULES:
            raise ValueError(f"schedule {schedule!r} not in "
                             f"{self.SCHEDULES}")
        if prefill_mode == "chunked" and \
                not cfg.serving_capabilities().chunked_prefill:
            # sliding-window families only: the ring cache needs stepwise
            # writes — every other family (MoE, hybrid, enc-dec included)
            # chunk-prefills through the segmented decode_chunk path
            prefill_mode = "full"
        self.cfg = cfg
        self.mesh = mesh or make_test_mesh()
        self.n_slots = n_slots
        self.max_len = max_len
        # -- paged cache (continuous batching) ---------------------------
        # n_pages defaults to full static capacity (no oversubscription);
        # the interesting regime is n_pages < n_slots * max_len/page_size,
        # where admitted concurrency exceeds what worst-case contiguous
        # slots could back and page pressure drives preemption.
        self.paged = bool(paged)
        if self.paged:
            if max_len % page_size != 0:
                raise ValueError(
                    f"paged engine needs max_len % page_size == 0 "
                    f"(got {max_len} % {page_size}) — equality "
                    f"max_pages_per_slot * page_size == max_len is what "
                    f"makes paged decode bitwise the contiguous path")
            self.page_size = int(page_size)
            self.max_pages_per_slot = max_len // page_size
            self.n_pages = (int(n_pages) if n_pages is not None
                            else n_slots * self.max_pages_per_slot)
            self.page_alloc: Optional[PageAllocator] = PageAllocator(
                self.n_pages, n_slots, self.max_pages_per_slot,
                self.page_size)
        else:
            self.page_size = self.max_pages_per_slot = self.n_pages = 0
            self.page_alloc = None
        self._ptab_cached = None
        self._ptab_version = -1
        self.preempted: deque = deque()   # _Preempted, FIFO re-admission
        self._admit_seq = 0
        self.prefill_chunk = prefill_chunk
        self.prefill_mode = prefill_mode
        self.schedule = schedule
        self.spf_age_cap = spf_age_cap
        self.max_ticks = max_ticks
        self.strict = strict
        self.queue_cap = queue_cap
        self.fault_plan = fault_plan
        self.max_step_retries = max_step_retries
        self.max_replays = max_replays
        self.tracer = tracer
        # -- durability layer (all host-side: journaling/snapshotting
        # never issue device calls, so journal=None vs a live journal is
        # bitwise-output- and device-call-count-identical — the same
        # passivity contract the tracer carries) ------------------------
        if snapshot_every and not snapshot_dir:
            raise ValueError("snapshot_every set without snapshot_dir")
        self.journal: Optional[Journal] = (
            journal if isinstance(journal, Journal) or journal is None
            else Journal(str(journal)))
        self.snapshot_dir = snapshot_dir
        self.snapshot_every = snapshot_every
        self.snapshot_keep = snapshot_keep
        self.restore_stats: Optional[dict] = None

        self.params = params
        self.stacked_tables = stacked_tables
        with self.mesh:
            cache = init_cache(
                cfg, n_slots, max_len, enc_out=enc_out,
                n_pages=self.n_pages if self.paged else None,
                page_size=self.page_size if self.paged else None)
            # per-slot positions from the start (merge_slots vectorizes
            # them anyway; starting scalar would recompile after tick 0)
            cache["pos"] = jnp.zeros((n_slots,), jnp.int32)
            if "attn" in cache and "pos" in cache["attn"]:
                cache["attn"]["pos"] = jnp.zeros((n_slots,), jnp.int32)
            self.cache = cache

            decode_fn, shard_fn = build_step(
                cfg, self.mesh, "decode", stacked_tables=stacked_tables,
                paged=self.paged)
            tok0 = jnp.zeros((n_slots, 1), jnp.int32)
            act0 = jnp.zeros((n_slots,), bool)
            if self.paged:
                pt0 = jnp.full((n_slots, self.max_pages_per_slot), -1,
                               jnp.int32)
                pspec, cspec, tspec, aspec, ptspec = shard_fn(
                    params, cache, tok0, act0, pt0)
            else:
                pspec, cspec, tspec, aspec = shard_fn(params, cache, tok0,
                                                      act0)
            # COMMIT the fresh cache to its serving sharding up front:
            # otherwise the first jitted call returns committed outputs
            # whose signature differs from the uncommitted init arrays,
            # and reset/prefill each compile a second, steady-state
            # variant at tick 1 (the recompile sentinel caught this)
            self.cache = jax.device_put(self.cache,
                                        shr.named(cspec, self.mesh))
            # kept for restore: a snapshot's host cache re-enters the
            # device under the exact serving sharding
            self._cache_sharding = shr.named(cspec, self.mesh)
            # out_shardings pin the returned cache to the SAME spec the
            # steps take it with: left to propagation, XLA hands attn
            # k/v back replicated, and every consumer (reset, prefill)
            # compiles a second steady-state variant at tick 1 — the
            # recompile sentinel caught this
            dec_in = (shr.named(pspec, self.mesh),
                      shr.named(cspec, self.mesh),
                      shr.named(tspec, self.mesh),
                      shr.named(aspec, self.mesh))
            if self.paged:
                dec_in = dec_in + (shr.named(ptspec, self.mesh),)
            self._decode = jax.jit(
                decode_fn,
                in_shardings=dec_in,
                out_shardings=(None, shr.named(cspec, self.mesh)),
                donate_argnums=(1,))
            self._prefill = None
            if prefill_mode == "chunked":
                self._prefill = build_chunk_step(
                    cfg, self.mesh, params, cache, n_slots, prefill_chunk,
                    stacked_tables=stacked_tables, paged=self.paged,
                    max_pages=self.max_pages_per_slot)
            if self.paged:
                self._reset = jax.jit(
                    lambda c, m, pt: reset_slots(c, m, cfg, ptab=pt),
                    out_shardings=shr.named(cspec, self.mesh),
                    donate_argnums=(0,))
            else:
                self._reset = jax.jit(
                    lambda c, m: reset_slots(c, m, cfg),
                    out_shardings=shr.named(cspec, self.mesh),
                    donate_argnums=(0,))

        # which chunk math this engine's prefill executable compiles to
        # ("prefill_parallel" / "prefill_chunk_exact"; None in "full" mode
        # where prompt tokens ride the decode call)
        self.prefill_kind = (self._prefill.call_kind
                             if self._prefill is not None else None)

        # the fixed-shape no-recompile contract, enforced: each jitted
        # step gets ONE compile; check() runs every tick (obs.sentinel)
        self.sentinel = None
        if recompile_sentinel:
            self.sentinel = RecompileSentinel()
            self.sentinel.register(RecompileSentinel.key("decode", cfg.name),
                                   self._decode)
            if self._prefill is not None:
                self.sentinel.register(
                    RecompileSentinel.key(self.prefill_kind, cfg.name),
                    self._prefill)
            self.sentinel.register(RecompileSentinel.key("reset", cfg.name),
                                   self._reset)

        self.queue: deque = deque()
        self.skips: Dict[int, int] = {}   # QUEUED rid -> times jumped (spf);
        #                                   entries die at admission
        self.slots = [_Slot() for _ in range(n_slots)]
        self.tick_count = 0
        self.outputs: Dict[int, List[int]] = {}
        self.first_logits: Dict[int, np.ndarray] = {}
        self.rejected: Dict[int, str] = {}   # rid -> rejection reason
        self.duplicate_rids: List[int] = []  # re-submitted rids (rejected
        #                                      without touching the
        #                                      original's row or outputs)
        self.slot_log: List[SlotInterval] = []
        self._open_interval: Dict[int, SlotInterval] = {}
        self._has_deadlines = False
        self.straggler = StragglerMonitor()
        self.metrics = MetricsRecorder()

    # ------------------------------------------------------------------ API

    def submit(self, request: Request) -> bool:
        """Queue a request; returns False if it was REJECTED instead
        (oversized, the bounded queue is full, or the rid was already
        submitted — accepting a duplicate rid would silently merge two
        requests' token streams in ``self.outputs`` and corrupt journal
        keying). Rejections are recorded (metrics.on_reject,
        ``self.rejected`` / ``self.duplicate_rids``), never raised — one
        malformed request must not abort a whole trace. Construct the
        engine with ``strict=True`` to get the hard ValueError back
        (tests / offline traces). Submissions become DURABLE at the next
        journal commit (run() commits once after queueing a trace;
        direct submit() callers inherit the next tick's commit)."""
        if request.rid in self.metrics.requests:
            if self.strict:
                raise ValueError(
                    f"request {request.rid}: duplicate rid (already "
                    f"submitted)")
            return self._reject(request, "duplicate_rid")
        total = request.prompt_len + request.gen_len
        # capacity is PAGED capacity when paged: a slot can back at most
        # max_pages_per_slot * page_size tokens, and no request may need
        # more pages than the whole pool holds (otherwise admission
        # could never satisfy it and page-pressure preemption would
        # livelock trying)
        if self.paged:
            cap = self.max_pages_per_slot * self.page_size
            oversized = (total > cap or
                         self.page_alloc.pages_for(total) > self.n_pages)
        else:
            cap = self.max_len
            oversized = total > cap
        if oversized:
            if self.strict:
                raise ValueError(
                    f"request {request.rid}: prompt {request.prompt_len} + "
                    f"gen {request.gen_len} exceeds capacity {cap}"
                    + (f" (page pool {self.n_pages} pages)"
                       if self.paged else ""))
            return self._reject(request, "oversized")
        if self.queue_cap is not None and len(self.queue) >= self.queue_cap:
            return self._reject(request, "queue_full")
        self.queue.append(request)
        self.skips[request.rid] = 0
        if request.deadline is not None:
            self._has_deadlines = True
        self.metrics.on_submit(request.rid, request.prompt_len,
                               request.gen_len, request.arrival,
                               deadline=request.deadline)
        if self.journal is not None:
            self.journal.append(
                "submit", self.tick_count, rid=int(request.rid),
                prompt=[int(t) for t in request.prompt],
                gen_len=int(request.gen_len),
                arrival=float(request.arrival),
                deadline=(None if request.deadline is None
                          else float(request.deadline)))
        return True

    def _reject(self, request: Request, reason: str) -> bool:
        if reason == "duplicate_rid":
            # the rid's ORIGINAL request is live (or finished) — don't
            # let the duplicate's reason clobber its results entry
            self.duplicate_rids.append(request.rid)
        else:
            self.rejected[request.rid] = reason
        self.metrics.on_reject(request.rid, request.prompt_len,
                               request.gen_len, request.arrival, reason,
                               deadline=request.deadline)
        if self.journal is not None:
            self.journal.append(
                "reject", self.tick_count, rid=int(request.rid),
                reason=reason, prompt_len=int(request.prompt_len),
                gen_len=int(request.gen_len),
                arrival=float(request.arrival),
                deadline=(None if request.deadline is None
                          else float(request.deadline)))
        if self.tracer is not None:
            self.tracer.event("reject", self.tick_count, rid=request.rid,
                              reason=reason)
        return False

    def run(self, requests: List[Request]):
        """Serve a trace to completion; returns {rid: generated tokens}
        for every request that held a slot (rejected ones appear in
        ``self.rejected`` / metrics instead)."""
        for r in sorted(requests, key=lambda r: (r.arrival, r.rid)):
            self.submit(r)
        if self.journal is not None:
            self.journal.commit()   # the accepted trace is durable
            #                         before any serving work happens
        return self._serve_loop()

    def resume(self):
        """Continue serving after ``ServeEngine.restore`` — the same
        loop as run() without re-submitting anything (the queue and
        slots were rebuilt from the snapshot + journal tail; calling
        run() on a restored engine would just reject every request as
        ``duplicate_rid``)."""
        return self._serve_loop()

    def _serve_loop(self):
        self.metrics.start()
        while self.queue or self.preempted or \
                any(s.state is not SlotState.FREE for s in self.slots):
            self.tick()
            if self.fault_plan is not None and \
                    self.fault_plan.crash_at(self.tick_count - 1):
                # simulated process kill BETWEEN ticks: the completed
                # tick's journal batch is already committed (tick() ends
                # with the commit), so a restored engine resumes at
                # tick_count — strictly past the event, which therefore
                # never re-fires
                if self.tracer is not None:
                    self.tracer.event("crash", self.tick_count - 1)
                raise EngineCrash(
                    f"injected engine crash after tick "
                    f"{self.tick_count - 1}", tick=self.tick_count - 1)
            if self.tick_count > self.max_ticks:
                self._record_slot_log()
                self.metrics.stop()
                journal_path = trace_path = None
                if self.journal is not None:
                    self.journal.commit()
                    journal_path = self.journal.path
                if self.tracer is not None and self.tracer.path:
                    self.tracer.dump(self.tracer.path)
                    trace_path = self.tracer.path
                raise EngineStuckError(
                    f"engine exceeded max_ticks={self.max_ticks}; "
                    f"scheduler stuck?",
                    outputs=dict(self.outputs),
                    slot_log=list(self.slot_log),
                    summary=self.metrics.summary(),
                    journal_path=journal_path, trace_path=trace_path)
        self._record_slot_log()
        self.metrics.stop()
        if self.journal is not None:
            self.journal.commit()
        return self.outputs

    def _record_slot_log(self):
        """Hand the slot audit log to the recorder so summary() can
        aggregate slot_busy_frac / per-slot occupancy from it."""
        self.metrics.record_slot_log(
            [(iv.slot, iv.admit_tick, iv.release_tick)
             for iv in self.slot_log], self.n_slots)

    # ------------------------------------------------- durability layer

    def save_snapshot(self) -> str:
        """Write one atomic engine snapshot (serving.snapshot) — called
        automatically every ``snapshot_every`` ticks, or manually at any
        between-ticks point. Host-side only plus a device->host copy of
        the cache: no device calls, so snapshotting never perturbs the
        token streams."""
        from repro.serving.snapshot import save_snapshot
        path = save_snapshot(self)
        if self.tracer is not None:
            self.tracer.event("snapshot", self.tick_count,
                              step=self.tick_count, path=path)
        return path

    @classmethod
    def restore(cls, cfg, params, *, snapshot_dir: str,
                journal_path: Optional[str] = None,
                step: Optional[int] = None, mesh=None,
                stacked_tables=None, enc_out=None,
                fault_plan: Optional[FaultPlan] = None,
                tracer: Optional[Tracer] = None,
                recompile_sentinel: bool = True,
                journal_fsync: bool = True, **overrides) -> "ServeEngine":
        """Bring up a replacement engine from the latest (or ``step``)
        snapshot plus the journal tail — the warm-restart path after a
        crash (EngineCrash in tests/benches; a real kill in production).

        Geometry and policy knobs (n_slots, max_len, prefill_chunk,
        prefill_mode, schedule, ...) come from the snapshot manifest;
        ``overrides`` can replace the policy ones, but the cache
        geometry must match or restore refuses. The caller re-supplies
        what is NOT durable state: cfg/params/tables (weights are the
        training checkpoint's business, not the serving snapshot's) and
        runtime objects (fault_plan, tracer — pass the same tracer to
        span the restart in one trace).

        The journal is reopened in resume mode (torn tail truncated at
        the first bad frame) and further records append after the last
        good one. Call ``resume()`` on the returned engine to continue
        serving; every active slot finishes a chunked re-prefill of
        ``prompt + journaled tokens`` and the streams continue bitwise
        (cfg.prefill_exact where the SSM parallel path must be exact).
        ``restore_stats`` carries the replay-work accounting the
        kill-chaos bench bounds by snapshot cadence."""
        from repro.serving.snapshot import (read_snapshot_meta,
                                            restore_engine_state)
        step, extra = read_snapshot_meta(snapshot_dir, step)
        kw = {k: extra["engine"][k] for k in
              ("n_slots", "max_len", "prefill_chunk", "prefill_mode",
               "schedule", "spf_age_cap", "max_ticks", "strict",
               "queue_cap", "max_step_retries", "max_replays",
               "snapshot_every", "snapshot_keep")}
        # paged keys arrived with snapshot v2; .get keeps v1 restorable
        kw["paged"] = extra["engine"].get("paged", False)
        if kw["paged"]:
            kw["page_size"] = extra["engine"]["page_size"]
            kw["n_pages"] = extra["engine"]["n_pages"]
        kw.update(overrides)
        engine = cls(cfg, params, mesh=mesh, stacked_tables=stacked_tables,
                     enc_out=enc_out, fault_plan=fault_plan, tracer=tracer,
                     recompile_sentinel=recompile_sentinel,
                     journal=None, snapshot_dir=snapshot_dir, **kw)
        restore_engine_state(engine, snapshot_dir, step,
                             journal_path=journal_path,
                             journal_fsync=journal_fsync)
        return engine

    # ------------------------------------------------------------- one tick

    def tick(self):
        t0 = time.monotonic()
        tick = self.tick_count
        span = (self.tracer.begin("tick", tick)
                if self.tracer is not None else None)
        calls = 0
        if self.fault_plan is not None:
            self._inject_cache_faults(tick)
        if self._has_deadlines:
            self._shed_hopeless_slots(tick)
        self._admit(tick)
        if self.paged:
            # every occupied slot must own the pages this tick's writes
            # land in BEFORE the device calls go out; pressure resolves
            # by preempting the youngest-admitted slot
            self._page_growth(tick)
            self.page_alloc.check()
        if self.prefill_mode == "chunked":
            calls += self._prefill_phase(tick)
        calls += self._decode_phase(tick)
        qd = len(self.queue)
        n_pre = sum(s.state is SlotState.PREFILLING for s in self.slots)
        n_dec = sum(s.state is SlotState.DECODING for s in self.slots)
        pages_used = pages_total = None
        if self.paged:
            pages_used = self.page_alloc.used_pages
            pages_total = self.n_pages
        self.metrics.on_tick(tick, queue_depth=qd, n_prefilling=n_pre,
                             n_decoding=n_dec, device_calls=calls,
                             pages_used=pages_used,
                             pages_total=pages_total)
        if span is not None:
            attrs = dict(queue_depth=qd, n_prefilling=n_pre,
                         n_decoding=n_dec, device_calls=calls)
            if self.paged:
                attrs.update(pages_used=pages_used,
                             pages_total=pages_total)
            self.tracer.end(span, **attrs)
        self.tick_count += 1
        if self.journal is not None:
            # ONE write + fsync for the whole tick's batch (admits,
            # tokens, terminal events) — durability costs one fsync per
            # tick however many requests moved; a kill can only lose
            # the current tick's uncommitted records, which restore
            # re-derives bitwise
            self.journal.commit()
        if self.straggler.record(time.monotonic() - t0):
            self.metrics.on_straggler(tick)
        if self.sentinel is not None:
            self.sentinel.check()
        if self.snapshot_every and \
                self.tick_count % self.snapshot_every == 0:
            self.save_snapshot()

    # -------------------------------------------------------------- phases

    def _pop_next(self, tick: int, can_admit=None):
        """Next request to admit, or None. "fifo" pops the head once it
        has arrived. "spf" picks the shortest ARRIVED prompt — unless a
        request has already been passed over ``spf_age_cap`` times, in
        which case the oldest such urgent request goes first. Every
        NON-urgent (shortest-first) pick raises the skip count of every
        other arrived request; urgent picks raise none (forced fairness
        is not a jump). Since a non-urgent pick requires the urgent set
        to be empty, a request at the cap can never be incremented
        again: skips[rid] <= spf_age_cap always, and deferral is bounded
        even when all requests arrive simultaneously.

        The queue is arrival-sorted, so the arrived set is a PREFIX:
        one O(arrived) scan finds the pick's index and the deque delete
        shifts at most that prefix — no full-queue equality scan.

        ``can_admit(req) -> bool`` is the paged admission gate (enough
        free pages for the prompt). A gated-out pick stays at the head
        with NO side effects — no skip increments, no reorder: page
        waits are head-of-line blocking, not queue jumping, so FIFO
        order survives page pressure and the spf skip bound is
        unaffected by it."""
        arrived = []
        for i, r in enumerate(self.queue):
            if r.arrival > tick:
                break
            arrived.append((i, r))
        if not arrived:
            return None
        if self.schedule == "fifo":
            idx, req = arrived[0]
        else:
            urgent = [(i, r) for i, r in arrived
                      if self.skips[r.rid] >= self.spf_age_cap]
            if urgent:
                idx, req = urgent[0]      # oldest urgent arrival
            else:
                idx, req = min(arrived, key=lambda ir: (
                    ir[1].prompt_len, ir[1].arrival, ir[1].rid))
        if can_admit is not None and not can_admit(req):
            return None
        if self.schedule != "fifo" and not \
                (self.skips[req.rid] >= self.spf_age_cap):
            for _, r in arrived:
                if r is not req:
                    self.skips[r.rid] += 1
        del self.queue[idx]
        return req

    def _admit(self, tick: int):
        """QUEUED -> PREFILLING: pop arrived requests into free slots and
        ZERO the slots' stale cache slices (the previous occupant's
        KV/SSM state must not leak into the new request).

        Paged engines admit PREEMPTED requests first (FIFO — they are
        the oldest admitted work), then the queue, each gated on free
        pages for the full (re-)prefill record rather than merely a free
        slot. A gate miss is head-of-line blocking: nothing younger
        jumps it (jumping would re-trigger the very preemptions that
        freed the pages)."""
        if self._has_deadlines:
            self._shed_hopeless_queue(tick)
        mask = np.zeros((self.n_slots,), bool)
        for s, slot in enumerate(self.slots):
            if slot.state is not SlotState.FREE:
                continue
            if self.preempted:
                ent = self.preempted[0]
                emitted = self.outputs.get(ent.rid, [])
                record = (np.concatenate(
                              [ent.durable,
                               np.asarray(emitted, np.int32)])
                          if emitted else ent.durable)
                need = self.page_alloc.pages_for(len(record))
                if need > self.page_alloc.free_pages:
                    self.metrics.on_alloc_failure()
                    break                 # head-of-line: wait for pages
                self.preempted.popleft()
                self.page_alloc.grow(s, need)
                self.slots[s] = _Slot(
                    state=SlotState.PREFILLING, rid=ent.rid, prompt=record,
                    durable=ent.durable, gen_len=ent.gen_len,
                    deadline=ent.deadline, fault_count=ent.fault_count,
                    replay=bool(emitted), admit_seq=self._admit_seq)
                self._admit_seq += 1
                mask[s] = True
                self.metrics.on_admit(ent.rid, tick, skips=0)
                if self.journal is not None:
                    self.journal.append("admit", tick, rid=int(ent.rid),
                                        slot=s, skips=0)
                if self.tracer is not None:
                    self.tracer.event("admit", tick, rid=ent.rid, slot=s,
                                      wait=0, skips=0, resumed=True)
                iv = SlotInterval(slot=s, rid=ent.rid, admit_tick=tick)
                self.slot_log.append(iv)
                self._open_interval[s] = iv
                continue
            can_admit = None
            if self.paged:
                def can_admit(r):
                    need = self.page_alloc.pages_for(r.prompt_len)
                    if need > self.page_alloc.free_pages:
                        self.metrics.on_alloc_failure()
                        return False
                    return True
            req = self._pop_next(tick, can_admit)
            if req is None:
                break
            prompt = np.asarray(req.prompt, np.int32)
            self.slots[s] = _Slot(
                state=SlotState.PREFILLING, rid=req.rid, prompt=prompt,
                durable=prompt, gen_len=req.gen_len, deadline=req.deadline,
                admit_seq=self._admit_seq)
            self._admit_seq += 1
            if self.paged:
                self.page_alloc.grow(
                    s, self.page_alloc.pages_for(len(prompt)))
            mask[s] = True
            self.outputs[req.rid] = []
            skips = self.skips.pop(req.rid, 0)
            self.metrics.on_admit(req.rid, tick, skips=skips)
            if self.journal is not None:
                self.journal.append("admit", tick, rid=int(req.rid),
                                    slot=s, skips=skips)
            if self.tracer is not None:
                self.tracer.event("admit", tick, rid=req.rid, slot=s,
                                  wait=tick - req.arrival, skips=skips)
            iv = SlotInterval(slot=s, rid=req.rid, admit_tick=tick)
            self.slot_log.append(iv)
            self._open_interval[s] = iv
        if mask.any():
            self.cache = self._reset_call(mask)

    # ------------------------------------------------------- page pressure

    def _ptab(self):
        """Device copy of the allocator's page table, refreshed only
        when the allocator actually mutated (version counter) — the
        common decode tick reuses the cached array."""
        if self._ptab_version != self.page_alloc.version:
            self._ptab_cached = jnp.asarray(self.page_alloc.table())
            self._ptab_version = self.page_alloc.version
        return self._ptab_cached

    def _reset_call(self, mask):
        if self.paged:
            return self._reset(self.cache, jnp.asarray(mask), self._ptab())
        return self._reset(self.cache, jnp.asarray(mask))

    def _slot_pages_needed(self, s: int) -> int:
        """Pages slot ``s`` must own BEFORE this tick's device calls: a
        prefilling slot writes up to its next chunk's end; a decoding
        slot writes exactly one token at position
        len(durable) + len(outputs) - 1."""
        slot = self.slots[s]
        if slot.state is SlotState.PREFILLING:
            step = (self.prefill_chunk if self.prefill_mode == "chunked"
                    else 1)
            tokens = min(slot.cursor + step, len(slot.prompt))
            if self.prefill_mode == "chunked" and \
                    tokens == len(slot.prompt):
                # the chunk that finishes the prompt flips the slot to
                # DECODING within this same tick, and that first decode
                # step writes one position PAST the prompt
                tokens += 1
        else:                              # DECODING
            tokens = len(slot.durable) + len(self.outputs[slot.rid])
        return self.page_alloc.pages_for(tokens)

    def _page_growth(self, tick: int):
        """Grow each occupied slot to the pages this tick's writes need,
        OLDEST admission first. Page pressure preempts the YOUNGEST
        occupied slot strictly younger than the needer (a needer with no
        younger neighbor preempts itself — it cannot steal from its
        elders, which is what makes the oldest admitted request always
        runnable and the policy livelock-free: submit() guarantees its
        total need fits the pool)."""
        order = sorted((s for s in range(self.n_slots)
                        if self.slots[s].state is not SlotState.FREE),
                       key=lambda s: self.slots[s].admit_seq)
        for s in order:
            slot = self.slots[s]
            if slot.state is SlotState.FREE:
                continue                   # preempted earlier in the walk
            need = self._slot_pages_needed(s)
            while not self.page_alloc.grow(s, need):
                self.metrics.on_alloc_failure()
                younger = [v for v in range(self.n_slots)
                           if v != s
                           and self.slots[v].state is not SlotState.FREE
                           and self.slots[v].admit_seq > slot.admit_seq]
                if younger:
                    victim = max(younger,
                                 key=lambda v: self.slots[v].admit_seq)
                    self._preempt(victim, tick)
                else:
                    self._preempt(s, tick)
                    break

    def _preempt(self, s: int, tick: int):
        """Evict slot ``s`` under page pressure: free its pages, push it
        onto the FIFO re-admission deque, and journal the transition (a
        "preempt" record — restore must know the slot's pages were
        surrendered). The emitted tokens stay in ``outputs``; the
        re-admitted record is durable + outputs, and because chunked
        prefill == sequential decode, the resumed stream is BITWISE the
        unpreempted one."""
        slot = self.slots[s]
        rid = slot.rid
        freed = self.page_alloc.release(s)
        self.metrics.on_preempt(rid, tick)
        if self.journal is not None:
            self.journal.append("preempt", tick, rid=int(rid), slot=s)
        if self.tracer is not None:
            self.tracer.event("preempt", tick, rid=rid, slot=s,
                              freed_pages=freed)
        self._close_interval(s, tick)
        self.preempted.append(_Preempted(
            rid=rid, durable=slot.durable, gen_len=slot.gen_len,
            deadline=slot.deadline, fault_count=slot.fault_count))
        self.slots[s] = _Slot()

    def _prefill_phase(self, tick: int) -> int:
        prefilling = {s: slot.prompt for s, slot in enumerate(self.slots)
                      if slot.state is SlotState.PREFILLING}
        if not prefilling:
            return 0
        cursors = {s: self.slots[s].cursor for s in prefilling}
        tokens, n_valid = assemble_chunk(prefilling, cursors, self.n_slots,
                                         self.prefill_chunk)
        replaying = any(self.slots[s].replay for s in prefilling)
        restoring = any(self.slots[s].restore for s in prefilling)
        span = (self.tracer.begin(
                    "call", tick, phase="prefill", kind=self.prefill_kind,
                    arch=self.cfg.name, participants=sorted(prefilling),
                    occupancy=len(prefilling) / self.n_slots,
                    replay=replaying, restore=restoring)
                if self.tracer is not None else None)
        c0 = time.monotonic()
        args = (self.params, self.cache, jnp.asarray(tokens),
                jnp.asarray(n_valid))
        if self.paged:
            args = args + (self._ptab(),)
        res = self._device_call("prefill", self.prefill_kind,
                                self._prefill, *args)
        dur_s = time.monotonic() - c0
        if span is not None:
            self.tracer.end(span, ok=res is not None)
        if res is None:                   # persistent step failure:
            for s in prefilling:          # quarantine every participant
                self._quarantine(s, tick, "step_exception")
            return 0
        logits, self.cache = res
        self.metrics.on_device_call("prefill", kind=self.prefill_kind,
                                    replay=replaying, restore=restoring,
                                    dur_s=dur_s)
        lg = self._host_logits(logits, tick, "prefill")
        nxt = lg.argmax(axis=-1)
        for s in prefilling:
            if not np.isfinite(lg[s]).all():
                self._quarantine(s, tick, "nonfinite_logits")
                continue
            slot = self.slots[s]
            slot.cursor += int(n_valid[s])
            self.metrics.on_prefill_step(slot.rid)
            if self.tracer is not None:
                self.tracer.event("prefill", tick, rid=slot.rid, slot=s,
                                  cursor=slot.cursor,
                                  prompt_len=len(slot.prompt),
                                  replay=slot.replay)
            if slot.cursor >= len(slot.prompt):
                # the chunk containing the last prompt token yields the
                # first generated token — TTFT lands here
                self._finish_prefill(s, int(nxt[s]),
                                     np.asarray(logits[s]), tick)
        return 1

    def _decode_phase(self, tick: int) -> int:
        stepwise_prefill = (self.prefill_mode == "full")
        tokens = np.zeros((self.n_slots, 1), np.int32)
        active = np.zeros((self.n_slots,), bool)
        for s, slot in enumerate(self.slots):
            if slot.state is SlotState.DECODING:
                tokens[s, 0] = slot.pending_token
                active[s] = True
            elif stepwise_prefill and slot.state is SlotState.PREFILLING:
                tokens[s, 0] = slot.prompt[slot.cursor]
                active[s] = True
        if not active.any():
            return 0
        span = (self.tracer.begin(
                    "call", tick, phase="decode", kind="decode",
                    arch=self.cfg.name,
                    participants=[s for s in range(self.n_slots)
                                  if active[s]],
                    occupancy=float(active.mean()))
                if self.tracer is not None else None)
        c0 = time.monotonic()
        args = (self.params, self.cache, jnp.asarray(tokens),
                jnp.asarray(active))
        if self.paged:
            args = args + (self._ptab(),)
        res = self._device_call("decode", "decode", self._decode, *args)
        dur_s = time.monotonic() - c0
        if span is not None:
            self.tracer.end(span, ok=res is not None)
        if res is None:
            for s in range(self.n_slots):
                if active[s]:
                    self._quarantine(s, tick, "step_exception")
            return 0
        logits, self.cache = res
        self.metrics.on_device_call("decode", kind="decode", dur_s=dur_s)
        lg = self._host_logits(logits, tick, "decode")
        nxt = lg.argmax(axis=-1)
        for s, slot in enumerate(self.slots):
            if not active[s]:
                continue
            if not np.isfinite(lg[s]).all():
                self._quarantine(s, tick, "nonfinite_logits")
                continue
            if slot.state is SlotState.PREFILLING:
                slot.cursor += 1
                self.metrics.on_prefill_step(slot.rid)
                if self.tracer is not None:
                    self.tracer.event("prefill", tick, rid=slot.rid,
                                      slot=s, cursor=slot.cursor,
                                      prompt_len=len(slot.prompt),
                                      replay=slot.replay)
                if slot.cursor >= len(slot.prompt):
                    self._finish_prefill(s, int(nxt[s]),
                                         np.asarray(logits[s]), tick)
                continue
            tok = int(nxt[s])
            self.outputs[slot.rid].append(tok)
            slot.pending_token = tok
            self.metrics.on_token(slot.rid)
            if self.journal is not None:
                self.journal.append("token", tick, rid=int(slot.rid),
                                    token=tok)
            if len(self.outputs[slot.rid]) >= slot.gen_len:
                self._release(s, tick)
        return 1

    # ----------------------------------------------- fault containment ----

    def _device_call(self, call: str, kind: str, fn, *args):
        """Run a device call under the fault contract: injected or real
        exceptions get ``max_step_retries`` re-issues (the injection
        layer raises BEFORE dispatch, so the donated cache buffer is
        intact for the retry); past the budget, returns None and the
        caller quarantines every participating slot. With no fault plan
        installed, real exceptions propagate unchanged — containment
        must never hide a programming error in a plain run.

        ``call`` is the fault-plan phase key ("prefill" / "decode");
        ``kind`` the compiled call_kind retries are attributed to."""
        attempt = 0
        while True:
            try:
                if self.fault_plan is not None:
                    self.fault_plan.check_step(self.tick_count, call,
                                               attempt)
                return fn(*args)
            except Exception as e:  # noqa: BLE001 — any step failure
                if self.fault_plan is None:
                    raise
                self.metrics.on_fault("step_exception", None,
                                      self.tick_count)
                if self.tracer is not None:
                    self.tracer.event("fault", self.tick_count,
                                      kind="step_exception", call=kind,
                                      attempt=attempt, error=str(e))
                attempt += 1
                if attempt > self.max_step_retries:
                    return None
                self.metrics.on_retry(kind)
                if self.tracer is not None:
                    self.tracer.event("retry", self.tick_count, call=kind,
                                      attempt=attempt)

    def _host_logits(self, logits, tick: int, call: str) -> np.ndarray:
        """Host-side (B, V) f32 logits for argmax + the finite-guard;
        the fault plan's nan_logits events poison rows here (the
        corruption a real device would hand back)."""
        lg = np.asarray(logits[:, 0, :], np.float32)
        if self.fault_plan is not None:
            bad = self.fault_plan.logit_slots(tick, call)
            if bad:
                lg = lg.copy()
                for s in bad:
                    lg[s] = np.nan
        return lg

    def _inject_cache_faults(self, tick: int):
        slots = [s for s in self.fault_plan.cache_slots(tick)
                 if self.slots[s].state is not SlotState.FREE]
        if not slots:
            return
        self.cache = corrupt_cache(self.cache, slots, self.n_slots,
                                   self.cfg)
        for s in slots:
            self.metrics.on_fault("cache_corruption", self.slots[s].rid,
                                  tick)
            if self.tracer is not None:
                self.tracer.event("fault", tick, kind="cache_corruption",
                                  rid=self.slots[s].rid, slot=s)

    def _quarantine(self, s: int, tick: int, kind: str):
        """Contain a fault to slot ``s`` and schedule recovery-by-replay:
        zero the slot's cache and re-prefill its durable record (prompt +
        tokens emitted so far). Because chunked prefill == sequential
        decode, the replayed stream continues bitwise as if the fault
        never happened. Past ``max_replays`` the request is shed
        ("fault_budget") — a slot that faults deterministically must
        converge to shedding, not livelock."""
        slot = self.slots[s]
        rid = slot.rid
        self.metrics.on_fault(kind, rid, tick)
        slot.fault_count += 1
        if self.tracer is not None:
            self.tracer.event("quarantine", tick, rid=rid, slot=s,
                              kind=kind, fault_count=slot.fault_count)
        if slot.fault_count > self.max_replays:
            self.metrics.on_shed(rid, tick, "fault_budget")
            if self.journal is not None:
                self.journal.append("shed", tick, rid=int(rid),
                                    reason="fault_budget")
            if self.tracer is not None:
                self.tracer.event("shed", tick, rid=rid, slot=s,
                                  reason="fault_budget")
            self._close_interval(s, tick)
            if self.paged:
                self.page_alloc.release(s)
            self.slots[s] = _Slot()
            return
        self.metrics.on_replay(rid)
        emitted = self.outputs[rid]
        record = (np.concatenate([slot.durable,
                                  np.asarray(emitted, np.int32)])
                  if emitted else slot.durable)
        slot.prompt = record
        slot.cursor = 0
        slot.pending_token = 0
        slot.replay = bool(emitted)
        slot.restore = False              # a fault replay, not restart work
        slot.state = SlotState.PREFILLING
        if self.tracer is not None:
            self.tracer.event("replay", tick, rid=rid, slot=s,
                              record_len=int(len(record)))
        mask = np.zeros((self.n_slots,), bool)
        mask[s] = True
        self.cache = self._reset_call(mask)

    # ------------------------------------------------------ SLO shedding --

    def _min_ticks_to_done(self, prompt_left: int, gen_left: int,
                           queued: bool = False) -> int:
        """OPTIMISTIC ticks (including the current one) until the
        request finishes: the last prefill chunk emits the first of the
        remaining tokens, then one token per tick. A lower bound, so a
        request is only ever shed when its deadline is provably
        unreachable.

        ``queued=True`` on a paged engine adds the page-wait floor: when
        the free pool cannot cover the prompt's pages, admission cannot
        happen THIS tick — at least one tick must pass for any release
        to free pages. Exactly +1 keeps the estimate a lower bound (one
        release could free everything needed)."""
        est = (((math.ceil(prompt_left / self.prefill_chunk)
                 if self.prefill_mode == "chunked" else prompt_left)
                + max(gen_left - 1, 0))
               if prompt_left > 0 else max(gen_left, 1))
        if queued and self.paged and \
                self.page_alloc.pages_for(prompt_left) > \
                self.page_alloc.free_pages:
            est += 1
        return est

    def _shed_hopeless_queue(self, tick: int):
        """Drop arrived queued requests whose deadline is unreachable
        even if admitted RIGHT NOW — load shedding before they waste a
        slot. O(arrived): the arrived prefix is popped, filtered, and
        pushed back."""
        kept = []
        while self.queue and self.queue[0].arrival <= tick:
            r = self.queue.popleft()
            est = self._min_ticks_to_done(r.prompt_len, r.gen_len,
                                          queued=True)
            if r.deadline is not None and tick + est - 1 > r.deadline:
                self.skips.pop(r.rid, None)
                self.metrics.on_shed(r.rid, tick, "deadline")
                if self.journal is not None:
                    self.journal.append("shed", tick, rid=int(r.rid),
                                        reason="deadline")
                if self.tracer is not None:
                    self.tracer.event("shed", tick, rid=r.rid,
                                      reason="deadline", where="queue")
            else:
                kept.append(r)
        self.queue.extendleft(reversed(kept))

    def _shed_hopeless_slots(self, tick: int):
        """Preempt in-flight requests the tick their deadline becomes
        unreachable — the slot is worth more to the queue than to a
        request that can no longer meet its SLO."""
        for s, slot in enumerate(self.slots):
            if slot.state is SlotState.FREE or slot.deadline is None:
                continue
            gen_left = slot.gen_len - len(self.outputs[slot.rid])
            prompt_left = (len(slot.prompt) - slot.cursor
                           if slot.state is SlotState.PREFILLING else 0)
            if tick + self._min_ticks_to_done(prompt_left, gen_left) - 1 \
                    > slot.deadline:
                self.metrics.on_shed(slot.rid, tick, "deadline")
                if self.journal is not None:
                    self.journal.append("shed", tick, rid=int(slot.rid),
                                        reason="deadline")
                if self.tracer is not None:
                    self.tracer.event("shed", tick, rid=slot.rid, slot=s,
                                      reason="deadline", where="slot")
                self._close_interval(s, tick)
                if self.paged:
                    self.page_alloc.release(s)
                self.slots[s] = _Slot()   # cache zeroed at next admit

    # ------------------------------------------------------------- helpers

    def _finish_prefill(self, s: int, token: int, logits: np.ndarray,
                        tick: int):
        slot = self.slots[s]
        slot.state = SlotState.DECODING
        slot.pending_token = token
        self.outputs[slot.rid].append(token)
        if not slot.replay:
            # a replayed record's final chunk yields the NEXT token of an
            # already-started stream, not the request's first — TTFT and
            # first_logits were recorded before the fault
            self.first_logits[slot.rid] = logits
            self.metrics.on_first_token(slot.rid, tick)
            if self.tracer is not None:
                self.tracer.event("first_token", tick, rid=slot.rid,
                                  slot=s)
        slot.replay = False
        slot.restore = False
        self.metrics.on_token(slot.rid)
        if self.journal is not None:
            self.journal.append("token", tick, rid=int(slot.rid),
                                token=int(token))
        if len(self.outputs[slot.rid]) >= slot.gen_len:
            self._release(s, tick)

    def _close_interval(self, s: int, tick: int):
        iv = self._open_interval.pop(s, None)
        if iv is not None:
            iv.release_tick = tick + 1
            if self.tracer is not None:
                self.tracer.interval(iv.slot, iv.rid, iv.admit_tick,
                                     iv.release_tick)

    def _release(self, s: int, tick: int):
        slot = self.slots[s]
        self.metrics.on_done(slot.rid, tick)
        if self.journal is not None:
            self.journal.append("done", tick, rid=int(slot.rid))
        if self.tracer is not None:
            self.tracer.event("release", tick, rid=slot.rid, slot=s,
                              tokens=len(self.outputs[slot.rid]))
        self._close_interval(s, tick)
        if self.paged:
            self.page_alloc.release(s)
        self.slots[s] = _Slot()           # FREE; cache zeroed at next admit
