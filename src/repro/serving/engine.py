"""Request-level serving engine: admission queue + per-slot state machine
+ fixed-shape jitted steps.

The engine owns a static batch of ``n_slots`` cache slots. Each request
moves through

    QUEUED -> PREFILLING -> DECODING -> DONE

with all scheduling host-side and all math in exactly TWO compiled
executables (three with slot reset), fixed-shape so NO recompilation ever
happens per request:

  * decode step   (B, 1) tokens + (B,) active mask
    (launch.steps.build_slot_decode_step — inactive slots' cache writes
    are discarded by models.decode.merge_slots);
  * prefill chunk (B, C) tokens + (B,) n_valid
    (serving.prefill.build_chunk_step — only in "chunked" mode);
  * slot reset — zeroes a freed slot's KV/SSM cache slices and position
    before admission (models.decode.reset_slots), so a refilled slot is
    indistinguishable from a fresh batch.

One engine TICK = admit -> (prefill chunk, if any slot is prefilling) ->
(decode step, if any slot is decoding). Prefill and decode are separate
device calls, so prefilling a newly admitted request NEVER stalls
in-flight decodes — decoding slots emit a token every tick regardless of
arrivals. In "full" prefill mode (the baseline), prompt tokens instead
ride the decode call one at a time.

Per-slot cache positions: cache["pos"] is a (B,) vector — slots hold
requests at different depths, which is what the vectorized
decode_attention / decode_chunk paths exist for.
"""

from __future__ import annotations

import enum
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.mesh import make_test_mesh
from repro.launch.steps import build_slot_decode_step
from repro.models import init_cache, reset_slots
from repro.runtime import sharding as shr
from repro.serving.metrics import MetricsRecorder
from repro.serving.prefill import (PREFILL_MODES, assemble_chunk,
                                   build_chunk_step)
from repro.serving.workload import Request


class SlotState(enum.Enum):
    FREE = "free"
    PREFILLING = "prefilling"
    DECODING = "decoding"


@dataclass
class _Slot:
    state: SlotState = SlotState.FREE
    rid: Optional[int] = None
    prompt: Optional[np.ndarray] = None
    cursor: int = 0                      # prompt tokens already in cache
    gen_len: int = 0
    pending_token: int = 0               # next decode input


@dataclass
class SlotInterval:
    """Audit record: slot s served rid from admit_tick until release_tick
    (exclusive). Tests verify intervals on one slot never overlap."""
    slot: int
    rid: int
    admit_tick: int
    release_tick: Optional[int] = None


class ServeEngine:
    """See module docstring. Typical use:

        engine = ServeEngine(cfg, params, n_slots=4, max_len=64,
                             prefill_chunk=16, stacked_tables=tables)
        results = engine.run(make_trace(spec, cfg.vocab_size))
        print(engine.metrics.summary())
    """

    def __init__(self, cfg, params, *, mesh=None, n_slots: int = 4,
                 max_len: int = 64, prefill_chunk: int = 16,
                 prefill_mode: str = "chunked", stacked_tables=None,
                 enc_out=None, max_ticks: int = 100_000):
        if prefill_mode not in PREFILL_MODES:
            raise ValueError(f"prefill_mode {prefill_mode!r} not in "
                             f"{PREFILL_MODES}")
        if prefill_mode == "chunked" and not cfg.supports_chunked_prefill:
            # windowed / MoE / hybrid / enc-dec families: chunk semantics
            # can't reproduce sequential decode — serve them stepwise
            prefill_mode = "full"
        self.cfg = cfg
        self.mesh = mesh or make_test_mesh()
        self.n_slots = n_slots
        self.max_len = max_len
        self.prefill_chunk = prefill_chunk
        self.prefill_mode = prefill_mode
        self.max_ticks = max_ticks

        self.params = params
        with self.mesh:
            cache = init_cache(cfg, n_slots, max_len, enc_out=enc_out)
            # per-slot positions from the start (merge_slots vectorizes
            # them anyway; starting scalar would recompile after tick 0)
            cache["pos"] = jnp.zeros((n_slots,), jnp.int32)
            if "attn" in cache and "pos" in cache["attn"]:
                cache["attn"]["pos"] = jnp.zeros((n_slots,), jnp.int32)
            self.cache = cache

            decode_fn, shard_fn = build_slot_decode_step(
                cfg, self.mesh, stacked_tables=stacked_tables)
            tok0 = jnp.zeros((n_slots, 1), jnp.int32)
            act0 = jnp.zeros((n_slots,), bool)
            pspec, cspec, tspec, aspec = shard_fn(params, cache, tok0, act0)
            self._decode = jax.jit(
                decode_fn,
                in_shardings=(shr.named(pspec, self.mesh),
                              shr.named(cspec, self.mesh),
                              shr.named(tspec, self.mesh),
                              shr.named(aspec, self.mesh)),
                donate_argnums=(1,))
            self._prefill = None
            if prefill_mode == "chunked":
                self._prefill = build_chunk_step(
                    cfg, self.mesh, params, cache, n_slots, prefill_chunk,
                    stacked_tables=stacked_tables)
            self._reset = jax.jit(
                lambda c, m: reset_slots(c, m, cfg), donate_argnums=(0,))

        self.queue: deque = deque()
        self.slots = [_Slot() for _ in range(n_slots)]
        self.tick_count = 0
        self.outputs: Dict[int, List[int]] = {}
        self.first_logits: Dict[int, np.ndarray] = {}
        self.slot_log: List[SlotInterval] = []
        self._open_interval: Dict[int, SlotInterval] = {}
        self.metrics = MetricsRecorder()

    # ------------------------------------------------------------------ API

    def submit(self, request: Request):
        total = request.prompt_len + request.gen_len
        if total > self.max_len:
            raise ValueError(
                f"request {request.rid}: prompt {request.prompt_len} + "
                f"gen {request.gen_len} exceeds max_len {self.max_len}")
        self.queue.append(request)
        self.metrics.on_submit(request.rid, request.prompt_len,
                               request.gen_len, request.arrival)

    def run(self, requests: List[Request]):
        """Serve a trace to completion; returns {rid: generated tokens}."""
        for r in sorted(requests, key=lambda r: (r.arrival, r.rid)):
            self.submit(r)
        self.metrics.start()
        while self.queue or any(s.state is not SlotState.FREE
                                for s in self.slots):
            self.tick()
            if self.tick_count > self.max_ticks:
                raise RuntimeError(f"engine exceeded max_ticks="
                                   f"{self.max_ticks}; scheduler stuck?")
        self.metrics.stop()
        return self.outputs

    # ------------------------------------------------------------- one tick

    def tick(self):
        tick = self.tick_count
        calls = 0
        self._admit(tick)
        if self.prefill_mode == "chunked":
            calls += self._prefill_phase(tick)
        calls += self._decode_phase(tick)
        self.metrics.on_tick(
            tick,
            queue_depth=len(self.queue),
            n_prefilling=sum(s.state is SlotState.PREFILLING
                             for s in self.slots),
            n_decoding=sum(s.state is SlotState.DECODING
                           for s in self.slots),
            device_calls=calls)
        self.tick_count += 1

    # -------------------------------------------------------------- phases

    def _admit(self, tick: int):
        """QUEUED -> PREFILLING: pop arrived requests into free slots and
        ZERO the slots' stale cache slices (the previous occupant's
        KV/SSM state must not leak into the new request)."""
        mask = np.zeros((self.n_slots,), bool)
        for s, slot in enumerate(self.slots):
            if slot.state is not SlotState.FREE or not self.queue:
                continue
            if self.queue[0].arrival > tick:
                break                     # trace is arrival-sorted
            req = self.queue.popleft()
            slot.state = SlotState.PREFILLING
            slot.rid = req.rid
            slot.prompt = np.asarray(req.prompt, np.int32)
            slot.cursor = 0
            slot.gen_len = req.gen_len
            slot.pending_token = 0
            mask[s] = True
            self.outputs[req.rid] = []
            self.metrics.on_admit(req.rid, tick)
            iv = SlotInterval(slot=s, rid=req.rid, admit_tick=tick)
            self.slot_log.append(iv)
            self._open_interval[s] = iv
        if mask.any():
            self.cache = self._reset(self.cache, jnp.asarray(mask))

    def _prefill_phase(self, tick: int) -> int:
        prefilling = {s: slot.prompt for s, slot in enumerate(self.slots)
                      if slot.state is SlotState.PREFILLING}
        if not prefilling:
            return 0
        cursors = {s: self.slots[s].cursor for s in prefilling}
        tokens, n_valid = assemble_chunk(prefilling, cursors, self.n_slots,
                                         self.prefill_chunk)
        logits, self.cache = self._prefill(self.params, self.cache,
                                           jnp.asarray(tokens),
                                           jnp.asarray(n_valid))
        self.metrics.on_device_call("prefill")
        nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1))
        for s in prefilling:
            slot = self.slots[s]
            slot.cursor += int(n_valid[s])
            self.metrics.on_prefill_step(slot.rid)
            if slot.cursor >= len(slot.prompt):
                # the chunk containing the last prompt token yields the
                # first generated token — TTFT lands here
                self._emit_first_token(s, int(nxt[s]),
                                       np.asarray(logits[s]), tick)
        return 1

    def _decode_phase(self, tick: int) -> int:
        stepwise_prefill = (self.prefill_mode == "full")
        tokens = np.zeros((self.n_slots, 1), np.int32)
        active = np.zeros((self.n_slots,), bool)
        for s, slot in enumerate(self.slots):
            if slot.state is SlotState.DECODING:
                tokens[s, 0] = slot.pending_token
                active[s] = True
            elif stepwise_prefill and slot.state is SlotState.PREFILLING:
                tokens[s, 0] = slot.prompt[slot.cursor]
                active[s] = True
        if not active.any():
            return 0
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(tokens),
                                          jnp.asarray(active))
        self.metrics.on_device_call("decode")
        nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1))
        for s, slot in enumerate(self.slots):
            if not active[s]:
                continue
            if slot.state is SlotState.PREFILLING:
                slot.cursor += 1
                self.metrics.on_prefill_step(slot.rid)
                if slot.cursor >= len(slot.prompt):
                    self._emit_first_token(s, int(nxt[s]),
                                           np.asarray(logits[s]), tick)
                continue
            tok = int(nxt[s])
            self.outputs[slot.rid].append(tok)
            slot.pending_token = tok
            self.metrics.on_token(slot.rid)
            if len(self.outputs[slot.rid]) >= slot.gen_len:
                self._release(s, tick)
        return 1

    # ------------------------------------------------------------- helpers

    def _emit_first_token(self, s: int, token: int, logits: np.ndarray,
                          tick: int):
        slot = self.slots[s]
        slot.state = SlotState.DECODING
        slot.pending_token = token
        self.outputs[slot.rid].append(token)
        self.first_logits[slot.rid] = logits
        self.metrics.on_first_token(slot.rid, tick)
        self.metrics.on_token(slot.rid)
        if slot.gen_len <= 1:
            self._release(s, tick)

    def _release(self, s: int, tick: int):
        slot = self.slots[s]
        self.metrics.on_done(slot.rid, tick)
        iv = self._open_interval.pop(s, None)
        if iv is not None:
            iv.release_tick = tick + 1
        self.slots[s] = _Slot()           # FREE; cache zeroed at next admit
