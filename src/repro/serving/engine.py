"""Request-level serving engine: admission queue + per-slot state machine
+ fixed-shape jitted steps.

The engine owns a static batch of ``n_slots`` cache slots. Each request
moves through

    QUEUED -> PREFILLING -> DECODING -> DONE

with all scheduling host-side and all math in exactly TWO compiled
executables (three with slot reset), fixed-shape so NO recompilation ever
happens per request:

  * decode step   (B, 1) tokens + (B,) active mask
    (launch.steps.build_step("decode") — inactive slots' cache writes
    are discarded by models.decode.merge_slots);
  * prefill chunk (B, C) tokens + (B,) n_valid
    (serving.prefill.build_chunk_step — only in "chunked" mode);
  * slot reset — zeroes a freed slot's KV/SSM cache slices and position
    before admission (models.decode.reset_slots), so a refilled slot is
    indistinguishable from a fresh batch.

One engine TICK = admit -> (prefill chunk, if any slot is prefilling) ->
(decode step, if any slot is decoding). Prefill and decode are separate
device calls, so prefilling a newly admitted request NEVER stalls
in-flight decodes — decoding slots emit a token every tick regardless of
arrivals. In "full" prefill mode (the baseline), prompt tokens instead
ride the decode call one at a time.

Admission order (``schedule``):

  * "fifo" (default) — strictly arrival order from one queue;
  * "spf" — shortest-prompt-first among ARRIVED requests: under mixed
    (bimodal) loads, short prompts stop queueing behind long prefills
    and mean TTFT drops. Starvation is bounded by ``spf_age_cap``:
    every shortest-first admission raises the skip count of every other
    arrived request it passed over; at the cap a request becomes urgent
    and is admitted before any non-urgent request (oldest-arrival
    first; urgent admissions are forced fairness, not jumps, and raise
    no counts). A non-urgent pick only happens when NOBODY is urgent,
    so skips <= spf_age_cap is a hard bound — no request is ever passed
    over by shortest-first picks more than ``spf_age_cap`` times, even
    when every request arrives at once — the invariant
    tests/test_serving_engine.py holds the scheduler to.

Per-slot cache positions: cache["pos"] is a (B,) vector — slots hold
requests at different depths, which is what the vectorized
decode_attention / decode_chunk paths exist for.
"""

from __future__ import annotations

import enum
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.mesh import make_test_mesh
from repro.launch.steps import build_step
from repro.models import init_cache, reset_slots
from repro.runtime import sharding as shr
from repro.serving.metrics import MetricsRecorder
from repro.serving.prefill import (PREFILL_MODES, assemble_chunk,
                                   build_chunk_step)
from repro.serving.workload import Request


class SlotState(enum.Enum):
    FREE = "free"
    PREFILLING = "prefilling"
    DECODING = "decoding"


@dataclass
class _Slot:
    state: SlotState = SlotState.FREE
    rid: Optional[int] = None
    prompt: Optional[np.ndarray] = None
    cursor: int = 0                      # prompt tokens already in cache
    gen_len: int = 0
    pending_token: int = 0               # next decode input


@dataclass
class SlotInterval:
    """Audit record: slot s served rid from admit_tick until release_tick
    (exclusive). Tests verify intervals on one slot never overlap."""
    slot: int
    rid: int
    admit_tick: int
    release_tick: Optional[int] = None


class ServeEngine:
    """See module docstring. Typical use:

        engine = ServeEngine(cfg, params, n_slots=4, max_len=64,
                             prefill_chunk=16, stacked_tables=tables)
        results = engine.run(make_trace(spec, cfg.vocab_size))
        print(engine.metrics.summary())
    """

    SCHEDULES = ("fifo", "spf")

    def __init__(self, cfg, params, *, mesh=None, n_slots: int = 4,
                 max_len: int = 64, prefill_chunk: int = 16,
                 prefill_mode: str = "chunked", schedule: str = "fifo",
                 spf_age_cap: int = 8, stacked_tables=None,
                 enc_out=None, max_ticks: int = 100_000):
        if prefill_mode not in PREFILL_MODES:
            raise ValueError(f"prefill_mode {prefill_mode!r} not in "
                             f"{PREFILL_MODES}")
        if schedule not in self.SCHEDULES:
            raise ValueError(f"schedule {schedule!r} not in "
                             f"{self.SCHEDULES}")
        if prefill_mode == "chunked" and \
                not cfg.serving_capabilities().chunked_prefill:
            # sliding-window families only: the ring cache needs stepwise
            # writes — every other family (MoE, hybrid, enc-dec included)
            # chunk-prefills through the segmented decode_chunk path
            prefill_mode = "full"
        self.cfg = cfg
        self.mesh = mesh or make_test_mesh()
        self.n_slots = n_slots
        self.max_len = max_len
        self.prefill_chunk = prefill_chunk
        self.prefill_mode = prefill_mode
        self.schedule = schedule
        self.spf_age_cap = spf_age_cap
        self.max_ticks = max_ticks

        self.params = params
        with self.mesh:
            cache = init_cache(cfg, n_slots, max_len, enc_out=enc_out)
            # per-slot positions from the start (merge_slots vectorizes
            # them anyway; starting scalar would recompile after tick 0)
            cache["pos"] = jnp.zeros((n_slots,), jnp.int32)
            if "attn" in cache and "pos" in cache["attn"]:
                cache["attn"]["pos"] = jnp.zeros((n_slots,), jnp.int32)
            self.cache = cache

            decode_fn, shard_fn = build_step(
                cfg, self.mesh, "decode", stacked_tables=stacked_tables)
            tok0 = jnp.zeros((n_slots, 1), jnp.int32)
            act0 = jnp.zeros((n_slots,), bool)
            pspec, cspec, tspec, aspec = shard_fn(params, cache, tok0, act0)
            self._decode = jax.jit(
                decode_fn,
                in_shardings=(shr.named(pspec, self.mesh),
                              shr.named(cspec, self.mesh),
                              shr.named(tspec, self.mesh),
                              shr.named(aspec, self.mesh)),
                donate_argnums=(1,))
            self._prefill = None
            if prefill_mode == "chunked":
                self._prefill = build_chunk_step(
                    cfg, self.mesh, params, cache, n_slots, prefill_chunk,
                    stacked_tables=stacked_tables)
            self._reset = jax.jit(
                lambda c, m: reset_slots(c, m, cfg), donate_argnums=(0,))

        # which chunk math this engine's prefill executable compiles to
        # ("prefill_parallel" / "prefill_chunk_exact"; None in "full" mode
        # where prompt tokens ride the decode call)
        self.prefill_kind = (self._prefill.call_kind
                             if self._prefill is not None else None)

        self.queue: deque = deque()
        self.skips: Dict[int, int] = {}   # rid -> times queue-jumped (spf)
        self.slots = [_Slot() for _ in range(n_slots)]
        self.tick_count = 0
        self.outputs: Dict[int, List[int]] = {}
        self.first_logits: Dict[int, np.ndarray] = {}
        self.slot_log: List[SlotInterval] = []
        self._open_interval: Dict[int, SlotInterval] = {}
        self.metrics = MetricsRecorder()

    # ------------------------------------------------------------------ API

    def submit(self, request: Request):
        total = request.prompt_len + request.gen_len
        if total > self.max_len:
            raise ValueError(
                f"request {request.rid}: prompt {request.prompt_len} + "
                f"gen {request.gen_len} exceeds max_len {self.max_len}")
        self.queue.append(request)
        self.skips[request.rid] = 0
        self.metrics.on_submit(request.rid, request.prompt_len,
                               request.gen_len, request.arrival)

    def run(self, requests: List[Request]):
        """Serve a trace to completion; returns {rid: generated tokens}."""
        for r in sorted(requests, key=lambda r: (r.arrival, r.rid)):
            self.submit(r)
        self.metrics.start()
        while self.queue or any(s.state is not SlotState.FREE
                                for s in self.slots):
            self.tick()
            if self.tick_count > self.max_ticks:
                raise RuntimeError(f"engine exceeded max_ticks="
                                   f"{self.max_ticks}; scheduler stuck?")
        self.metrics.stop()
        return self.outputs

    # ------------------------------------------------------------- one tick

    def tick(self):
        tick = self.tick_count
        calls = 0
        self._admit(tick)
        if self.prefill_mode == "chunked":
            calls += self._prefill_phase(tick)
        calls += self._decode_phase(tick)
        self.metrics.on_tick(
            tick,
            queue_depth=len(self.queue),
            n_prefilling=sum(s.state is SlotState.PREFILLING
                             for s in self.slots),
            n_decoding=sum(s.state is SlotState.DECODING
                           for s in self.slots),
            device_calls=calls)
        self.tick_count += 1

    # -------------------------------------------------------------- phases

    def _pop_next(self, tick: int):
        """Next request to admit, or None. "fifo" pops the head once it
        has arrived. "spf" picks the shortest ARRIVED prompt — unless a
        request has already been passed over ``spf_age_cap`` times, in
        which case the oldest such urgent request goes first. Every
        NON-urgent (shortest-first) pick raises the skip count of every
        other arrived request; urgent picks raise none (forced fairness
        is not a jump). Since a non-urgent pick requires the urgent set
        to be empty, a request at the cap can never be incremented
        again: skips[rid] <= spf_age_cap always, and deferral is bounded
        even when all requests arrive simultaneously."""
        arrived = [r for r in self.queue if r.arrival <= tick]
        if not arrived:                   # queue is arrival-sorted
            return None
        if self.schedule == "fifo":
            req = arrived[0]
        else:
            urgent = [r for r in arrived
                      if self.skips[r.rid] >= self.spf_age_cap]
            if urgent:
                req = urgent[0]           # oldest urgent arrival
            else:
                req = min(arrived,
                          key=lambda r: (r.prompt_len, r.arrival, r.rid))
                for r in arrived:
                    if r is not req:
                        self.skips[r.rid] += 1
        self.queue.remove(req)
        return req

    def _admit(self, tick: int):
        """QUEUED -> PREFILLING: pop arrived requests into free slots and
        ZERO the slots' stale cache slices (the previous occupant's
        KV/SSM state must not leak into the new request)."""
        mask = np.zeros((self.n_slots,), bool)
        for s, slot in enumerate(self.slots):
            if slot.state is not SlotState.FREE:
                continue
            req = self._pop_next(tick)
            if req is None:
                break
            slot.state = SlotState.PREFILLING
            slot.rid = req.rid
            slot.prompt = np.asarray(req.prompt, np.int32)
            slot.cursor = 0
            slot.gen_len = req.gen_len
            slot.pending_token = 0
            mask[s] = True
            self.outputs[req.rid] = []
            self.metrics.on_admit(req.rid, tick)
            iv = SlotInterval(slot=s, rid=req.rid, admit_tick=tick)
            self.slot_log.append(iv)
            self._open_interval[s] = iv
        if mask.any():
            self.cache = self._reset(self.cache, jnp.asarray(mask))

    def _prefill_phase(self, tick: int) -> int:
        prefilling = {s: slot.prompt for s, slot in enumerate(self.slots)
                      if slot.state is SlotState.PREFILLING}
        if not prefilling:
            return 0
        cursors = {s: self.slots[s].cursor for s in prefilling}
        tokens, n_valid = assemble_chunk(prefilling, cursors, self.n_slots,
                                         self.prefill_chunk)
        logits, self.cache = self._prefill(self.params, self.cache,
                                           jnp.asarray(tokens),
                                           jnp.asarray(n_valid))
        self.metrics.on_device_call("prefill")
        nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1))
        for s in prefilling:
            slot = self.slots[s]
            slot.cursor += int(n_valid[s])
            self.metrics.on_prefill_step(slot.rid)
            if slot.cursor >= len(slot.prompt):
                # the chunk containing the last prompt token yields the
                # first generated token — TTFT lands here
                self._emit_first_token(s, int(nxt[s]),
                                       np.asarray(logits[s]), tick)
        return 1

    def _decode_phase(self, tick: int) -> int:
        stepwise_prefill = (self.prefill_mode == "full")
        tokens = np.zeros((self.n_slots, 1), np.int32)
        active = np.zeros((self.n_slots,), bool)
        for s, slot in enumerate(self.slots):
            if slot.state is SlotState.DECODING:
                tokens[s, 0] = slot.pending_token
                active[s] = True
            elif stepwise_prefill and slot.state is SlotState.PREFILLING:
                tokens[s, 0] = slot.prompt[slot.cursor]
                active[s] = True
        if not active.any():
            return 0
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(tokens),
                                          jnp.asarray(active))
        self.metrics.on_device_call("decode")
        nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1))
        for s, slot in enumerate(self.slots):
            if not active[s]:
                continue
            if slot.state is SlotState.PREFILLING:
                slot.cursor += 1
                self.metrics.on_prefill_step(slot.rid)
                if slot.cursor >= len(slot.prompt):
                    self._emit_first_token(s, int(nxt[s]),
                                           np.asarray(logits[s]), tick)
                continue
            tok = int(nxt[s])
            self.outputs[slot.rid].append(tok)
            slot.pending_token = tok
            self.metrics.on_token(slot.rid)
            if len(self.outputs[slot.rid]) >= slot.gen_len:
                self._release(s, tick)
        return 1

    # ------------------------------------------------------------- helpers

    def _emit_first_token(self, s: int, token: int, logits: np.ndarray,
                          tick: int):
        slot = self.slots[s]
        slot.state = SlotState.DECODING
        slot.pending_token = token
        self.outputs[slot.rid].append(token)
        self.first_logits[slot.rid] = logits
        self.metrics.on_first_token(slot.rid, tick)
        self.metrics.on_token(slot.rid)
        if slot.gen_len <= 1:
            self._release(s, tick)

    def _release(self, s: int, tick: int):
        slot = self.slots[s]
        self.metrics.on_done(slot.rid, tick)
        iv = self._open_interval.pop(s, None)
        if iv is not None:
            iv.release_tick = tick + 1
        self.slots[s] = _Slot()           # FREE; cache zeroed at next admit
