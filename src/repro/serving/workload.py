"""Trace-driven load generation for the serving engine.

Traces are fully deterministic and carry NO wall-clock: arrival times are
measured in abstract ENGINE TICKS (one tick = one scheduler iteration),
inter-arrival gaps are Poisson (exponential with a fixed-seed generator),
and prompt/generation lengths come from configurable distributions. The
same spec + seed always yields the same trace, so engine runs are
reproducible and two prefill policies can be compared on identical load
— the methodology real-PIM workload studies (Gómez-Luna et al.; CIMinus)
use to keep architecture comparisons honest.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class Request:
    """One serving request. ``arrival`` is in engine ticks (no wall
    clock); the engine admits the request at the first tick >= arrival
    with a free slot. ``deadline`` (also in ticks) is the SLO: the
    request must be DONE by that tick or the engine sheds it — queued
    requests whose optimistic completion estimate already overshoots are
    dropped without ever occupying a slot, in-flight ones are preempted
    the tick the deadline becomes unreachable. None = no SLO."""
    rid: int
    prompt: Tuple[int, ...]            # prompt token ids, len >= 1
    gen_len: int                       # tokens to generate after prefill
    arrival: float = 0.0
    deadline: Optional[float] = None

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)


@dataclass(frozen=True)
class WorkloadSpec:
    """Knobs of the load generator. ``prompt_len`` / ``gen_len`` are
    inclusive (lo, hi) ranges; ``dist`` picks how prompt lengths spread:

      * "uniform":   plen ~ U[lo, hi] — mixed-length traffic;
      * "bimodal":   short (lo) and long (hi) prompts, 50/50 — the
                     chat-vs-document mix that stresses chunked prefill;
      * "fixed":     every prompt is exactly hi.
      * "lognormal": lo * LogNormal(0, 0.8) clipped to [lo, hi] — the
                     right-skewed long-tail real request logs show (most
                     prompts short, a heavy tail of long ones), the shape
                     that makes static worst-case cache slots wasteful
                     and paged pools win;
      * "zipf":      lo - 1 + Zipf(2.0) clipped to [lo, hi] — an even
                     heavier power-law tail.

    ``gen_dist`` spreads GENERATION lengths over ``gen_len`` with the
    same choices (default "uniform", matching older traces bit-for-bit).

    ``arrival_rate`` is requests per engine tick (Poisson); 0 puts every
    arrival at tick 0 (closed-loop batch). ``deadline_slack`` (ticks)
    gives every request the SLO ``deadline = arrival + deadline_slack``;
    None (default) disables deadlines entirely."""
    n_requests: int = 8
    arrival_rate: float = 0.5
    prompt_len: Tuple[int, int] = (4, 24)
    gen_len: Tuple[int, int] = (4, 12)
    dist: str = "uniform"
    gen_dist: str = "uniform"
    seed: int = 0
    deadline_slack: Optional[float] = None


def _sample_len(rng, lo: int, hi: int, dist: str) -> int:
    if dist == "fixed":
        return hi
    if dist == "bimodal":
        return lo if rng.random() < 0.5 else hi
    if dist == "uniform":
        return int(rng.integers(lo, hi + 1))
    if dist == "lognormal":
        return int(np.clip(round(lo * rng.lognormal(0.0, 0.8)), lo, hi))
    if dist == "zipf":
        return int(np.clip(lo - 1 + rng.zipf(2.0), lo, hi))
    raise ValueError(f"unknown dist {dist!r}")


def make_trace(spec: WorkloadSpec, vocab_size: int) -> List[Request]:
    """Deterministic request trace for `spec` (same spec -> same trace)."""
    rng = np.random.default_rng(spec.seed)
    t = 0.0
    out: List[Request] = []
    for rid in range(spec.n_requests):
        if spec.arrival_rate > 0:
            t += float(rng.exponential(1.0 / spec.arrival_rate))
        plen = _sample_len(rng, *spec.prompt_len, spec.dist)
        glen = _sample_len(rng, *spec.gen_len, spec.gen_dist)
        prompt = tuple(int(x) for x in
                       rng.integers(1, vocab_size, size=max(plen, 1)))
        out.append(Request(rid=rid, prompt=prompt, gen_len=max(glen, 1),
                           arrival=t,
                           deadline=(t + spec.deadline_slack
                                     if spec.deadline_slack is not None
                                     else None)))
    return out
