"""Serving metrics: TTFT, per-token latency, throughput, queue depth.

Two clocks, kept separate on purpose:

  * ENGINE TICKS / DEVICE STEPS — deterministic, trace-reproducible.
    TTFT in ticks and steps-per-served-token are what benchmarks guard
    (they cannot flake with machine load).
  * WALL CLOCK — tokens/sec and per-token latency, measured around the
    engine run for reporting only; traces themselves carry no wall time.
"""

from __future__ import annotations

import math
import time
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.obs.histogram import LogHistogram


@dataclass
class RequestMetrics:
    rid: int
    prompt_len: int
    gen_len: int
    arrival: float
    deadline: Optional[float] = None  # SLO tick; None = no deadline
    admitted_tick: Optional[int] = None
    first_token_tick: Optional[int] = None
    done_tick: Optional[int] = None
    prefill_steps: int = 0            # device calls spent filling the cache
    skips: int = 0                    # times queue-jumped before admission
    faults: int = 0                   # faults charged to this request
    replays: int = 0                  # recovery-by-replay re-prefills
    preemptions: int = 0              # page-pressure evictions suffered
    #: terminal outcome: "done", "rejected" (refused at submit),
    #: "shed" (dropped after acceptance — deadline or fault budget);
    #: None while queued / in flight
    outcome: Optional[str] = None
    reason: Optional[str] = None      # rejected/shed: why

    @property
    def ttft_ticks(self) -> Optional[int]:
        """Arrival -> first generated token, in engine ticks."""
        if self.first_token_tick is None:
            return None
        return self.first_token_tick - int(self.arrival)

    @property
    def admission_wait_ticks(self) -> Optional[int]:
        """Arrival -> admission, in engine ticks — the queueing share of
        TTFT, which is what SLO shedding decisions act on."""
        if self.admitted_tick is None:
            return None
        return self.admitted_tick - int(self.arrival)


@dataclass
class TickMetrics:
    tick: int
    queue_depth: int
    n_prefilling: int
    n_decoding: int
    device_calls: int
    # page-pool occupancy (paged engines only; None keeps contiguous
    # engines' rows and old snapshots loadable unchanged)
    pages_used: Optional[int] = None
    pages_total: Optional[int] = None


class MetricsRecorder:
    """Accumulates per-request and per-tick serving metrics."""

    def __init__(self):
        self.requests: Dict[int, RequestMetrics] = {}
        self.ticks: List[TickMetrics] = []
        self.decode_calls = 0
        self.prefill_calls = 0
        self.generated_tokens = 0
        # fault-tolerance counters (serving.faults / engine containment)
        self.faults: Dict[str, int] = {}        # fault kind -> count
        self.retries = 0                        # re-issued device calls
        #: retries by the failed call's call_kind tag — which executable
        #: kept going down, same attribution calls_by_kind gives replay
        #: traffic
        self.retries_by_kind: Dict[str, int] = {}
        self.replays = 0                        # recovery-by-replay resets
        self.rejected = 0                       # refused at submit
        self.shed = 0                           # dropped after acceptance
        self.straggler_ticks = 0                # wall-time outlier ticks
        # paging counters (paged engines; zero otherwise)
        self.preemptions = 0                    # page-pressure evictions
        self.alloc_failures = 0                 # unsatisfiable page asks
        #: device calls by the step's call_kind tag; replay prefills are
        #: tagged "<kind>+replay" so recovery traffic is attributable
        #: (launch.steps.build_step call_kind contract)
        self.calls_by_kind: Dict[str, int] = {}
        #: per-call wall latency, log-bucketed per call_kind tag —
        #: p50/p95/p99 without storing raw samples (obs.histogram)
        self.call_latency: Dict[str, LogHistogram] = {}
        #: closed slot-occupancy intervals [(slot, admit, release), ...]
        #: + slot count, installed by the engine (record_slot_log) so
        #: summary() can aggregate the audit log into utilization
        self._slot_log: List[Tuple[int, int, Optional[int]]] = []
        self._n_slots: int = 0
        self._t0: Optional[float] = None
        self._wall: float = 0.0

    @property
    def device_calls(self) -> int:
        return self.decode_calls + self.prefill_calls

    # -- lifecycle ---------------------------------------------------------
    def start(self):
        self._t0 = time.monotonic()

    def stop(self):
        # accumulate (don't overwrite): a restored engine loads the dead
        # process's wall total via load_state_dict and adds its own
        # start/stop segment on top
        if self._t0 is not None:
            self._wall += time.monotonic() - self._t0
            self._t0 = None

    # -- events ------------------------------------------------------------
    def on_submit(self, rid, prompt_len, gen_len, arrival, deadline=None):
        self.requests[rid] = RequestMetrics(
            rid=rid, prompt_len=prompt_len, gen_len=gen_len,
            arrival=arrival, deadline=deadline)

    def on_admit(self, rid, tick, skips: int = 0):
        r = self.requests[rid]
        if r.admitted_tick is None:
            # a preempted request's RE-admission must not move its
            # admission-wait clock — the user-visible wait ended at the
            # first admit
            r.admitted_tick = tick
            r.skips = skips

    def on_prefill_step(self, rid):
        self.requests[rid].prefill_steps += 1

    def on_first_token(self, rid, tick):
        self.requests[rid].first_token_tick = tick

    def on_token(self, rid):
        self.generated_tokens += 1

    def on_done(self, rid, tick):
        self.requests[rid].done_tick = tick
        self.requests[rid].outcome = "done"

    def on_tick(self, tick, queue_depth, n_prefilling, n_decoding,
                device_calls, pages_used=None, pages_total=None):
        self.ticks.append(TickMetrics(tick, queue_depth, n_prefilling,
                                      n_decoding, device_calls,
                                      pages_used, pages_total))

    def on_device_call(self, call: str, kind: Optional[str] = None,
                       replay: bool = False, restore: bool = False,
                       dur_s: Optional[float] = None):
        """``call`` is the engine phase ("decode" | "prefill");
        ``kind`` the compiled step's call_kind tag, suffixed "+replay"
        when the batch carries a recovering slot and "+restore" when it
        carries a slot re-prefilling after a warm restart (restore wins:
        restart traffic is the cost snapshot cadence trades against, so
        it must not hide inside the fault-replay bucket). ``dur_s``
        (wall seconds around the device call) feeds the per-kind
        log-bucketed latency histogram."""
        if call == "decode":
            self.decode_calls += 1
        elif call == "prefill":
            self.prefill_calls += 1
        tag = kind or call
        if restore:
            from repro.launch.steps import RESTORE_TAG
            tag += RESTORE_TAG
        elif replay:
            from repro.launch.steps import REPLAY_TAG
            tag += REPLAY_TAG
        self.calls_by_kind[tag] = self.calls_by_kind.get(tag, 0) + 1
        if dur_s is not None:
            if tag not in self.call_latency:
                self.call_latency[tag] = LogHistogram()
            self.call_latency[tag].add(dur_s)

    # -- fault-tolerance events --------------------------------------------
    def on_reject(self, rid, prompt_len, gen_len, arrival, reason: str,
                  deadline=None):
        """A request refused at submit: recorded, never admitted. The
        row exists so ``n_requests`` still counts every submission and
        results can report the rejection. If the rid already has a row
        (a "duplicate_rid" rejection), the ORIGINAL request's row must
        survive — only the rejection counter moves, or the duplicate
        would silently erase the live request's metrics."""
        if rid in self.requests:
            self.rejected += 1
            return
        r = RequestMetrics(rid=rid, prompt_len=prompt_len, gen_len=gen_len,
                           arrival=arrival, deadline=deadline)
        r.outcome, r.reason = "rejected", reason
        self.requests[rid] = r
        self.rejected += 1

    def on_shed(self, rid, tick, reason: str):
        """A request dropped AFTER acceptance — its deadline became
        unreachable or it exhausted the per-request fault budget."""
        r = self.requests[rid]
        r.outcome, r.reason = "shed", reason
        r.done_tick = None
        self.shed += 1

    def on_fault(self, kind: str, rid: Optional[int], tick: int):
        self.faults[kind] = self.faults.get(kind, 0) + 1
        if rid is not None and rid in self.requests:
            self.requests[rid].faults += 1

    def on_retry(self, call: str):
        """``call`` is the failed step's call_kind tag; the per-kind
        count makes "which executable kept failing" answerable (the old
        recorder dropped the argument on the floor)."""
        self.retries += 1
        self.retries_by_kind[call] = self.retries_by_kind.get(call, 0) + 1

    def on_replay(self, rid):
        self.replays += 1
        self.requests[rid].replays += 1

    # -- paging events -----------------------------------------------------
    def on_preempt(self, rid, tick):
        """A request evicted from its slot under page pressure (not a
        shed — it re-enters later with its stream intact)."""
        self.preemptions += 1
        if rid in self.requests:
            self.requests[rid].preemptions += 1

    def on_alloc_failure(self):
        """A page allocation that could not be satisfied this tick —
        the admission gate held a request back, or slot growth had to
        preempt. The counter is the page-pressure signal capacity
        planning reads (alloc failures ~ 0 means the pool is sized
        generously; climbing means preemption churn)."""
        self.alloc_failures += 1

    def on_straggler(self, tick):
        self.straggler_ticks += 1

    def record_slot_log(self, intervals: List[Tuple[int, int, Optional[int]]],
                        n_slots: int):
        """Install the engine's slot audit log — [(slot, admit_tick,
        release_tick-or-None), ...] — so summary() can aggregate it into
        ``slot_busy_frac`` / per-slot occupancy. The engine calls this
        at shutdown (the log was collected all along but never
        aggregated before); open intervals count as busy through the
        last tick."""
        self._slot_log = list(intervals)
        self._n_slots = n_slots

    # -- snapshot / restore ------------------------------------------------
    def state_dict(self) -> dict:
        """JSON-serializable full state — everything summary()/
        per_request() derive from. Saved inside engine snapshots
        (serving.snapshot) so a warm-restarted engine reports cumulative
        metrics, not just the post-restart segment. Wall time is saved
        as the accumulated total; the live ``_t0`` segment (if the
        recorder is mid-run) is intentionally NOT folded in — a snapshot
        taken mid-tick must not double-count when the same process later
        stops cleanly."""
        return {
            "requests": [asdict(r)
                         for r in sorted(self.requests.values(),
                                         key=lambda r: r.rid)],
            "ticks": [asdict(t) for t in self.ticks],
            "decode_calls": self.decode_calls,
            "prefill_calls": self.prefill_calls,
            "generated_tokens": self.generated_tokens,
            "faults": dict(self.faults),
            "retries": self.retries,
            "retries_by_kind": dict(self.retries_by_kind),
            "replays": self.replays,
            "rejected": self.rejected,
            "shed": self.shed,
            "straggler_ticks": self.straggler_ticks,
            "preemptions": self.preemptions,
            "alloc_failures": self.alloc_failures,
            "calls_by_kind": dict(self.calls_by_kind),
            "call_latency": {tag: h.to_dict()
                             for tag, h in self.call_latency.items()},
            "slot_log": [[s, a, r] for s, a, r in self._slot_log],
            "n_slots": self._n_slots,
            "wall": self._wall,
        }

    def load_state_dict(self, d: dict):
        """Inverse of state_dict (JSON round-trip safe: request rows are
        a list, so rids never go through string keys)."""
        self.requests = {int(row["rid"]): RequestMetrics(**row)
                         for row in d["requests"]}
        self.ticks = [TickMetrics(**row) for row in d["ticks"]]
        self.decode_calls = int(d["decode_calls"])
        self.prefill_calls = int(d["prefill_calls"])
        self.generated_tokens = int(d["generated_tokens"])
        self.faults = {str(k): int(v) for k, v in d["faults"].items()}
        self.retries = int(d["retries"])
        self.retries_by_kind = {str(k): int(v)
                                for k, v in d["retries_by_kind"].items()}
        self.replays = int(d["replays"])
        self.rejected = int(d["rejected"])
        self.shed = int(d["shed"])
        self.straggler_ticks = int(d["straggler_ticks"])
        # .get: pre-paging snapshots carry no paging counters
        self.preemptions = int(d.get("preemptions", 0))
        self.alloc_failures = int(d.get("alloc_failures", 0))
        self.calls_by_kind = {str(k): int(v)
                              for k, v in d["calls_by_kind"].items()}
        self.call_latency = {str(tag): LogHistogram.from_dict(h)
                             for tag, h in d["call_latency"].items()}
        self._slot_log = [(int(s), int(a), None if r is None else int(r))
                          for s, a, r in d["slot_log"]]
        self._n_slots = int(d["n_slots"])
        self._wall = float(d["wall"])
        self._t0 = None

    # -- summaries ---------------------------------------------------------
    def summary(self) -> dict:
        """Aggregate serving metrics.

        TTFT aggregates are computed over requests that REACHED a first
        token only — requests still queued/prefilling at shutdown have no
        TTFT yet, and folding a placeholder in would bias the mean.
        Instead of dropping them silently they are counted explicitly:
        ``ttft_n`` requests contributed, ``n_no_first_token`` did not
        (``ttft_n + n_no_first_token == n_requests`` always). All TTFT
        fields are None when nothing reached a first token (the
        all-queued-at-shutdown edge), never a crash. Percentiles are
        nearest-rank (ceil(q*n)-1), so p95 of 20 samples is the 19th
        value, not the max. ``prefill_steps_per_request_mean`` averages
        over every ADMITTED request — half-prefilled requests did real
        device work and dropping them would understate prefill cost.
        """
        with_ft = [r for r in self.requests.values()
                   if r.first_token_tick is not None]
        ttfts = sorted(r.ttft_ticks for r in with_ft)
        admitted = [r for r in self.requests.values()
                    if r.admitted_tick is not None]

        def pct(xs, q):
            if not xs:
                return None
            return xs[min(len(xs) - 1, max(0, math.ceil(q * len(xs)) - 1))]

        toks = self.generated_tokens
        calls = max(self.device_calls, 1)
        qd = [t.queue_depth for t in self.ticks]
        n_completed = sum(r.done_tick is not None
                          for r in self.requests.values())
        # slot utilization from the audit log (record_slot_log): busy
        # ticks per slot / engine ticks; open intervals run to the end
        n_ticks = len(self.ticks)
        slot_busy_frac = None
        slot_occupancy = None
        if self._n_slots and n_ticks:
            busy = [0] * self._n_slots
            for slot, admit, release in self._slot_log:
                end = n_ticks if release is None else min(release, n_ticks)
                busy[slot] += max(end - admit, 0)
            slot_occupancy = [b / n_ticks for b in busy]
            slot_busy_frac = sum(busy) / (self._n_slots * n_ticks)
        return {
            "n_requests": len(self.requests),
            "n_completed": n_completed,
            # fault-tolerance block: what went wrong and what it cost.
            # goodput is the serving-under-faults headline — completed
            # over EVERY submission, rejected and shed included.
            "n_rejected": self.rejected,
            "n_shed": self.shed,
            "faults": dict(self.faults),
            "n_faults": sum(self.faults.values()),
            "retries": self.retries,
            "retries_by_kind": dict(self.retries_by_kind),
            "replays": self.replays,
            "straggler_ticks": self.straggler_ticks,
            # paging block: preemption churn + page-pool occupancy over
            # the run (None when the engine is not paged)
            "n_preemptions": self.preemptions,
            "page_alloc_failures": self.alloc_failures,
            "pages_used_mean": (
                sum(pu) / len(pu) if (pu := [t.pages_used
                                             for t in self.ticks
                                             if t.pages_used is not None])
                else None),
            "pages_used_max": max(pu) if pu else None,
            "pages_total": next(
                (t.pages_total for t in self.ticks
                 if t.pages_total is not None), None),
            "calls_by_kind": dict(self.calls_by_kind),
            "call_latency_ms": {tag: h.summary_ms()
                                for tag, h in self.call_latency.items()},
            # from the slot audit log; None until record_slot_log runs
            "slot_busy_frac": slot_busy_frac,
            "slot_occupancy": slot_occupancy,
            "goodput": n_completed / max(len(self.requests), 1),
            "ttft_n": len(ttfts),
            "n_no_first_token": len(self.requests) - len(ttfts),
            "generated_tokens": toks,
            "engine_ticks": len(self.ticks),
            "device_calls": self.device_calls,
            "decode_calls": self.decode_calls,
            "prefill_calls": self.prefill_calls,
            "tokens_per_step": toks / calls,
            "steps_per_token": calls / max(toks, 1),
            "ttft_ticks_mean": (sum(ttfts) / len(ttfts)) if ttfts else None,
            "ttft_ticks_p50": pct(ttfts, 0.50),
            "ttft_ticks_p95": pct(ttfts, 0.95),
            "prefill_steps_per_request_mean": (
                sum(r.prefill_steps for r in admitted) / len(admitted)
                if admitted else None),
            "queue_depth_mean": (sum(qd) / len(qd)) if qd else 0.0,
            "queue_depth_max": max(qd) if qd else 0,
            "wall_s": self._wall,
            "tokens_per_sec": (toks / self._wall) if self._wall else None,
            "per_token_latency_ms": (1e3 * self._wall / toks
                                     if self._wall and toks else None),
        }

    def per_request(self) -> List[dict]:
        out = []
        for r in sorted(self.requests.values(), key=lambda r: r.rid):
            out.append({
                "rid": r.rid, "prompt_len": r.prompt_len,
                "gen_len": r.gen_len, "arrival": r.arrival,
                "deadline": r.deadline,
                "admitted_tick": r.admitted_tick,
                "admission_wait_ticks": r.admission_wait_ticks,
                "first_token_tick": r.first_token_tick,
                "done_tick": r.done_tick,
                "ttft_ticks": r.ttft_ticks,
                "prefill_steps": r.prefill_steps,
                "skips": r.skips,
                "faults": r.faults,
                "replays": r.replays,
                "preemptions": r.preemptions,
                "outcome": r.outcome,
                "reason": r.reason,
            })
        return out
