"""Chunked cache-filling prefill for the serving engine.

Two prefill policies over the SAME per-slot caches:

  * "chunked" — fixed-shape (B, prefill_chunk) chunks through
    ``launch.steps.build_step("prefill_chunk")`` (-> models.decode_chunk):
    each prefilling slot advances up to ``prefill_chunk`` prompt tokens
    per device call, so time-to-first-token is ceil(P/C) calls. Chunks
    ride the stacked joint-sparse tables exactly like decode steps.
  * "full" — the full-forward baseline: prompt tokens feed the ordinary
    (B, 1) decode step one at a time (P calls to first token). Prefilling
    slots share the decode call with in-flight decodes, so this is the
    honest continuous-batching baseline, not a strawman.

Within "chunked", the per-token math comes in two flavors, dispatched by
ModelConfig (the compiled step's ``call_kind`` tag says which):

  * exact ("prefill_chunk_exact") — attention families (a chunk already
    projects all C tokens in one matmul) and SSM with
    ``cfg.prefill_exact=True``: bit-identical to sequential decode.
  * parallel SSD ("prefill_parallel") — the SSM default: the chunk is
    evaluated in the training-style matrix form
    (models.ssm.prefill_ssm_parallel), reading the stacked in/out
    projections ONCE per chunk instead of once per token (~C x less SSM
    prefill weight traffic), tolerance-equal to sequential decode
    (models.ssm.PARALLEL_PREFILL_ATOL), not bitwise.

Exact policies never change generated tokens — only step counts move.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.launch.steps import build_step
from repro.runtime import sharding as shr

PREFILL_MODES = ("chunked", "full")


def assemble_chunk(prompts: Dict[int, np.ndarray], cursors: Dict[int, int],
                   n_slots: int, chunk: int
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Fixed-shape chunk batch from ragged per-slot prompt cursors.

    prompts/cursors map slot -> prompt array / tokens already prefilled.
    Returns (tokens (n_slots, chunk) int32, n_valid (n_slots,) int32);
    slots absent from `prompts` get n_valid 0 (their cache is untouched
    by the chunk step). Tail chunks are ragged: n_valid < chunk."""
    tokens = np.zeros((n_slots, chunk), np.int32)
    n_valid = np.zeros((n_slots,), np.int32)
    for s, prompt in prompts.items():
        cur = cursors[s]
        n = min(chunk, len(prompt) - cur)
        if n <= 0:
            continue
        tokens[s, :n] = prompt[cur:cur + n]
        n_valid[s] = n
    return tokens, n_valid


def build_chunk_step(cfg, mesh, params, cache, n_slots: int, chunk: int,
                     stacked_tables=None, paged: bool = False,
                     max_pages: int = 0):
    """Jit the fixed-shape chunk prefill step with serving shardings.

    Compiles ONCE for (n_slots, chunk) — every request, whatever its
    prompt length, flows through this single executable (ragged tails via
    n_valid), which is what keeps admission latency flat under load.

    paged=True compiles the page-table variant: one extra trailing
    ``ptab`` (n_slots, max_pages) int32 operand (the host allocator's
    table) the KV writes scatter through. The table is per-call data,
    not cache state — page churn between calls never recompiles."""
    import jax.numpy as jnp

    step_fn, shard_fn = build_step(cfg, mesh, "prefill_chunk",
                                   stacked_tables=stacked_tables,
                                   paged=paged)
    tok0 = jnp.zeros((n_slots, chunk), jnp.int32)
    nv0 = jnp.zeros((n_slots,), jnp.int32)
    if paged:
        pt0 = jnp.full((n_slots, max_pages), -1, jnp.int32)
        pspec, cspec, tspec, nspec, ptspec = shard_fn(params, cache, tok0,
                                                      nv0, pt0)
        in_sh = (shr.named(pspec, mesh), shr.named(cspec, mesh),
                 shr.named(tspec, mesh), shr.named(nspec, mesh),
                 shr.named(ptspec, mesh))
    else:
        pspec, cspec, tspec, nspec = shard_fn(params, cache, tok0, nv0)
        in_sh = (shr.named(pspec, mesh), shr.named(cspec, mesh),
                 shr.named(tspec, mesh), shr.named(nspec, mesh))
    jitted = jax.jit(step_fn,
                     in_shardings=in_sh,
                     # pin the returned cache to the spec it arrives
                     # with; propagated (replicated) output shardings
                     # make downstream steps recompile at tick 1
                     out_shardings=(None, shr.named(cspec, mesh)),
                     donate_argnums=(1,))
    # per-kind cost attribution rides along (jaxpr_cost.analyze_call_kinds)
    jitted.call_kind = step_fn.call_kind
    jitted.arch = cfg.name
    return jitted
