"""Chunked cache-filling prefill for the serving engine.

Two prefill policies over the SAME per-slot caches:

  * "chunked" — fixed-shape (B, prefill_chunk) chunks through
    ``launch.steps.build_prefill_chunk_step`` (-> models.decode_chunk):
    each prefilling slot advances up to ``prefill_chunk`` prompt tokens
    per device call, so time-to-first-token is ceil(P/C) calls. Chunks
    ride the stacked joint-sparse tables exactly like decode steps.
  * "full" — the full-forward baseline: prompt tokens feed the ordinary
    (B, 1) decode step one at a time (P calls to first token). Prefilling
    slots share the decode call with in-flight decodes, so this is the
    honest continuous-batching baseline, not a strawman.

Both fill caches through identical per-token math (decode_chunk is
bit-identical to sequential decode steps by construction), so the engine
can swap policies without changing results — only step counts move.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.launch.steps import build_prefill_chunk_step
from repro.runtime import sharding as shr

PREFILL_MODES = ("chunked", "full")


def assemble_chunk(prompts: Dict[int, np.ndarray], cursors: Dict[int, int],
                   n_slots: int, chunk: int
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Fixed-shape chunk batch from ragged per-slot prompt cursors.

    prompts/cursors map slot -> prompt array / tokens already prefilled.
    Returns (tokens (n_slots, chunk) int32, n_valid (n_slots,) int32);
    slots absent from `prompts` get n_valid 0 (their cache is untouched
    by the chunk step). Tail chunks are ragged: n_valid < chunk."""
    tokens = np.zeros((n_slots, chunk), np.int32)
    n_valid = np.zeros((n_slots,), np.int32)
    for s, prompt in prompts.items():
        cur = cursors[s]
        n = min(chunk, len(prompt) - cur)
        if n <= 0:
            continue
        tokens[s, :n] = prompt[cur:cur + n]
        n_valid[s] = n
    return tokens, n_valid


def build_chunk_step(cfg, mesh, params, cache, n_slots: int, chunk: int,
                     stacked_tables=None):
    """Jit the fixed-shape chunk prefill step with serving shardings.

    Compiles ONCE for (n_slots, chunk) — every request, whatever its
    prompt length, flows through this single executable (ragged tails via
    n_valid), which is what keeps admission latency flat under load."""
    import jax.numpy as jnp

    step_fn, shard_fn = build_prefill_chunk_step(
        cfg, mesh, stacked_tables=stacked_tables)
    tok0 = jnp.zeros((n_slots, chunk), jnp.int32)
    nv0 = jnp.zeros((n_slots,), jnp.int32)
    pspec, cspec, tspec, nspec = shard_fn(params, cache, tok0, nv0)
    return jax.jit(step_fn,
                   in_shardings=(shr.named(pspec, mesh),
                                 shr.named(cspec, mesh),
                                 shr.named(tspec, mesh),
                                 shr.named(nspec, mesh)),
                   donate_argnums=(1,))
