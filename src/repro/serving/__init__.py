"""Request-level serving: engine, chunked prefill, load gen, metrics."""

from .engine import ServeEngine, SlotState  # noqa: F401
from .metrics import MetricsRecorder  # noqa: F401
from .prefill import PREFILL_MODES, assemble_chunk  # noqa: F401
from .workload import Request, WorkloadSpec, make_trace  # noqa: F401
