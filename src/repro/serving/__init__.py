"""Request-level serving: engine, chunked prefill, load gen, metrics,
deterministic fault injection."""

from .engine import EngineStuckError, ServeEngine, SlotState  # noqa: F401
from .faults import (FAULT_KINDS, FaultEvent, FaultPlan,  # noqa: F401
                     InjectedFault)
from .metrics import MetricsRecorder  # noqa: F401
from .prefill import PREFILL_MODES, assemble_chunk  # noqa: F401
from .workload import Request, WorkloadSpec, make_trace  # noqa: F401
