"""Request-level serving: engine, chunked prefill, load gen, metrics,
deterministic fault injection, and the durability layer (write-ahead
journal + snapshot/restore for bitwise warm restarts)."""

from .engine import EngineStuckError, ServeEngine, SlotState  # noqa: F401
from .faults import (FAULT_KINDS, INJECTABLE_KINDS,  # noqa: F401
                     EngineCrash, FaultEvent, FaultPlan, InjectedFault)
from .journal import (Journal, JournalError, fold_records,  # noqa: F401
                      read_journal)
from .metrics import MetricsRecorder  # noqa: F401
from .paging import PageAllocError, PageAllocator  # noqa: F401
from .prefill import PREFILL_MODES, assemble_chunk  # noqa: F401
from .snapshot import (SnapshotError, read_snapshot_meta,  # noqa: F401
                       save_snapshot)
from .workload import Request, WorkloadSpec, make_trace  # noqa: F401
