"""Engine snapshot / restore: periodic durable state + journal-tail replay.

A snapshot is one atomic checkpoint (checkpoint.checkpoint: tmp dir +
fsync + ``os.replace`` publish, so a kill mid-snapshot leaves the
previous good step untouched) holding BOTH halves of engine state:

  * the DEVICE half — the full slot-cache pytree (KV/SSM state +
    per-slot positions + shared enc_out), saved as the checkpoint tree;
  * the HOST half — manifest ``extra``: per-slot state machine rows,
    the admission queue, outputs so far, skip counts, the slot audit
    log, the full metrics state, and the engine's construction config.

The snapshot also records ``journal_offset`` — the journal's durable
byte offset at save time — which is the seam the two durability layers
compose at: everything at or before the offset is already reflected in
the snapshot; everything after it is the TAIL that restore replays.

Restore (``restore_engine_state``, driven by ``ServeEngine.restore``):

  1. load the latest (or requested) snapshot; device_put the cache back
     under the engine's serving sharding;
  2. read the journal tail past ``journal_offset`` and fold it
     (journal.fold_records): post-snapshot submits extend the queue,
     admits move requests into slots, tokens extend outputs, done/shed/
     reject settle terminal states — metrics are re-applied in record
     order so counters stay cumulative across the crash;
  3. rebuild each occupied slot as PREFILLING over its durable record
     ``prompt + all journaled tokens`` with the cursor at the snapshot's
     cache-token count — the PR 7 replay path. Because chunked prefill
     is bit-identical to sequential decode (``prefill_exact`` on the SSM
     parallel path), finishing that re-prefill emits exactly the NEXT
     token of the stream, bitwise: a killed-and-restored run is
     indistinguishable from an uninterrupted one, token for token.
     Slots admitted after the snapshot have no trusted cache and
     re-prefill from zero (their slices are mask-reset first).

Cadence is the replay-work dial: a slot decodes at most one token per
tick, so the journal-evidenced work a restore re-enters is bounded by
(ticks since last snapshot) per slot — ``snapshot_every * n_slots``
total, the bound the kill-chaos bench guards. The final re-entered
token of each record is NOT redone work (its argmax yields the next NEW
token — the uninterrupted engine spends a decode call on the same
position), which is why ``replayed_prefill_tokens`` counts
``evidenced - cursor``, not record length.

What is deliberately NOT restored: ``first_logits`` (a debugging
convenience for guards, meaningful only within one process's run) and
the per-tick metrics series for ticks between the snapshot and the
crash (the dead process's memory; counters — tokens, calls, faults —
stay exact because token counters are re-applied from the journal,
while the lost ticks' DEVICE-call rows died with the process).
"""

from __future__ import annotations

import json
from collections import deque
from pathlib import Path
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import (latest_step, load_checkpoint,
                                         save_checkpoint)

#: v2 added the paged-engine fields: ``engine.paged/page_size/n_pages``
#: plus the ``paging`` block (per-slot page ownership + the preempted
#: re-admission deque). v1 snapshots stay restorable — the new fields
#: default to the contiguous engine.
SNAPSHOT_VERSION = 2
_READABLE_VERSIONS = (1, 2)

#: engine construction knobs stored in (and restored from) the manifest
ENGINE_KEYS = ("n_slots", "max_len", "prefill_chunk", "prefill_mode",
               "schedule", "spf_age_cap", "max_ticks", "strict",
               "queue_cap", "max_step_retries", "max_replays",
               "snapshot_every", "snapshot_keep")


class SnapshotError(RuntimeError):
    """Snapshot/engine mismatch or a structurally bad snapshot."""


def _slot_rows(engine) -> List[dict]:
    from repro.serving.engine import SlotState
    rows = []
    for slot in engine.slots:
        if slot.state is SlotState.FREE:
            rows.append({"state": "free"})
            continue
        emitted = engine.outputs.get(slot.rid, [])
        if slot.state is SlotState.DECODING:
            # cache holds prompt + all emitted tokens EXCEPT the newest
            # (pending_token): the decode that emits token m consumed
            # token m-1, writing position P+m-2 — so P+m-1 tokens total
            cache_tokens = len(slot.durable) + len(emitted) - 1
        else:
            cache_tokens = slot.cursor
        rows.append({
            "state": slot.state.value, "rid": int(slot.rid),
            "durable": [int(t) for t in slot.durable],
            "cursor": int(slot.cursor),
            "cache_tokens": int(cache_tokens),
            "gen_len": int(slot.gen_len),
            "pending_token": int(slot.pending_token),
            "deadline": (None if slot.deadline is None
                         else float(slot.deadline)),
            "fault_count": int(slot.fault_count),
            "replay": bool(slot.replay),
            "admit_seq": int(slot.admit_seq),
        })
    return rows


def save_snapshot(engine) -> str:
    """Write one atomic engine snapshot at step = completed tick count.
    Host-side state rides the manifest ``extra``; the cache pytree is
    the checkpoint tree. Returns the published step directory."""
    if engine.snapshot_dir is None:
        raise SnapshotError("engine has no snapshot_dir configured")
    host_cache = jax.tree_util.tree_map(np.asarray, engine.cache)
    extra = {
        "version": SNAPSHOT_VERSION,
        "tick": int(engine.tick_count),
        "journal_offset": (engine.journal.offset
                           if engine.journal is not None else None),
        "engine": {"arch": engine.cfg.name, "paged": bool(engine.paged),
                   **{k: getattr(engine, k) for k in ENGINE_KEYS}},
        "slots": _slot_rows(engine),
        "queue": [{"rid": int(r.rid),
                   "prompt": [int(t) for t in r.prompt],
                   "gen_len": int(r.gen_len),
                   "arrival": float(r.arrival),
                   "deadline": (None if r.deadline is None
                                else float(r.deadline))}
                  for r in engine.queue],
        "skips": {str(k): int(v) for k, v in engine.skips.items()},
        "outputs": {str(k): [int(t) for t in v]
                    for k, v in engine.outputs.items()},
        "rejected": {str(k): v for k, v in engine.rejected.items()},
        "duplicate_rids": [int(r) for r in engine.duplicate_rids],
        "has_deadlines": bool(engine._has_deadlines),
        "slot_log": [[iv.slot, iv.rid, iv.admit_tick, iv.release_tick]
                     for iv in engine.slot_log],
        "metrics": engine.metrics.state_dict(),
    }
    if engine.paged:
        extra["engine"]["page_size"] = int(engine.page_size)
        extra["engine"]["n_pages"] = int(engine.n_pages)
        extra["paging"] = {
            # page ownership at snapshot time: the positions already in
            # the pool for a trusted slot live in EXACTLY these pages,
            # in position order — restore must pin them back
            "slot_pages": engine.page_alloc.slot_pages(),
            # the FIFO re-admission deque (requests evicted under page
            # pressure, still waiting); their emitted tokens are in
            # ``outputs``, so rid + durable record reconstructs them
            "preempted": [{"rid": int(p.rid),
                           "durable": [int(t) for t in p.durable],
                           "gen_len": int(p.gen_len),
                           "deadline": (None if p.deadline is None
                                        else float(p.deadline)),
                           "fault_count": int(p.fault_count)}
                          for p in engine.preempted],
        }
    return save_checkpoint(engine.snapshot_dir, engine.tick_count,
                           {"cache": host_cache}, extra=extra,
                           keep=engine.snapshot_keep)


def read_snapshot_meta(snapshot_dir: str,
                       step: Optional[int] = None) -> Tuple[int, dict]:
    """Manifest ``extra`` of the latest (or given) snapshot, without
    touching the cache arrays — ServeEngine.restore reads this first to
    construct the replacement engine with matching geometry."""
    if step is None:
        step = latest_step(snapshot_dir)
        if step is None:
            raise FileNotFoundError(f"no snapshots in {snapshot_dir}")
    man = Path(snapshot_dir) / f"step_{step:010d}" / "manifest.json"
    extra = json.loads(man.read_text())["extra"]
    if extra.get("version") not in _READABLE_VERSIONS:
        raise SnapshotError(f"unknown snapshot version "
                            f"{extra.get('version')!r}")
    return step, extra


def _request_from(d: dict):
    from repro.serving.workload import Request
    return Request(rid=int(d["rid"]), prompt=tuple(d["prompt"]),
                   gen_len=int(d["gen_len"]), arrival=float(d["arrival"]),
                   deadline=(None if d.get("deadline") is None
                             else float(d["deadline"])))


def restore_engine_state(engine, snapshot_dir: str, step: int, *,
                         journal_path: Optional[str] = None,
                         journal_fsync: bool = True) -> dict:
    """Rebuild ``engine`` (freshly constructed, idle) from snapshot
    ``step`` plus the journal tail. Returns the restore stats dict (also
    left on ``engine.restore_stats``). See module docstring for the
    replay math."""
    from repro.serving.engine import (SlotInterval, SlotState, _Preempted,
                                      _Slot)
    from repro.serving.journal import Journal, fold_records, read_journal

    # geometry gate BEFORE touching cache arrays: a paged<->contiguous
    # mismatch would otherwise die inside load_checkpoint on leaf-key
    # inequality instead of saying what is actually wrong
    step, extra = read_snapshot_meta(snapshot_dir, step)
    eng_meta = extra["engine"]
    if eng_meta["arch"] != engine.cfg.name:
        raise SnapshotError(f"snapshot arch {eng_meta['arch']!r} != "
                            f"engine arch {engine.cfg.name!r}")
    for k in ("n_slots", "max_len", "prefill_chunk", "prefill_mode"):
        if eng_meta[k] != getattr(engine, k):
            raise SnapshotError(
                f"snapshot {k}={eng_meta[k]!r} != engine "
                f"{getattr(engine, k)!r} — restore needs identical "
                f"geometry for the cache layout to be meaningful")
    if bool(eng_meta.get("paged", False)) != engine.paged:
        raise SnapshotError(
            f"snapshot paged={eng_meta.get('paged', False)!r} != engine "
            f"paged={engine.paged!r} — the cache representations are "
            f"not interchangeable")
    if engine.paged:
        for k in ("page_size", "n_pages"):
            if int(eng_meta[k]) != getattr(engine, k):
                raise SnapshotError(
                    f"snapshot {k}={eng_meta[k]!r} != engine "
                    f"{getattr(engine, k)!r} — page ids in the snapshot "
                    f"table index a pool of this exact geometry")
    cache_like = jax.tree_util.tree_map(np.asarray, engine.cache)
    tree, step, extra = load_checkpoint(snapshot_dir, {"cache": cache_like},
                                        step)
    engine.cache = jax.device_put(tree["cache"], engine._cache_sharding)

    # -- journal tail (records the snapshot does NOT already reflect) --
    tail: List[dict] = []
    if journal_path is not None and Path(journal_path).exists():
        start = int(extra.get("journal_offset") or 0)
        tail, _, _ = read_journal(journal_path, start=start)
    fold = fold_records(tail)

    # -- queue: snapshot queue + tail submits − tail admits/sheds ------
    queue_reqs = {int(q["rid"]): _request_from(q) for q in extra["queue"]}
    requests_by_rid = dict(queue_reqs)
    for rid, rec in fold["submits"].items():
        req = _request_from(rec)
        queue_reqs[req.rid] = req
        requests_by_rid[req.rid] = req
    for rid in list(queue_reqs):
        if rid in fold["admitted"] or rid in fold["shed"]:
            del queue_reqs[rid]
    engine.queue = deque(sorted(queue_reqs.values(),
                                key=lambda r: (r.arrival, r.rid)))
    # skip counts: snapshot values for still-queued rids (spf picks
    # between snapshot and crash are the dead process's memory — the
    # cap-bound restarts from the snapshot's counts)
    engine.skips = {int(k): int(v) for k, v in extra["skips"].items()
                    if int(k) in queue_reqs}
    for rid in queue_reqs:
        engine.skips.setdefault(rid, 0)

    # -- outputs / terminal maps ---------------------------------------
    outputs = {int(k): [int(t) for t in v]
               for k, v in extra["outputs"].items()}
    for rid, toks in fold["tokens"].items():
        outputs.setdefault(int(rid), []).extend(int(t) for t in toks)
    for rid in fold["admitted"]:
        outputs.setdefault(int(rid), [])
    engine.outputs = outputs
    engine.rejected = {int(k): str(v)
                       for k, v in extra["rejected"].items()}
    engine.duplicate_rids = [int(r) for r in extra["duplicate_rids"]]
    for rid, rec in fold["rejected"].items():
        if rec["reason"] == "duplicate_rid":
            engine.duplicate_rids.append(int(rid))
        else:
            engine.rejected[int(rid)] = rec["reason"]
    engine._has_deadlines = bool(extra["has_deadlines"]) or any(
        r.get("deadline") is not None for r in fold["submits"].values())

    # -- metrics: snapshot state + tail re-applied in record order -----
    engine.metrics.load_state_dict(extra["metrics"])
    m = engine.metrics
    for rec in tail:
        kind, rid, tick = rec["kind"], rec.get("rid"), rec["tick"]
        if kind == "submit":
            m.on_submit(rid, len(rec["prompt"]), rec["gen_len"],
                        rec["arrival"], deadline=rec["deadline"])
        elif kind == "admit":
            m.on_admit(rid, tick, skips=rec.get("skips", 0))
        elif kind == "token":
            if m.requests[rid].first_token_tick is None:
                m.on_first_token(rid, tick)
            m.on_token(rid)
        elif kind == "done":
            m.on_done(rid, tick)
        elif kind == "shed":
            m.on_shed(rid, tick, rec["reason"])
        elif kind == "reject":
            m.on_reject(rid, rec["prompt_len"], rec["gen_len"],
                        rec["arrival"], rec["reason"],
                        deadline=rec["deadline"])
        elif kind == "preempt":
            m.on_preempt(rid, tick)

    # -- slot audit log + live occupancy through the tail --------------
    engine.slot_log = [SlotInterval(slot=int(s), rid=int(r),
                                    admit_tick=int(a),
                                    release_tick=(None if rel is None
                                                  else int(rel)))
                       for s, r, a, rel in extra["slot_log"]]
    engine._open_interval = {iv.slot: iv for iv in engine.slot_log
                             if iv.release_tick is None}
    slot_meta = extra["slots"]
    assign = {s: int(row["rid"]) for s, row in enumerate(slot_meta)
              if row["state"] != "free"}
    for rec in tail:
        if rec["kind"] == "admit":
            s = int(rec["slot"])
            assign[s] = int(rec["rid"])
            iv = SlotInterval(slot=s, rid=int(rec["rid"]),
                              admit_tick=int(rec["tick"]))
            engine.slot_log.append(iv)
            engine._open_interval[s] = iv
        elif rec["kind"] == "preempt":
            s = int(rec["slot"])
            if assign.get(s) == int(rec["rid"]):
                del assign[s]
            iv = engine._open_interval.pop(s, None)
            if iv is not None:
                iv.release_tick = int(rec["tick"]) + 1
        elif rec["kind"] in ("done", "shed"):
            rid = rec.get("rid")
            s = next((s for s, r in assign.items() if r == rid), None)
            if s is not None:
                del assign[s]
                iv = engine._open_interval.pop(s, None)
                if iv is not None:
                    # intervals closed by the dead process are not
                    # re-emitted to the tracer: a same-process tracer
                    # already has them, and duplicates would overlap
                    iv.release_tick = int(rec["tick"]) + 1

    # -- reattach the journal BEFORE rebuilding slots (the torn-tail
    # edge below may need to append) -----------------------------------
    if journal_path is not None:
        engine.journal = Journal(journal_path, resume=True,
                                 fsync=journal_fsync)

    # -- rebuild occupied slots on the PR 7 replay path ----------------
    # admission age must survive restore in paged mode: page pressure
    # preempts YOUNGEST-first, so a restored engine that forgot who is
    # older would evict different victims than the uninterrupted one
    seq_base = max((int(r.get("admit_seq", -1)) for r in slot_meta
                    if r["state"] != "free"), default=-1) + 1
    tail_admit_order = {}
    for i, rec in enumerate(tail):
        if rec["kind"] == "admit":
            tail_admit_order[int(rec["rid"])] = i
    snap_pages = (extra.get("paging", {}).get("slot_pages")
                  if engine.paged else None)
    snap_rows_by_rid = {int(r["rid"]): r for r in slot_meta
                        if r["state"] != "free"}
    snap_pre = (extra.get("paging", {}).get("preempted") or [])
    snap_pre_by_rid = {int(r["rid"]): r for r in snap_pre}
    pages_by_slot = [[] for _ in range(engine.n_slots)]
    reset_mask = np.zeros((engine.n_slots,), bool)
    replayed = fresh = restored = 0
    max_seq = seq_base - 1
    for s in range(engine.n_slots):
        rid = assign.get(s)
        if rid is None:
            engine.slots[s] = _Slot()
            continue
        row = slot_meta[s]
        if row["state"] != "free" and int(row["rid"]) == rid \
                and rid not in fold["admitted"]:
            # same occupant since the snapshot, never preempted in the
            # tail (a tail re-admit means its snapshot pages were
            # surrendered — the saved cache slice is stale)
            durable = np.asarray(row["durable"], np.int32)
            gen_len = int(row["gen_len"])
            deadline = row["deadline"]
            fault_count = int(row["fault_count"])
            cursor = int(row["cache_tokens"])
            admit_seq = int(row.get("admit_seq", s))
            if snap_pages is not None:
                # the snapshot cache's positions 0..cursor-1 live in
                # exactly these pool pages, in position order
                pages_by_slot[s] = [int(p) for p in snap_pages[s]]
        else:                              # admitted after the snapshot:
            prow = snap_rows_by_rid.get(rid) or snap_pre_by_rid.get(rid)
            if prow is not None:
                # preempted (pre- or post-snapshot), re-admitted in the
                # tail: the durable record rides the snapshot rows, not
                # the queue
                durable = np.asarray(prow["durable"], np.int32)
                gen_len = int(prow["gen_len"])
                deadline = prow["deadline"]
                fault_count = int(prow.get("fault_count", 0))
            else:                          # no trusted cache, start over
                req = requests_by_rid[rid]
                durable = np.asarray(req.prompt, np.int32)
                gen_len, deadline = req.gen_len, req.deadline
                fault_count = 0
            cursor = 0
            # pages re-grow on demand at the next tick's _page_growth
            admit_seq = seq_base + tail_admit_order.get(rid, 0)
        max_seq = max(max_seq, admit_seq)
        emitted = outputs.get(rid, [])
        if len(emitted) >= gen_len:
            # every token was journaled but the done record was lost in
            # the torn tail: settle the request instead of re-prefilling
            end_tick = max(fold["last_tick"], int(extra["tick"]))
            m.on_done(rid, end_tick)
            if engine.journal is not None:
                engine.journal.append("done", end_tick, rid=rid)
            iv = engine._open_interval.pop(s, None)
            if iv is not None:
                iv.release_tick = end_tick + 1
            engine.slots[s] = _Slot()
            pages_by_slot[s] = []          # settled: pages back to free
            continue
        record = (np.concatenate([durable,
                                  np.asarray(emitted, np.int32)])
                  if emitted else durable)
        if cursor == 0:
            reset_mask[s] = True
            fresh += len(record)
        elif emitted:
            # journal-evidenced progress the dead engine had already
            # made past the snapshot cache: re-entering it is the redone
            # work snapshot cadence bounds. The final record token is
            # excluded — its argmax produces the next NEW token, work
            # the uninterrupted engine does too.
            evidenced = len(durable) + len(emitted) - 1
            replayed += max(0, evidenced - cursor)
        engine.slots[s] = _Slot(
            state=SlotState.PREFILLING, rid=rid, prompt=record,
            durable=durable, cursor=cursor, gen_len=gen_len,
            deadline=deadline, fault_count=fault_count,
            replay=bool(emitted), restore=True, admit_seq=admit_seq)
        restored += 1
    if engine.paged:
        engine.page_alloc.load_slot_pages(pages_by_slot)
        engine._admit_seq = max_seq + 1
        # re-admission deque: snapshot entries still waiting (their tail
        # admit/terminal clears them), then tail preempts in record
        # order — FIFO age survives the crash
        terminal = set(fold["done"]) | set(fold["shed"])
        pre = []
        for prow in snap_pre:
            rid = int(prow["rid"])
            if rid in fold["admitted"] or rid in terminal:
                continue
            pre.append(prow)
        for rid in fold["preempted"]:
            rid = int(rid)
            if rid in terminal:
                continue
            prow = snap_rows_by_rid.get(rid) or snap_pre_by_rid.get(rid)
            if prow is None:               # submitted after the snapshot
                req = requests_by_rid[rid]
                prow = {"rid": rid, "durable": list(req.prompt),
                        "gen_len": req.gen_len, "deadline": req.deadline,
                        "fault_count": 0}
            pre.append(prow)
        engine.preempted = deque(
            _Preempted(rid=int(p["rid"]),
                       durable=np.asarray(p["durable"], np.int32),
                       gen_len=int(p["gen_len"]),
                       deadline=(None if p.get("deadline") is None
                                 else float(p["deadline"])),
                       fault_count=int(p.get("fault_count", 0)))
            for p in pre)
        for p in engine.preempted:
            engine.outputs.setdefault(p.rid, [])
        engine.page_alloc.check()
    if reset_mask.any():
        engine.cache = engine._reset_call(reset_mask)

    engine.tick_count = max(int(extra["tick"]), fold["last_tick"] + 1)
    stats = {"from_step": int(step),
             "resume_tick": int(engine.tick_count),
             "slots_restored": int(restored),
             "replayed_prefill_tokens": int(replayed),
             "fresh_prefill_tokens": int(fresh),
             "journal_tail_records": len(tail)}
    engine.restore_stats = stats
    if engine.tracer is not None:
        engine.tracer.event("restore", engine.tick_count, **stats)
    return stats
