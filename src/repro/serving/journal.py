"""Write-ahead request journal: append-only, CRC-framed JSONL.

The engine's durable record used to live only in memory (`_Slot.durable`
+ `engine.outputs`), so a process crash lost every in-flight and queued
request even though per-slot recovery (PR 7) could rebuild any one of
them. The journal makes that record durable: every request-visible
transition is appended as one CRC-framed JSON line and the whole tick's
batch is fsync'd ONCE at tick end — a crash can lose at most the
not-yet-committed tail of the current tick, and everything it loses is
re-derived bitwise on restart (argmax decoding is deterministic, and
chunked prefill == sequential decode).

Frame format — one record per line::

    <crc32 hex, 8 chars> <canonical JSON payload>\\n

The CRC is over the payload bytes. Recovery (`read_journal`) stops at
the FIRST bad frame — torn tail, flipped bit, truncated line — and
reports the byte offset of the last good frame, which `Journal(path,
resume=True)` truncates the file to before appending. Prefix semantics
are deliberate: a record is only trusted if every record before it is
intact, so replay state can never be built from a gap.

Record kinds (``kind`` field; every record carries ``tick``):

  ==========  ==========================================================
  kind        fields
  ==========  ==========================================================
  submit      rid, prompt (token list), gen_len, arrival, deadline
  admit       rid, slot, skips — also a PREEMPTED request re-entering a
              slot (its tokens so far are the token records; the replay
              record is prompt + tokens)
  token       rid, token — one generated token, in emission order
  done        rid — the request completed its stream
  shed        rid, reason — dropped after acceptance (deadline,
              fault_budget)
  reject      rid, reason, prompt_len, gen_len, arrival, deadline —
              refused at submit (oversized, queue_full, duplicate_rid)
  preempt     rid, slot — evicted under page pressure (paged engine);
              the slot's pages were surrendered and the request waits
              for re-admission with its emitted tokens intact
  ==========  ==========================================================

Journaling is PASSIVE: with ``journal=None`` (the engine default) the
outputs and device-call count are bitwise/count-identical — the journal
only ever observes host-side decisions, exactly like the tracer.

Restore folds the journal tail (records past the snapshot's committed
offset) over the snapshot state: `fold_records` returns the net effect
— who was admitted where, every token emitted, who finished/was shed —
and serving.snapshot applies it to a fresh engine.
"""

from __future__ import annotations

import json
import os
import zlib
from typing import Dict, List, Optional, Tuple

RECORD_KINDS = ("submit", "admit", "token", "done", "shed", "reject",
                "preempt")


class JournalError(RuntimeError):
    """A structural problem with a journal file or record."""


def frame(record: dict) -> bytes:
    """One CRC-framed line for ``record`` (canonical JSON, sorted keys,
    so the same record always frames to the same bytes)."""
    payload = json.dumps(record, sort_keys=True,
                         separators=(",", ":")).encode()
    if b"\n" in payload:
        raise JournalError("journal payload contains a newline")
    return b"%08x " % (zlib.crc32(payload) & 0xFFFFFFFF) + payload + b"\n"


def _parse_frame(line: bytes) -> Optional[dict]:
    """Decode one framed line; None if the frame is bad in any way."""
    sp = line.find(b" ")
    if sp != 8:
        return None
    try:
        crc = int(line[:sp], 16)
    except ValueError:
        return None
    payload = line[sp + 1:]
    if zlib.crc32(payload) & 0xFFFFFFFF != crc:
        return None
    try:
        rec = json.loads(payload)
    except (ValueError, UnicodeDecodeError):
        return None
    return rec if isinstance(rec, dict) else None


def read_journal(path: str, start: int = 0
                 ) -> Tuple[List[dict], int, bool]:
    """Read committed records from byte offset ``start``.

    Returns ``(records, end_offset, torn)``: every record up to the
    first bad frame, the ABSOLUTE byte offset just past the last good
    frame, and whether anything (a torn tail, a corrupt frame) was left
    unread. Truncating the file to ``end_offset`` recovers a clean
    journal."""
    with open(path, "rb") as f:
        f.seek(start)
        buf = f.read()
    records: List[dict] = []
    pos = 0
    while True:
        nl = buf.find(b"\n", pos)
        if nl < 0:                         # partial final frame (or EOF)
            break
        rec = _parse_frame(buf[pos:nl])
        if rec is None:                    # first bad frame: stop trusting
            break
        records.append(rec)
        pos = nl + 1
    return records, start + pos, pos < len(buf)


class Journal:
    """Append-only write-ahead log with one fsync per commit.

    ``append`` buffers records host-side; ``commit`` writes the whole
    batch in one syscall, flushes, and fsyncs — the engine calls it once
    per tick, so durability costs one fsync per tick regardless of how
    many requests moved. ``offset`` is the number of DURABLE bytes
    (snapshots record it so restore knows exactly which records the
    snapshot already reflects).

    ``resume=True`` recovers an existing file: the torn tail (if any) is
    truncated at the first bad frame and appending continues from the
    last good record — the restart path. The default (``resume=False``)
    starts a fresh journal, truncating whatever was there."""

    def __init__(self, path: str, *, resume: bool = False,
                 fsync: bool = True):
        self.path = str(path)
        self.fsync = fsync
        self._pending: List[dict] = []
        self.records_recovered = 0
        if resume and os.path.exists(self.path):
            recs, end, torn = read_journal(self.path)
            if torn:
                with open(self.path, "r+b") as f:
                    f.truncate(end)
            self.records_recovered = len(recs)
            self._offset = end
        else:
            open(self.path, "wb").close()
            self._offset = 0
        self._fh = open(self.path, "ab")

    @property
    def offset(self) -> int:
        """Byte offset of the last COMMITTED (durable) frame."""
        return self._offset

    @property
    def pending(self) -> int:
        return len(self._pending)

    def append(self, kind: str, tick: int, **fields):
        """Buffer one record; durable only after the next commit()."""
        if kind not in RECORD_KINDS:
            raise JournalError(f"kind {kind!r} not in {RECORD_KINDS}")
        self._pending.append({"kind": kind, "tick": int(tick), **fields})

    def commit(self) -> int:
        """Write + fsync every buffered record in one batch; returns the
        number of records made durable (0 = nothing buffered, no I/O)."""
        if not self._pending:
            return 0
        buf = b"".join(frame(r) for r in self._pending)
        n = len(self._pending)
        self._pending.clear()
        self._fh.write(buf)
        self._fh.flush()
        if self.fsync:
            os.fsync(self._fh.fileno())
        self._offset += len(buf)
        return n

    def close(self):
        self.commit()
        self._fh.close()


def fold_records(records: List[dict]) -> dict:
    """Fold a journal tail into its net effect on engine state.

    Returns a dict the restore path (serving.snapshot) applies on top of
    the snapshot:

      * ``submits``    — rid -> submit record (requests that entered the
        queue after the snapshot);
      * ``admits``     — slot -> the LAST admit record placed there
        (earlier occupants must have terminated; their terminal records
        are also in the tail);
      * ``admitted``   — rid -> admit record, every admission in order;
      * ``tokens``     — rid -> [token, ...] emitted after the snapshot,
        with ``token_ticks`` carrying each token's tick (first-token
        metrics);
      * ``done`` / ``shed`` / ``rejected`` — terminal outcomes
        (rid -> record);
      * ``preempted``  — rid -> the LAST preempt record for requests
        still waiting for re-admission at the tail's end (a later admit
        clears the entry; insertion order == re-admission order). Their
        slot's ``admits`` entry is cleared too — a preempted slot holds
        nothing;
      * ``last_tick``  — highest tick any record carries (-1 if empty):
        the restored engine resumes at ``last_tick + 1``.
    """
    out = {"submits": {}, "admits": {}, "admitted": {}, "tokens": {},
           "token_ticks": {}, "done": {}, "shed": {}, "rejected": {},
           "preempted": {}, "last_tick": -1}
    for rec in records:
        kind = rec["kind"]
        out["last_tick"] = max(out["last_tick"], rec["tick"])
        rid = rec.get("rid")
        if kind == "submit":
            out["submits"][rid] = rec
        elif kind == "admit":
            out["admits"][rec["slot"]] = rec
            out["admitted"][rid] = rec
            out["preempted"].pop(rid, None)   # re-admitted
        elif kind == "preempt":
            out["preempted"][rid] = rec
            cur = out["admits"].get(rec["slot"])
            if cur is not None and cur.get("rid") == rid:
                del out["admits"][rec["slot"]]
        elif kind == "token":
            out["tokens"].setdefault(rid, []).append(rec["token"])
            out["token_ticks"].setdefault(rid, []).append(rec["tick"])
        elif kind == "done":
            out["done"][rid] = rec
        elif kind == "shed":
            out["shed"][rid] = rec
        elif kind == "reject":
            out["rejected"][rid] = rec
    return out
