"""Host-side page allocator for the paged KV cache.

The paged cache splits each attention segment's KV storage into a global
pool of fixed-size pages ``(L_seg, n_pages, page_size, Hkv, hd)`` plus a
per-slot page table ``(n_slots, max_pages_per_slot)`` of int32 page ids
(-1 = unallocated). All gathers/scatters resolve the indirection INSIDE
the jitted steps (models.attention paged paths), so shapes stay fixed
and the RecompileSentinel stays quiet — the serving-side twin of the
kernel's scalar-prefetched compacted K-block index table (the DB-PIM
idiom one level up: an index table turns irregular occupancy into dense
fixed-shape compute).

This module is the HOST half: who owns which pages. It is plain Python
over numpy — no device calls, fully deterministic (pages allocate
lowest-id-first, so the same admission schedule always produces the
same page tables, which is what makes paged runs reproducible enough to
diff bitwise against contiguous runs).

Invariants (``check()`` enforces; tests/test_paging.py churns them):

  * no page is owned by two slots;
  * no page is both free and owned;
  * free + owned == n_pages always (conservation);
  * a slot owns at most ``max_pages_per_slot`` pages;
  * a slot's pages are position-ordered: owned[i] backs token positions
    [i * page_size, (i+1) * page_size).

The engine composes continuous batching out of three operations:
``alloc`` at admission (gated — a request only takes a slot when its
prompt's pages are free), ``grow`` during decode (one page as the write
position crosses a page boundary; failure triggers preemption of the
youngest-admitted slot), and ``release`` at completion/preemption.
"""

from __future__ import annotations

import math
from typing import Dict, List

import numpy as np


class PageAllocError(RuntimeError):
    """An allocator invariant was violated (a scheduler bug, not load)."""


class PageAllocator:
    """Free-list page allocator with per-slot ordered ownership.

    ``version`` increments on every mutation — the engine uses it to
    refresh its device-side copy of the page table only when something
    actually moved (the table is a per-call operand, not cache-resident
    state, so a stale copy would silently misroute writes).
    """

    def __init__(self, n_pages: int, n_slots: int,
                 max_pages_per_slot: int, page_size: int):
        if n_pages < 1 or page_size < 1 or max_pages_per_slot < 1:
            raise ValueError("n_pages, page_size, max_pages_per_slot "
                             "must be >= 1")
        self.n_pages = int(n_pages)
        self.n_slots = int(n_slots)
        self.max_pages_per_slot = int(max_pages_per_slot)
        self.page_size = int(page_size)
        # descending so list.pop() hands out the LOWEST free id first —
        # deterministic tables for a deterministic schedule
        self._free: List[int] = list(range(self.n_pages - 1, -1, -1))
        self._owned: Dict[int, List[int]] = {s: [] for s in range(n_slots)}
        self.version = 0

    # ----------------------------------------------------------- queries --

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.n_pages - len(self._free)

    def owned(self, slot: int) -> int:
        return len(self._owned[slot])

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to back ``n_tokens`` cache positions."""
        return math.ceil(max(int(n_tokens), 0) / self.page_size)

    def can_grow(self, slot: int, total_pages: int) -> bool:
        """Could ``grow(slot, total_pages)`` succeed right now?"""
        if total_pages > self.max_pages_per_slot:
            return False
        return total_pages - len(self._owned[slot]) <= len(self._free)

    # --------------------------------------------------------- mutations --

    def grow(self, slot: int, total_pages: int) -> bool:
        """Grow ``slot``'s ownership to ``total_pages`` pages (no-op when
        it already owns that many). Returns False — allocating NOTHING —
        when the free list cannot cover the delta or the slot cap would
        be exceeded; partial grabs would strand pages on failure."""
        have = self._owned[slot]
        need = total_pages - len(have)
        if need <= 0:
            return True
        if not self.can_grow(slot, total_pages):
            return False
        for _ in range(need):
            have.append(self._free.pop())
        self.version += 1
        return True

    def release(self, slot: int) -> int:
        """Free every page ``slot`` owns; returns how many. The free
        list is re-sorted so future allocations stay lowest-id-first."""
        pages = self._owned[slot]
        if not pages:
            return 0
        n = len(pages)
        self._free.extend(pages)
        self._free.sort(reverse=True)
        self._owned[slot] = []
        self.version += 1
        return n

    # ------------------------------------------------------------- views --

    def table(self) -> np.ndarray:
        """The (n_slots, max_pages_per_slot) int32 page table; -1 marks
        unallocated entries. This array is the per-call step operand."""
        t = np.full((self.n_slots, self.max_pages_per_slot), -1, np.int32)
        for s, pages in self._owned.items():
            if pages:
                t[s, :len(pages)] = pages
        return t

    def slot_pages(self) -> List[List[int]]:
        """Per-slot owned-page lists (ordered) — the snapshot payload."""
        return [[int(p) for p in self._owned[s]]
                for s in range(self.n_slots)]

    def load_slot_pages(self, slot_pages: List[List[int]]):
        """Rebuild ownership from a snapshot's ``slot_pages``; everything
        unowned returns to the free list. Validates before committing."""
        if len(slot_pages) != self.n_slots:
            raise PageAllocError(
                f"snapshot has {len(slot_pages)} slots, allocator has "
                f"{self.n_slots}")
        owned_all = [p for pages in slot_pages for p in pages]
        if len(set(owned_all)) != len(owned_all):
            raise PageAllocError("snapshot page tables share a page "
                                 "between slots")
        for p in owned_all:
            if not (0 <= p < self.n_pages):
                raise PageAllocError(f"snapshot page id {p} out of range "
                                     f"[0, {self.n_pages})")
        for pages in slot_pages:
            if len(pages) > self.max_pages_per_slot:
                raise PageAllocError("snapshot slot owns more than "
                                     "max_pages_per_slot pages")
        self._owned = {s: [int(p) for p in pages]
                       for s, pages in enumerate(slot_pages)}
        free = set(range(self.n_pages)) - set(owned_all)
        self._free = sorted(free, reverse=True)
        self.version += 1

    # ---------------------------------------------------------- invariants

    def check(self):
        """Raise PageAllocError on any broken invariant. O(n_pages) —
        the engine runs it once per tick in paged mode; corruption here
        means silently cross-wired KV streams, which no output-level
        guard would localize."""
        seen: Dict[int, int] = {}
        for s, pages in self._owned.items():
            if len(pages) > self.max_pages_per_slot:
                raise PageAllocError(f"slot {s} owns {len(pages)} pages > "
                                     f"cap {self.max_pages_per_slot}")
            for p in pages:
                if p in seen:
                    raise PageAllocError(
                        f"page {p} owned by slots {seen[p]} and {s}")
                seen[p] = s
        free_set = set(self._free)
        if len(free_set) != len(self._free):
            raise PageAllocError("free list contains duplicates")
        both = free_set & set(seen)
        if both:
            raise PageAllocError(f"pages both free and owned: "
                                 f"{sorted(both)}")
        if len(free_set) + len(seen) != self.n_pages:
            raise PageAllocError(
                f"conservation broken: {len(free_set)} free + "
                f"{len(seen)} owned != {self.n_pages}")
