"""Deterministic fault injection for the serving engine.

The engine's fault contract (serving.engine) is: **the blast radius of
any single fault is at most one tick, and recovery is bitwise-
verifiable**. This module is the harness that lets CI hold it to that —
a seeded :class:`FaultPlan` schedules adverse events at exact engine
ticks, so a faulted run and a fault-free run of the SAME trace can be
compared token-for-token (benchmarks/serve_engine_bench.py, BENCH key
``chaos``). Same seed + same parameters => identical schedule, always;
the plan itself is stateless at inject time (the engine passes the
attempt number in), so one plan can drive many runs.

Four fault kinds, covering the places a serving step can go wrong on
real hardware plus the process itself (cf. runtime.fault's
``failure_hook`` for the training loop — same philosophy, request-level
granularity):

  * ``step_exception`` — the device call raises (host runtime /
    collective failure). Injected BEFORE dispatch, so the engine's
    bounded retry re-issues the call against intact buffers; an event
    with ``repeat > max_step_retries`` models a persistent failure and
    exercises the quarantine-all path.
  * ``nan_logits``    — one slot's logits come back non-finite
    (overflow, corrupted accumulator). Injected host-side after the
    call; the engine's finite-guard must fail ONLY that slot.
  * ``cache_corruption`` — one slot's KV/SSM cache slices are poisoned
    with NaN at the start of a tick (bit flips, lost DMA). There is no
    direct detector — the poison surfaces as non-finite logits at the
    next device call that reads the slot, which is exactly how the
    engine is meant to catch it (detection-by-propagation).
  * ``engine_crash``  — the whole PROCESS dies (OOM kill, node
    preemption). Raised as :class:`EngineCrash` BETWEEN ticks, after
    the completed tick's journal batch committed, so it models the
    clean kill-point the write-ahead journal is fsync'd at; mid-tick
    loss (a torn journal tail) is covered separately by the journal's
    truncate-at-first-bad-frame recovery. The harness catches the
    exception, abandons the engine object, and brings up a replacement
    via ``ServeEngine.restore`` — the kill-chaos restart case in
    benchmarks/serve_engine_bench.py guards that the restored streams
    are bitwise identical to an uninterrupted run. Unlike the three
    injectable kinds the engine survives in-place, ``engine_crash`` is
    never sampled by :meth:`FaultPlan.generate` (see
    ``INJECTABLE_KINDS``): a crash schedule is a harness-level choice,
    and keeping it out of the sampler keeps every existing seeded
    chaos schedule bit-identical.

Poisoning uses the same layout-generic slot surgery as admission
zeroing (models.decode.merge_slots): float leaves carry the batch on
axis 1, ``pos`` stays valid (a corrupted cache with a trashed position
would be a *different* fault), ``enc_out`` is shared and passes
through.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

#: kinds the engine absorbs in-place (retry / quarantine / replay) —
#: the only kinds FaultPlan.generate samples
INJECTABLE_KINDS = ("step_exception", "nan_logits", "cache_corruption")
#: all valid event kinds; "engine_crash" kills the process between ticks
FAULT_KINDS = INJECTABLE_KINDS + ("engine_crash",)
#: which engine device call an event may target
FAULT_CALLS = ("decode", "prefill", "any")


class InjectedFault(RuntimeError):
    """Raised by FaultPlan.check_step in place of a device-call failure."""


class EngineCrash(RuntimeError):
    """Simulated whole-process kill (fault kind "engine_crash"): raised
    by the engine between ticks, after the finished tick's journal
    batch was committed. Nothing about the engine object is usable
    afterwards — the harness discards it and rebuilds with
    ``ServeEngine.restore(snapshot_dir, journal_path)``."""

    def __init__(self, msg: str, *, tick: int):
        super().__init__(msg)
        self.tick = tick


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.

    ``tick`` is the engine tick it fires on. ``call`` scopes
    step_exception / nan_logits events to a device-call kind ("decode",
    "prefill", or "any"); cache_corruption ignores it (the poison lands
    before either call). ``slot`` targets nan_logits/cache_corruption;
    an event aimed at a slot that is idle that tick is a no-op (the
    schedule is deterministic, the *effect* depends on engine state —
    the plan never peeks at the engine). ``repeat`` is how many
    consecutive attempts of the same tick's call a step_exception
    fails: 1 (default) is a transient blip one retry absorbs, anything
    above the engine's ``max_step_retries`` is a persistent outage.
    ``engine_crash`` events use only ``tick`` — the process dies after
    that tick completes; ``call``/``slot``/``repeat`` are ignored."""
    tick: int
    kind: str
    call: str = "any"
    slot: int = 0
    repeat: int = 1

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"kind {self.kind!r} not in {FAULT_KINDS}")
        if self.call not in FAULT_CALLS:
            raise ValueError(f"call {self.call!r} not in {FAULT_CALLS}")


@dataclass(frozen=True)
class FaultPlan:
    """A deterministic fault schedule. Build one explicitly from events,
    or sample one with :meth:`generate`. ``FaultPlan.none()`` is the
    no-overhead control: an engine driven with it must produce exactly
    the outputs AND device-call count of an engine with no plan at all
    (CI-guarded in the chaos bench)."""
    events: Tuple[FaultEvent, ...] = ()

    @classmethod
    def none(cls) -> "FaultPlan":
        return cls(events=())

    @classmethod
    def generate(cls, seed: int, n_ticks: int, rate: float, n_slots: int,
                 kinds: Tuple[str, ...] = INJECTABLE_KINDS) -> "FaultPlan":
        """Sample a schedule: each tick independently hosts one fault
        with probability ``rate``, uniform over ``kinds``, slots, and
        (for step/logit faults) the two call kinds. Same arguments =>
        identical plan, bit-for-bit — the determinism contract
        tests/test_fault_tolerance.py pins. Defaults to the three
        INJECTABLE kinds (never "engine_crash": crashes are scheduled
        explicitly by restart harnesses, and sampling them here would
        silently change every existing seeded schedule)."""
        rng = np.random.default_rng(seed)
        events: List[FaultEvent] = []
        for tick in range(n_ticks):
            if rng.random() >= rate:
                continue
            kind = kinds[int(rng.integers(len(kinds)))]
            call = ("decode", "prefill")[int(rng.integers(2))]
            slot = int(rng.integers(n_slots))
            events.append(FaultEvent(tick=tick, kind=kind, call=call,
                                     slot=slot))
        return cls(events=tuple(events))

    # ------------------------------------------------------------ queries

    def _at(self, tick: int, kind: str) -> List[FaultEvent]:
        return [e for e in self.events if e.tick == tick and e.kind == kind]

    def check_step(self, tick: int, call: str, attempt: int):
        """Raise InjectedFault if a step_exception event targets this
        tick's ``call`` and has failures left for this ``attempt``
        (0-based). Stateless: the engine's retry loop supplies the
        attempt number, so replaying a run replays the faults."""
        for e in self._at(tick, "step_exception"):
            if e.call in ("any", call) and attempt < e.repeat:
                raise InjectedFault(
                    f"injected step fault: tick={tick} call={call} "
                    f"attempt={attempt}/{e.repeat}")

    def logit_slots(self, tick: int, call: str) -> List[int]:
        """Slots whose logits this tick's ``call`` should NaN-poison."""
        return [e.slot for e in self._at(tick, "nan_logits")
                if e.call in ("any", call)]

    def cache_slots(self, tick: int) -> List[int]:
        """Slots whose cache slices to poison at the start of ``tick``."""
        return [e.slot for e in self._at(tick, "cache_corruption")]

    def crash_at(self, tick: int) -> bool:
        """True if the process should die after completing ``tick``
        (the engine raises EngineCrash between ticks; a restored
        engine resumes at tick+1, so the same event never re-fires)."""
        return bool(self._at(tick, "engine_crash"))


def corrupt_logits(logits: np.ndarray, slots: List[int]) -> np.ndarray:
    """NaN-poison the given batch rows of a host-side logits array."""
    out = np.array(logits, copy=True)
    for s in slots:
        out[s] = np.nan
    return out


def corrupt_cache(cache, slots: List[int], n_slots: int, cfg):
    """NaN-poison every inexact cache leaf's slices for ``slots``.

    Mirrors models.decode.reset_slots: merge_slots does the per-slot
    select with the batch on axis 1, ``pos`` and integer leaves stay
    intact (position corruption would be a different fault class), and
    ``enc_out`` is shared, not per-slot state."""
    from repro.models import merge_slots

    mask = np.zeros((n_slots,), bool)
    for s in slots:
        mask[s] = True

    def poison(leaf):
        if not jnp.issubdtype(leaf.dtype, jnp.inexact):
            return leaf
        return jnp.full_like(leaf, jnp.nan)

    poisoned = {}
    for key, val in cache.items():
        if key in ("enc_out", "pos"):
            poisoned[key] = val
        else:
            poisoned[key] = jax.tree_util.tree_map(poison, val)
    return merge_slots(poisoned, cache, jnp.asarray(mask), cfg)
