"""gemma-7b [dense]: 28L d=3072 16H (kv=16, MHA) d_ff=24576 vocab=256000,
GeGLU, head_dim=256, embedding scaling, (1+w) RMSNorm. [arXiv:2403.08295]"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b", family="dense", n_layers=28, d_model=3072,
        n_heads=16, n_kv_heads=16, d_ff=24576, vocab_size=256000,
        head_dim=256, mlp_type="geglu", norm_plus_one=True,
        embed_scale=True, tie_embeddings=True)


def reduced_config() -> ModelConfig:
    return config().scaled(name="gemma-smoke", n_layers=2, d_model=64,
                           n_heads=4, n_kv_heads=4, d_ff=128, head_dim=32,
                           vocab_size=256)
