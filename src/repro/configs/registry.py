"""Registry of the 10 assigned architectures (filled in by arch modules)."""

from __future__ import annotations

import importlib
from typing import Dict, List

ARCHS: List[str] = [
    "qwen3-8b", "tinyllama-1.1b", "gemma-7b", "stablelm-1.6b",
    "arctic-480b", "mixtral-8x7b", "mamba2-1.3b", "pixtral-12b",
    "whisper-base", "jamba-v0.1-52b",
]

_MODULES: Dict[str, str] = {a: a.replace("-", "_").replace(".", "_")
                            for a in ARCHS}


def get_config(arch: str, reduced: bool = False):
    """Load the ModelConfig for `arch`. reduced=True returns the small
    smoke-test variant of the same family."""
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.reduced_config() if reduced else mod.config()


def list_archs() -> List[str]:
    return list(ARCHS)
