"""Registry of the 10 assigned architectures (filled in by arch modules)."""

from __future__ import annotations

import importlib
from typing import Dict, List

ARCHS: List[str] = [
    "qwen3-8b", "tinyllama-1.1b", "gemma-7b", "stablelm-1.6b",
    "arctic-480b", "mixtral-8x7b", "mamba2-1.3b", "pixtral-12b",
    "whisper-base", "jamba-v0.1-52b",
]

_MODULES: Dict[str, str] = {a: a.replace("-", "_").replace(".", "_")
                            for a in ARCHS}


#: DB-PIM kernel modes selectable per config (mirrors
#: sparsity.sparse_linear.KERNEL_MODES; kept literal so the registry
#: stays import-light).
DBPIM_MODES = ("dense", "value", "bit", "joint")


def get_config(arch: str, reduced: bool = False,
               dbpim_mode: str = None, prefill_exact: bool = None):
    """Load the ModelConfig for `arch`. reduced=True returns the small
    smoke-test variant of the same family. dbpim_mode selects the DB-PIM
    kernel path ("dense" | "value" | "bit" | "joint") the serving stack
    packs for: launch.serve builds uniform-MAXB stacked tables
    (sparsity.sparse_linear.build_stacked_tables) and threads them
    through the scanned layer stacks, so "joint"/"bit" (INT8/FTA
    payload) and "value" (bf16 payload, value level only) change the
    compiled serving HLO end-to-end (dense-attention and SSM families;
    per-layer hooks via build_kernel_tables -> models.layers.make_matmul
    remain for the others). prefill_exact=True forces SSM chunked
    prefill onto the exact per-token recurrence (bit-identical to
    decode, C x the projection traffic) instead of the default parallel
    SSD form (one stacked-weight read per chunk, tolerance-equivalent —
    models.ssm.PARALLEL_PREFILL_ATOL)."""
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    cfg = mod.reduced_config() if reduced else mod.config()
    if dbpim_mode is not None:
        if dbpim_mode not in DBPIM_MODES:
            raise KeyError(f"unknown dbpim_mode {dbpim_mode!r}; "
                           f"choose from {DBPIM_MODES}")
        cfg = cfg.scaled(dbpim=True, dbpim_mode=dbpim_mode)
    if prefill_exact is not None:
        cfg = cfg.scaled(prefill_exact=prefill_exact)
    return cfg


def list_archs() -> List[str]:
    return list(ARCHS)
