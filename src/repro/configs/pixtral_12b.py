"""pixtral-12b [vlm]: 40L d=5120 32H (GQA kv=8) d_ff=14336 vocab=131072,
head_dim=128 (mistral-nemo backbone); vision frontend is a STUB — the
input spec provides precomputed patch embeddings.
[hf:mistralai/Pixtral-12B-2409]"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b", family="vlm", n_layers=40, d_model=5120,
        n_heads=32, n_kv_heads=8, d_ff=14336, vocab_size=131072,
        head_dim=128, mlp_type="swiglu", frontend="vision_stub",
        n_patches=256, rope_theta=1_000_000.0)


def reduced_config() -> ModelConfig:
    return config().scaled(name="pixtral-smoke", n_layers=2, d_model=64,
                           n_heads=4, n_kv_heads=2, d_ff=128, head_dim=16,
                           vocab_size=256, n_patches=8)
