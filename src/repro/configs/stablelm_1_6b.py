"""stablelm-1.6b [dense]: 24L d=2048 32H (kv=32, MHA) d_ff=5632
vocab=100352, LayerNorm, partial rotary 25%.
[hf:stabilityai/stablelm-2-1_6b]"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-1.6b", family="dense", n_layers=24, d_model=2048,
        n_heads=32, n_kv_heads=32, d_ff=5632, vocab_size=100352,
        norm_type="layernorm", rope_pct=0.25, mlp_type="swiglu")


def reduced_config() -> ModelConfig:
    return config().scaled(name="stablelm-smoke", n_layers=2, d_model=64,
                           n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256)
