"""arctic-480b [moe]: 35L d=7168 56H (GQA kv=8) expert d_ff=4864
vocab=32000, 128 experts top-2 PLUS a dense residual MLP per layer
(dense-MoE hybrid). head_dim=128. [hf:Snowflake/snowflake-arctic-base]"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b", family="moe", n_layers=35, d_model=7168,
        n_heads=56, n_kv_heads=8, d_ff=4864, vocab_size=32000,
        head_dim=128, n_experts=128, top_k=2, dense_residual=True,
        mlp_type="swiglu")


def reduced_config() -> ModelConfig:
    return config().scaled(name="arctic-smoke", n_layers=2, d_model=64,
                           n_heads=4, n_kv_heads=2, d_ff=96, head_dim=16,
                           vocab_size=256, n_experts=4, top_k=2)
