"""Architecture configs: the 10 assigned LM-family archs + the paper's CNNs."""

from .registry import get_config, list_archs, ARCHS  # noqa: F401
