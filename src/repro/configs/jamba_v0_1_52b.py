"""jamba-v0.1-52b [hybrid]: 32L d=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, Mamba:attention 7:1 interleave (1 attn per 8-layer period),
MoE 16 experts top-2 on every second layer. Mamba d_state=16.
[arXiv:2403.19887]"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b", family="hybrid", n_layers=32, d_model=4096,
        n_heads=32, n_kv_heads=8, d_ff=14336, vocab_size=65536,
        n_experts=16, top_k=2, moe_every=2, attn_period=8, attn_index=4,
        ssm_state=16, ssm_expand=2, ssm_head_dim=64, mlp_type="swiglu")


def reduced_config() -> ModelConfig:
    return config().scaled(name="jamba-smoke", n_layers=4, d_model=64,
                           n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                           n_experts=4, top_k=2, attn_period=4, attn_index=2,
                           ssm_state=16, ssm_head_dim=16, ssm_chunk=32)
