"""mamba2-1.3b [ssm]: 48L d=2048, attention-free SSD, d_state=128,
vocab=50280. [arXiv:2405.21060]"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b", family="ssm", n_layers=48, d_model=2048,
        n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=50280,
        ssm_state=128, ssm_expand=2, ssm_head_dim=64, tie_embeddings=True)


def reduced_config() -> ModelConfig:
    return config().scaled(name="mamba2-smoke", n_layers=2, d_model=64,
                           vocab_size=256, ssm_state=16, ssm_head_dim=16,
                           ssm_chunk=32)
