"""qwen3-8b [dense]: 36L d=4096 32H (GQA kv=8) d_ff=12288 vocab=151936,
qk-norm, head_dim 128. [hf:Qwen/Qwen3-8B]"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-8b", family="dense", n_layers=36, d_model=4096,
        n_heads=32, n_kv_heads=8, d_ff=12288, vocab_size=151936,
        head_dim=128, qk_norm=True, mlp_type="swiglu",
        rope_theta=1_000_000.0)


def reduced_config() -> ModelConfig:
    return config().scaled(name="qwen3-8b-smoke", n_layers=2, d_model=64,
                           n_heads=4, n_kv_heads=2, d_ff=128, head_dim=16,
                           vocab_size=256)
