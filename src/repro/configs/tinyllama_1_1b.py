"""tinyllama-1.1b [dense]: 22L d=2048 32H (GQA kv=4) d_ff=5632 vocab=32000.
Llama-2 architecture. [arXiv:2401.02385]"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="tinyllama-1.1b", family="dense", n_layers=22, d_model=2048,
        n_heads=32, n_kv_heads=4, d_ff=5632, vocab_size=32000,
        mlp_type="swiglu")


def reduced_config() -> ModelConfig:
    return config().scaled(name="tinyllama-smoke", n_layers=2, d_model=64,
                           n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256)
