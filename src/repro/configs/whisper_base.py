"""whisper-base [audio]: enc-dec, 6+6L d=512 8H (MHA) d_ff=2048
vocab=51865; conv frontend is a STUB — the input spec provides
precomputed frame embeddings (1500 frames). Sinusoidal positions
(rope_pct=0), LayerNorm. [arXiv:2212.04356]"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base", family="audio", n_layers=6, d_model=512,
        n_heads=8, n_kv_heads=8, d_ff=2048, vocab_size=51865,
        norm_type="layernorm", mlp_type="gelu", rope_pct=0.0,
        encoder_layers=6, encoder_seq=1500, frontend="audio_stub")


def reduced_config() -> ModelConfig:
    return config().scaled(name="whisper-smoke", n_layers=2, d_model=64,
                           n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256,
                           encoder_layers=2, encoder_seq=32)
