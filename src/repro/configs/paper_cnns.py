"""Layer tables (im2col GEMM shapes) for the paper's five CNN workloads on
CIFAR-100 (32x32 inputs), batch 1 — VGG19, ResNet18, MobileNetV2, AlexNet,
EfficientNetB0. Feeds the DB-PIM performance model (Fig. 10-13, Tab. II/III).
"""

from __future__ import annotations

from typing import List

from repro.core.pim_model import LayerGEMM


def _conv(name, h, w, k, cin, cout, stride=1, kind="std"):
    ho, wo = h // stride, w // stride
    return LayerGEMM(name, M=ho * wo, K=k * k * cin, N=cout, kind=kind), ho, wo


def vgg19() -> List[LayerGEMM]:
    cfg = [64, 64, "M", 128, 128, "M", 256, 256, 256, 256, "M",
           512, 512, 512, 512, "M", 512, 512, 512, 512, "M"]
    layers, h, cin, i = [], 32, 3, 0
    for c in cfg:
        if c == "M":
            h //= 2
            continue
        l, h, _ = _conv(f"conv{i}", h, h, 3, cin, c)
        layers.append(l)
        cin, i = c, i + 1
    layers.append(LayerGEMM("fc", M=1, K=512, N=100, kind="fc"))
    return layers


def resnet18() -> List[LayerGEMM]:
    layers, h = [], 32
    l, h, _ = _conv("stem", 32, 32, 3, 3, 64)
    layers.append(l)
    cin = 64
    for stage, (cout, stride) in enumerate([(64, 1), (128, 2), (256, 2), (512, 2)]):
        for blk in range(2):
            s = stride if blk == 0 else 1
            l, h, _ = _conv(f"s{stage}b{blk}c0", h, h, 3, cin, cout, s)
            layers.append(l)
            l, h, _ = _conv(f"s{stage}b{blk}c1", h, h, 3, cout, cout, 1)
            layers.append(l)
            if s != 1 or cin != cout:
                layers.append(LayerGEMM(f"s{stage}b{blk}ds", M=h * h,
                                        K=cin, N=cout, kind="pw"))
            cin = cout
    layers.append(LayerGEMM("fc", M=1, K=512, N=100, kind="fc"))
    return layers


def _inverted_residual(layers, name, h, cin, cout, t, stride):
    hid = cin * t
    if t != 1:
        layers.append(LayerGEMM(f"{name}.expand", M=h * h, K=cin, N=hid,
                                kind="pw"))
    ho = h // stride
    layers.append(LayerGEMM(f"{name}.dw", M=ho * ho, K=9, N=hid, kind="dw"))
    layers.append(LayerGEMM(f"{name}.project", M=ho * ho, K=hid, N=cout,
                            kind="pw"))
    return ho


def mobilenet_v2() -> List[LayerGEMM]:
    table = [(1, 16, 1, 1), (6, 24, 2, 1), (6, 32, 3, 2), (6, 64, 4, 2),
             (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1)]
    layers, h = [], 32
    l, h, _ = _conv("stem", 32, 32, 3, 3, 32)
    layers.append(l)
    cin, i = 32, 0
    for t, c, n, s in table:
        for j in range(n):
            h = _inverted_residual(layers, f"ir{i}", h, cin, c,
                                   t, s if j == 0 else 1)
            cin, i = c, i + 1
    layers.append(LayerGEMM("head", M=h * h, K=cin, N=1280, kind="pw"))
    layers.append(LayerGEMM("fc", M=1, K=1280, N=100, kind="fc"))
    return layers


def alexnet() -> List[LayerGEMM]:
    layers = []
    specs = [("c0", 32, 3, 3, 64, 1), ("c1", 16, 3, 64, 192, 1),
             ("c2", 8, 3, 192, 384, 1), ("c3", 8, 3, 384, 256, 1),
             ("c4", 8, 3, 256, 256, 1)]
    for name, h, k, cin, cout, s in specs:
        l, _, _ = _conv(name, h, h, k, cin, cout, s)
        layers.append(l)
    layers += [LayerGEMM("fc0", M=1, K=256 * 4 * 4, N=4096, kind="fc"),
               LayerGEMM("fc1", M=1, K=4096, N=4096, kind="fc"),
               LayerGEMM("fc2", M=1, K=4096, N=100, kind="fc")]
    return layers


def efficientnet_b0() -> List[LayerGEMM]:
    table = [(1, 16, 1, 1), (6, 24, 2, 2), (6, 40, 2, 2), (6, 80, 3, 2),
             (6, 112, 3, 1), (6, 192, 4, 2), (6, 320, 1, 1)]
    layers, h = [], 32
    l, h, _ = _conv("stem", 32, 32, 3, 3, 32)
    layers.append(l)
    cin, i = 32, 0
    for t, c, n, s in table:
        for j in range(n):
            h = _inverted_residual(layers, f"mb{i}", h, cin, c,
                                   t, s if j == 0 else 1)
            cin, i = c, i + 1
    layers.append(LayerGEMM("head", M=h * h, K=cin, N=1280, kind="pw"))
    layers.append(LayerGEMM("fc", M=1, K=1280, N=100, kind="fc"))
    return layers


CNN_MODELS = {
    "alexnet": alexnet,
    "vgg19": vgg19,
    "resnet18": resnet18,
    "mobilenetv2": mobilenet_v2,
    "efficientnetb0": efficientnet_b0,
}


#: DB-PIM kernel-mode -> cost-model feature flags (core.pim_model
#: evaluate_model). The same mode vocabulary the LM configs use
#: (ModelConfig.dbpim_mode), so paper CNNs select joint/value/bit too.
MODE_FLAGS = {
    "dense": dict(use_value=False, use_weight_bit=False, use_input_bit=False),
    "value": dict(use_value=True, use_weight_bit=False, use_input_bit=False),
    "bit": dict(use_value=False, use_weight_bit=True, use_input_bit=True),
    "joint": dict(use_value=True, use_weight_bit=True, use_input_bit=True),
}


def _round_up(v: int, q: int) -> int:
    return -(-v // q) * q


def joint_bench_shapes(max_m: int = 256):
    """Representative paper layer GEMMs for the kernel benchmark.

    Picks the largest conv (std/pw — dw convs are excluded from DB-PIM
    in the paper too) of each of the five CNNs plus AlexNet's fc1, rounds
    dims up to the 128 kernel tile and caps M (batch-1 im2col rows) so
    the interpret-mode benchmark stays fast.
    """
    shapes = []
    for model in CNN_MODELS:
        layers = CNN_MODELS[model]()
        biggest = max((l for l in layers if l.kind not in ("dw", "fc")),
                      key=lambda l: l.K * l.N)
        shapes.append((f"{model}.{biggest.name}",
                       min(_round_up(biggest.M, 128), max_m),
                       _round_up(biggest.K, 128), _round_up(biggest.N, 128)))
    fc = alexnet()[-2]
    shapes.append(("alexnet.fc1", 128,
                   _round_up(fc.K, 128), _round_up(fc.N, 128)))
    return shapes
