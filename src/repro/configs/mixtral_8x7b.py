"""mixtral-8x7b [moe]: 32L d=4096 32H (GQA kv=8) d_ff=14336 vocab=32000,
8 experts top-2, sliding-window attention (4096). [arXiv:2401.04088]"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b", family="moe", n_layers=32, d_model=4096,
        n_heads=32, n_kv_heads=8, d_ff=14336, vocab_size=32000,
        n_experts=8, top_k=2, window=4096, mlp_type="swiglu")


def reduced_config() -> ModelConfig:
    return config().scaled(name="mixtral-smoke", n_layers=2, d_model=64,
                           n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                           n_experts=4, top_k=2, window=32)
