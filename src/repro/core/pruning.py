"""Coarse-grained block-wise value pruning (Sec. IV-C-1).

The weight matrix W (K, N) — K = reduction dim (rows of the PIM array),
N = filters/output channels (columns) — is partitioned into non-overlapping
1 x alpha blocks: the weights at the SAME reduction position k across alpha
consecutive filters. alpha = 8 in the paper (set by the macro column group /
FTA threshold). Blocks are ranked by L2 norm; the lowest `sparsity` fraction
is zeroed. Masks are per-layer artifacts consumed by the sparse allocation
network (hardware) and by the block-sparse Pallas kernel (TPU).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

DEFAULT_ALPHA = 8


def block_l2_norms(w, alpha: int = DEFAULT_ALPHA):
    """L2 norm per (k, n-block). w: (..., K, N) with N % alpha == 0.

    Returns (..., K, N // alpha).
    """
    xp = jnp if isinstance(w, jnp.ndarray) else np
    w = xp.asarray(w)
    K, N = w.shape[-2], w.shape[-1]
    assert N % alpha == 0, f"N={N} not divisible by alpha={alpha}"
    blocks = w.reshape(w.shape[:-2] + (K, N // alpha, alpha))
    return xp.sqrt(xp.sum(blocks.astype(xp.float32) ** 2, axis=-1))


def block_prune_mask(w, sparsity: float, alpha: int = DEFAULT_ALPHA):
    """Mask (same shape as w) with the lowest-L2 `sparsity` of blocks zeroed.

    The threshold is the per-layer quantile of block norms (paper: sort and
    cut at the sparsity level). Exactly floor(sparsity * nblocks) blocks are
    pruned (ties broken by stable argsort), so the ratio is exact.
    """
    xp = jnp if isinstance(w, jnp.ndarray) else np
    norms = block_l2_norms(w, alpha)                          # (..., K, B)
    flat = norms.reshape(norms.shape[:-2] + (-1,))
    nblk = flat.shape[-1]
    k_prune = int(np.floor(float(sparsity) * nblk))
    if k_prune == 0:
        block_mask = xp.ones_like(flat, dtype=xp.int32)
    else:
        order = xp.argsort(flat, axis=-1, stable=True)
        ranks = xp.argsort(order, axis=-1, stable=True)
        block_mask = (ranks >= k_prune).astype(xp.int32)
    block_mask = block_mask.reshape(norms.shape)              # (..., K, B)
    mask = xp.repeat(block_mask[..., None], alpha, axis=-1)
    return mask.reshape(w.shape)


def apply_mask(w, mask):
    xp = jnp if isinstance(w, jnp.ndarray) else np
    return w * xp.asarray(mask, dtype=w.dtype)


def value_sparsity(mask) -> float:
    m = np.asarray(mask)
    return float(1.0 - m.sum() / m.size)


def surviving_block_indices(mask, alpha: int = DEFAULT_ALPHA):
    """Per filter-group: indices of surviving K rows — consumed by the
    sparse allocation network model and the block-sparse kernel packer.

    mask: (K, N). Returns list over N//alpha groups of int32 arrays (rows kept).
    """
    m = np.asarray(mask)
    K, N = m.shape
    out = []
    for g in range(N // alpha):
        blk = m[:, g * alpha:(g + 1) * alpha]
        out.append(np.nonzero(blk.any(axis=1))[0].astype(np.int32))
    return out
