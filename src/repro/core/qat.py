"""FTA-aware Quantization-Aware Training (Sec. III / IV-C-2).

Pieces:
  * dynamic min-max range tracking with EMA smoothing (no trainable params,
    no precomputed global ranges — per the paper),
  * symmetric INT8 fake-quant with straight-through-estimator gradients,
  * the FTA projection folded into the forward pass (weights are projected to
    their nearest T(phi_th) value every step, STE through the projection),
  * final FTA quantization (export to true INT8 + scale + metadata).

State is plain pytrees; no framework dependency.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import fta
from .csd import INT8_MAX


class EMARange(NamedTuple):
    """EMA-smoothed dynamic range observer state."""
    amax: jnp.ndarray   # scalar, smoothed max |x|
    decay: float = 0.99
    initialized: jnp.ndarray = jnp.zeros(())  # 0. until first update


def ema_init() -> EMARange:
    return EMARange(amax=jnp.ones(()), initialized=jnp.zeros(()))


def ema_update(state: EMARange, x: jnp.ndarray) -> EMARange:
    cur = jnp.max(jnp.abs(x)).astype(jnp.float32) + 1e-8
    new = jnp.where(state.initialized > 0,
                    state.decay * state.amax + (1 - state.decay) * cur,
                    cur)
    return EMARange(amax=new, decay=state.decay,
                    initialized=jnp.ones(()))


def scale_of(state: EMARange) -> jnp.ndarray:
    return state.amax / INT8_MAX


def _ste(x_fq: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Straight-through estimator: forward = x_fq, backward = identity."""
    return x + jax.lax.stop_gradient(x_fq - x)


def quantize_int8(x: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    q = jnp.round(x / scale)
    return jnp.clip(q, -127, 127).astype(jnp.int32)


def fake_quant(x: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Plain symmetric INT8 fake-quant with STE (inputs/activations)."""
    return _ste(quantize_int8(x, scale).astype(x.dtype) * scale, x)


def fta_fake_quant(w: jnp.ndarray, mask: jnp.ndarray, scale: jnp.ndarray):
    """FTA-aware weight fake-quant (the per-epoch projection of Fig. 4).

    w: float (K, N) [filters last]; mask: block-prune mask. Returns
    (w_fq float with STE, phi_th (N,)) — w_fq values lie exactly on the
    scale * T(phi_th) grid so the final FTA quantization is lossless.
    """
    q = quantize_int8(w, scale)
    q_fta, phi_th = fta.fta_quantize(q, mask)
    w_fq = q_fta.astype(w.dtype) * scale
    return _ste(w_fq * mask.astype(w.dtype), w * mask.astype(w.dtype)), phi_th


class FTAExport(NamedTuple):
    """Final FTA quantization artifact (Sec. IV-C-3) for one weight tensor."""
    q: jnp.ndarray        # int32 (K, N) FTA-compliant INT8 values
    scale: jnp.ndarray    # scalar dequant scale
    phi_th: jnp.ndarray   # (N,) per-filter thresholds
    mask: jnp.ndarray     # (K, N) coarse block-prune mask


def fta_export(w: jnp.ndarray, mask: jnp.ndarray, scale: jnp.ndarray) -> FTAExport:
    q = quantize_int8(w, scale)
    q_fta, phi_th = fta.fta_quantize(q, mask)
    return FTAExport(q=q_fta, scale=scale, phi_th=phi_th,
                     mask=mask.astype(jnp.int32))


def dequant(exp: FTAExport) -> jnp.ndarray:
    return exp.q.astype(jnp.float32) * exp.scale
