"""DB-PIM architecture performance model (Sec. V / VI).

A loop-nest-faithful cycle / energy / utilization model of

  * the dense digital SRAM-PIM baseline (ADC-less macro of [20]: weights
    stored bit-parallel across columns, inputs broadcast bit-serially,
    16 rows per compartment time-multiplexed over one LPU), and
  * DB-PIM (this paper): Comp-pattern-only storage, per-filter phi_th
    column allocation, sparse allocation network (value-level skip),
    IPU input zero-bit-column skip, CSD adder trees.

It follows the mapping of Fig. 9: Tm = 4 macros/core (same weights,
different output pixels), Tn = 8*alpha filters across 8 cores,
Tk = Tk1 x Tk2 = 16 x 16 reduction elements per tile; Tk2 sequential,
everything else spatial. Cycle counts are derived from tile counts — the
same structure as the paper's cycle-accurate simulator, abstracted above
individual control cycles.

The model consumes REAL sparsity metadata (masks, per-filter phi_th, input
bit-column statistics) produced by `repro.core.hybrid`, so speedups move
with the actual pruning outcome, not with a hardcoded ratio.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from .csd import PHI_TABLE, INT8_MIN


# --------------------------------------------------------------------------
# Hardware description
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class PIMConfig:
    n_cores: int = 8
    macros_per_core: int = 4          # Tm
    compartments: int = 16            # Tk1
    rows_per_compartment: int = 16    # Tk2 (sequential; share one LPU)
    columns: int = 16                 # DBMUs per compartment = macro columns
    weight_bits: int = 8
    input_bits: int = 8
    input_group: int = 16             # IPU zero-column detection group size
    freq_mhz: float = 500.0

    # SIMD core (dw-conv, elementwise mul, pooling, ReLU, ResAdd, quant):
    # present in BOTH the dense baseline and DB-PIM (Sec. V-A / VII).
    simd_macs_per_cycle: int = 64

    # Energy constants (pJ), loosely calibrated against the 28 nm macro of
    # [20] (27.38 TOPS/W INT8) and typical SRAM buffer access costs. Ratios,
    # not absolutes, are the reproduction target.
    e_cell_cycle: float = 0.0020      # per active SRAM cell x cycle (AND+tree)
    e_lpu_extra: float = 0.0004      # DBMU dual-AND + CSD-tree overhead/cell
    e_input_buf_bit: float = 0.0100   # input buffer read, per bit broadcast
    e_output_acc: float = 0.1500      # accumulator/output RF update per psum
    e_weight_load_cell: float = 0.0100  # per cell written at tile switch
    e_meta_rf_bit: float = 0.0008     # sign/index RF read per cell x cycle
    e_ipu_group: float = 0.0200       # IPU detect per input group x bit
    e_switch_input: float = 0.0100    # sparse allocation network per input
    e_simd_mac: float = 0.5000        # SIMD core, per INT8 MAC-equivalent

    @property
    def tk(self) -> int:
        return self.compartments * self.rows_per_compartment   # 256

    @property
    def dense_filters_per_macro(self) -> int:
        return self.columns // self.weight_bits                 # 2

    @property
    def alpha(self) -> int:
        # pruning block granularity = columns / max phi_th (Sec. IV-C): 8
        return self.columns // 2


DEFAULT_PIM = PIMConfig()


# --------------------------------------------------------------------------
# Workload description
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class LayerGEMM:
    """One layer after im2col: O[M,N] = I[M,K] @ W[K,N]."""
    name: str
    M: int
    K: int
    N: int
    kind: str = "std"   # std | pw | dw | fc | mul | etc


@dataclass
class LayerSparsity:
    """Real metadata for one layer (from `repro.core.hybrid` exports)."""
    # Fraction of 1 x alpha blocks pruned (value-level).
    value_sparsity: float = 0.0
    # Per-filter phi_th histogram [n_th0, n_th1, n_th2] over N filters.
    phi_hist: Sequence[int] = field(default_factory=lambda: [0, 0, 0])
    # Per alpha-group max-phi_th histogram [g0, g1, g2]: the mapper packs
    # 2 groups/macro when every filter in the group has phi_th <= 1, else 1
    # (paper: "16 filters with threshold 1, 8 with threshold 2").
    group_phimax_hist: Sequence[int] = field(default_factory=lambda: [0, 0, 0])
    # Sum of phi_th over all filters (true stored column count).
    col_loads: float = 0.0
    # Macro loads after the offline mapper bin-packs groups: a group needs
    # sum(phi) columns; a macro holds 16 columns and at most 2 groups (the
    # per-core switch interleaves two row streams — paper: "16 filters at
    # threshold 1" = two alpha-groups in one macro).
    macro_loads: Optional[float] = None
    # Mean fraction of input bit-columns that are all-zero per group.
    input_zero_col_frac: float = 0.0
    # Mean / lockstep-max surviving K rows per filter group.
    k_eff: Optional[float] = None
    k_eff_max8: Optional[float] = None


def sparsity_from_export(q: np.ndarray, mask: np.ndarray,
                         phi_th: np.ndarray,
                         input_zero_col_frac: float = 0.0) -> LayerSparsity:
    """Build LayerSparsity from a qat.FTAExport's arrays. q: (K, N)."""
    mask = np.asarray(mask)
    phi_th = np.asarray(phi_th)
    v_s = 1.0 - mask.mean()
    hist = np.bincount(np.clip(phi_th, 0, 2), minlength=3).tolist()
    K, N = mask.shape
    alpha = DEFAULT_PIM.alpha
    n_groups = max(N // alpha, 1)
    phimax = phi_th.reshape(n_groups, -1).max(axis=1)
    ghist = np.bincount(np.clip(phimax, 0, 2), minlength=3).tolist()
    # surviving rows per alpha-group (a row survives if any weight kept)
    groups = mask.reshape(K, n_groups, -1).any(axis=2)        # (K, G)
    per_group = groups.sum(axis=0)                            # rows per group
    k_eff = float(per_group.mean())
    # Lockstep: the 8 cores run a tile for max(rows) over its 8 resident
    # groups. The offline compiler bin-packs groups by occupancy (sorted
    # assignment), so the max is taken over similar groups.
    pad = (-len(per_group)) % 8
    pg = np.sort(np.concatenate([per_group,
                                 np.zeros(pad, dtype=per_group.dtype)]))
    tile_max = pg.reshape(-1, 8).max(axis=1)
    live = tile_max > 0
    k_eff_max8 = float(tile_max[live].mean()) if live.any() else 0.0
    # Offline mapper: first-fit-decreasing bin-pack of groups into macros
    # (16 columns, <= 2 groups each). Well approximated by the two lower
    # bounds' max.
    cols_per_group = np.minimum(phi_th, 2).reshape(n_groups, -1).sum(axis=1)
    live_groups = int((cols_per_group > 0).sum())
    total_cols = float(cols_per_group.sum())
    macro_loads = max(np.ceil(total_cols / DEFAULT_PIM.columns),
                      np.ceil(live_groups / 2.0), 0.0)
    return LayerSparsity(value_sparsity=float(v_s), phi_hist=hist,
                         group_phimax_hist=ghist,
                         col_loads=total_cols,
                         macro_loads=float(macro_loads),
                         input_zero_col_frac=float(input_zero_col_frac),
                         k_eff=k_eff, k_eff_max8=k_eff_max8)


def input_zero_col_fraction(acts_int8: np.ndarray, group: int = 16,
                            bits: int = 8) -> float:
    """Fraction of all-zero bit columns over groups of `group` consecutive
    int8 activations (Fig. 3b statistic). Sign-magnitude view: a bit column
    is skippable when that bit is 0 in every value of the group."""
    a = np.abs(np.asarray(acts_int8).astype(np.int32)).ravel()
    n = (a.size // group) * group
    if n == 0:
        return 0.0
    a = a[:n].reshape(-1, group)
    cols_zero = 0
    for b in range(bits):
        colbit = (a >> b) & 1
        cols_zero += (colbit.max(axis=1) == 0).sum()
    return float(cols_zero / (a.shape[0] * bits))


# --------------------------------------------------------------------------
# Cycle / energy / utilization model
# --------------------------------------------------------------------------

@dataclass
class LayerReport:
    name: str
    cycles: float
    energy_pj: float
    eff_cells: float      # cells doing useful (non-zero-operand) work
    total_cells: float    # cells activated
    macs: float

    @property
    def u_act(self) -> float:
        return self.eff_cells / max(self.total_cells, 1.0)


def _ceil(a: float, b: float) -> float:
    return float(int(np.ceil(a / b)))


def _active_cells_per_rowcycle(cfg: PIMConfig) -> float:
    """Digital PIM mandates full-array activation: per row-cycle every
    compartment drives one cell in each of its columns, in every macro."""
    return (cfg.compartments * cfg.columns
            * cfg.macros_per_core * cfg.n_cores)


def dense_baseline_layer(layer: LayerGEMM, cfg: PIMConfig = DEFAULT_PIM,
                         nonzero_bit_frac: float = 0.45) -> LayerReport:
    """Dense digital-PIM baseline ([20]-style): weights bit-parallel (8
    columns/filter -> 2 filters/macro, 16 filters across 8 cores), all K
    rows occupied, all 8 input bits broadcast bit-serially.

    nonzero_bit_frac: fraction of stored weight bits that are non-zero —
    only used for the *utilization* metric (dense compute wastes the rest).
    """
    n_par = cfg.n_cores * cfg.dense_filters_per_macro          # 16 filters
    row_cycles = _ceil(layer.K, cfg.compartments)
    n_tiles = _ceil(layer.N, n_par)
    m_tiles = _ceil(layer.M, cfg.macros_per_core)
    cycles = m_tiles * n_tiles * row_cycles * cfg.input_bits

    activated = cycles * _active_cells_per_rowcycle(cfg)
    fill_k = layer.K / (row_cycles * cfg.compartments)
    fill_n = layer.N / (n_tiles * n_par)
    fill_m = layer.M / (m_tiles * cfg.macros_per_core)
    eff = activated * nonzero_bit_frac * fill_k * fill_n * min(fill_m, 1.0)

    cells_per_macro = cfg.compartments * cfg.rows_per_compartment * cfg.columns
    n_weight_loads = _ceil(layer.K, cfg.tk) * n_tiles
    e = (activated * cfg.e_cell_cycle
         + layer.M * layer.K * cfg.input_bits * cfg.e_input_buf_bit
         + n_weight_loads * cells_per_macro * cfg.n_cores * cfg.e_weight_load_cell
         + m_tiles * n_tiles * layer.N * cfg.macros_per_core * cfg.e_output_acc)
    return LayerReport(layer.name, cycles, e, eff, activated,
                       macs=float(layer.M) * layer.K * layer.N)


def dbpim_layer(layer: LayerGEMM, sp: LayerSparsity,
                cfg: PIMConfig = DEFAULT_PIM,
                use_value: bool = True, use_weight_bit: bool = True,
                use_input_bit: bool = True,
                value_skip_efficiency: float = 1.00) -> LayerReport:
    """DB-PIM cycles/energy for one layer given its real sparsity metadata.

    Ablation switches reproduce the paper's breakdown (Fig. 12):
      use_value      -> sparse allocation network (skip pruned blocks)
      use_weight_bit -> FTA Comp-pattern packing (16/phi filters per macro)
      use_input_bit  -> IPU zero-bit-column skip

    value_skip_efficiency: fraction of pruned-row cycles actually recovered.
    Row skipping is bounded by the sparse allocation network's sequential
    input extraction (one shared switch per core, pipelined over Tm macros,
    scanning the ORIGINAL index range) and by cross-core lockstep (a tile
    runs for the max row count over its 8 resident groups). Calibrated to
    the paper's Fig. 11 (8.10x/5.50x => 60% value sparsity recovers ~47%
    extra cycles, i.e. ~0.55 efficiency on skipped rows).
    """
    N = layer.N
    ghist = np.asarray(sp.group_phimax_hist, dtype=np.float64)
    if ghist.sum() == 0:                                   # dense fallback
        ghist = np.array([0.0, 0.0, max(N / cfg.alpha, 1.0)])

    # ---- N dimension: macro loads from the mapper's group bin-packing
    if use_weight_bit:
        if sp.macro_loads is not None:
            macro_loads = sp.macro_loads
        else:  # fall back to phi_max packing
            macro_loads = ghist[2] + _ceil(ghist[1], 2)
        n_tiles = max(_ceil(macro_loads, cfg.n_cores), 1.0)
    else:
        n_tiles = _ceil(N, cfg.n_cores * cfg.dense_filters_per_macro)

    # ---- K dimension: value-level row skip (bounded efficiency + lockstep)
    if use_value:
        k_base = sp.k_eff_max8 if sp.k_eff_max8 is not None else \
            layer.K * (1 - sp.value_sparsity)
        k_sched = layer.K - value_skip_efficiency * (layer.K - k_base)
    else:
        k_sched = float(layer.K)
    row_cycles = max(_ceil(k_sched, cfg.compartments), 1.0)

    # ---- input bit dimension: IPU skips all-zero bit columns
    eff_bits = cfg.input_bits * (1 - sp.input_zero_col_frac) if use_input_bit \
        else float(cfg.input_bits)
    eff_bits = max(eff_bits, 1.0)

    m_tiles = _ceil(layer.M, cfg.macros_per_core)
    cycles = m_tiles * n_tiles * row_cycles * eff_bits

    # ---- utilization: every STORED cell holds a Comp pattern and computes
    # a useful AND; waste = column padding (phi_1 filters inside phi_max=2
    # groups + ragged tiles), row padding, idle M slots. Input-extraction
    # stall cycles (the value_skip_efficiency loss) do NOT activate cells.
    k_eff_true = sp.k_eff if (use_value and sp.k_eff is not None) else float(layer.K)
    active_row_cycles = max(_ceil(k_eff_true, cfg.compartments), 1.0)
    activated = (m_tiles * n_tiles * active_row_cycles * eff_bits
                 * _active_cells_per_rowcycle(cfg))
    col_alloc = n_tiles * cfg.n_cores * cfg.columns
    if use_weight_bit:
        col_used = sp.col_loads if sp.col_loads else N * 2.0
        fill_n = min(col_used / max(col_alloc, 1.0), 1.0)
        bit_eff = 1.0          # stored cells are all non-zero Comp patterns
    else:
        fill_n = min(N * cfg.weight_bits / max(col_alloc, 1.0), 1.0)
        bit_eff = 0.45         # zero bits still stored, as in the baseline
    fill_k = min(k_eff_true / (active_row_cycles * cfg.compartments), 1.0)
    fill_m = min(layer.M / (m_tiles * cfg.macros_per_core), 1.0)
    eff = activated * bit_eff * fill_n * fill_k * fill_m

    cells_per_macro = cfg.compartments * cfg.rows_per_compartment * cfg.columns
    n_weight_loads = _ceil(k_eff_true, cfg.tk) * n_tiles
    n_inputs_routed = layer.M * k_eff_true
    e = (activated * (cfg.e_cell_cycle + cfg.e_lpu_extra + cfg.e_meta_rf_bit)
         + n_inputs_routed * eff_bits * cfg.e_input_buf_bit
         + n_inputs_routed * cfg.e_switch_input
         + layer.M * _ceil(k_eff_true, cfg.input_group) * cfg.input_bits * cfg.e_ipu_group
         + n_weight_loads * cells_per_macro * cfg.n_cores * cfg.e_weight_load_cell
         + m_tiles * n_tiles * N * cfg.macros_per_core * cfg.e_output_acc)
    return LayerReport(layer.name, cycles, e, eff, activated,
                       macs=float(layer.M) * layer.K * layer.N)


def simd_layer(layer: LayerGEMM, cfg: PIMConfig = DEFAULT_PIM) -> LayerReport:
    """Non-matmul-friendly ops (dw-conv, mul, pooling, ReLU, ResAdd) run on
    the SIMD vector core in both systems — the paper's Fig. 13 bottleneck."""
    macs = float(layer.M) * layer.K * layer.N if layer.kind == "dw" \
        else float(layer.M) * max(layer.K, 1) * max(layer.N, 1)
    if layer.kind == "dw":
        # dw-conv: K = kh*kw, N = channels; each output needs K MACs.
        macs = float(layer.M) * layer.K * layer.N
    cycles = macs / cfg.simd_macs_per_cycle
    e = macs * cfg.e_simd_mac
    return LayerReport(layer.name, cycles, e, eff_cells=0.0, total_cells=0.0,
                       macs=macs)


# --------------------------------------------------------------------------
# Model-level aggregation
# --------------------------------------------------------------------------

@dataclass
class ModelReport:
    layers: List[LayerReport]

    @property
    def cycles(self) -> float:
        return sum(l.cycles for l in self.layers)

    @property
    def energy_pj(self) -> float:
        return sum(l.energy_pj for l in self.layers)

    @property
    def u_act(self) -> float:
        eff = sum(l.eff_cells for l in self.layers)
        tot = sum(l.total_cells for l in self.layers)
        return eff / max(tot, 1.0)

    def time_ms(self, cfg: PIMConfig = DEFAULT_PIM) -> float:
        return self.cycles / (cfg.freq_mhz * 1e3)


def evaluate_model(layers: Sequence[LayerGEMM],
                   sparsities: Dict[str, LayerSparsity],
                   cfg: PIMConfig = DEFAULT_PIM,
                   use_value=True, use_weight_bit=True, use_input_bit=True,
                   accel_kinds=("std", "pw", "fc")) -> ModelReport:
    """DB-PIM report over accelerated layers (dw-conv etc. handled by the
    SIMD core — modeled as dense)."""
    reps = []
    for layer in layers:
        if layer.kind in accel_kinds:
            sp = sparsities.get(layer.name, LayerSparsity())
            reps.append(dbpim_layer(layer, sp, cfg, use_value,
                                    use_weight_bit, use_input_bit))
        else:
            reps.append(simd_layer(layer, cfg))
    return ModelReport(reps)


def evaluate_dense_baseline(layers: Sequence[LayerGEMM],
                            cfg: PIMConfig = DEFAULT_PIM,
                            accel_kinds=("std", "pw", "fc")) -> ModelReport:
    """Dense digital-PIM baseline: matmul layers on the PIM cores, the rest
    on the same SIMD core (identical in both systems)."""
    return ModelReport([dense_baseline_layer(l, cfg) if l.kind in accel_kinds
                        else simd_layer(l, cfg) for l in layers])
