"""Dyadic Block (DB) decomposition of CSD words.

An 8-digit CSD word splits into four dyadic blocks DB#k = (digit_{2k+1},
digit_{2k}). CSD non-adjacency guarantees each DB holds at most ONE non-zero
digit, so every DB is either a

  * Zero pattern:  (0, 0)                            -> not stored
  * Comp pattern:  (0,±1) or (±1,0)                  -> one 6T cell (Q/Q-bar)

A Comp pattern is fully described by (block index, hi/lo position, sign):
value = sign * 2^(2*block + pos). DB-PIM stores only Comp patterns plus this
metadata; this module is the bit-true "offline compilation" (Fig. 4) that
produces them, and the exact reconstruction used by oracles and tests.

Packed metadata layout (uint8 per term): bit0 = sign (1 => negative),
bit1 = pos (hi/lo within block), bits2-3 = block index, bit4 = valid.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .csd import to_csd, NDIGITS

NBLOCKS = NDIGITS // 2
MAX_TERMS = 2  # FTA caps phi_th at 2 -> at most two Comp patterns per weight

_SIGN_BIT = 0
_POS_BIT = 1
_BLK_SHIFT = 2
_VALID_BIT = 4


def dyadic_blocks(x):
    """CSD digits regrouped as blocks: shape x.shape + (NBLOCKS, 2) (lo, hi)."""
    d = to_csd(x)
    return d.reshape(d.shape[:-1] + (NBLOCKS, 2))


def classify_blocks(x):
    """Per-block pattern class: 0 = Zero pattern, 1 = Comp pattern.

    Raises (via returned `ok` flag) if any block held two non-zero digits,
    which CSD non-adjacency forbids.
    """
    xp = jnp if isinstance(x, jnp.ndarray) else np
    blk = dyadic_blocks(x)
    nnz = xp.sum(blk != 0, axis=-1)
    return (nnz > 0).astype(xp.int32), bool((np.asarray(nnz) <= 1).all())


def pack_terms(x, max_terms: int = MAX_TERMS):
    """Compress INT8 weights to (sign, position) Comp-pattern metadata.

    Returns uint8 array of shape x.shape + (max_terms,). Terms are ordered
    from the most significant block down. Weights with more than `max_terms`
    Comp patterns are an error for FTA-projected tensors; here extra terms
    are dropped (callers that need exactness must pre-project with FTA).
    """
    x = np.asarray(x, dtype=np.int32)
    blk = np.asarray(dyadic_blocks(x))                       # (..., 4, 2)
    # Per block: the single non-zero digit (non-adjacency => at most one).
    lo, hi = blk[..., 0], blk[..., 1]
    digit = np.where(hi != 0, hi, lo)                        # (..., 4)
    pos = (hi != 0).astype(np.int32)
    valid = (digit != 0)
    enc = ((1 << _VALID_BIT)
           | (np.arange(NBLOCKS, dtype=np.int32) << _BLK_SHIFT)
           | (pos << _POS_BIT)
           | (digit < 0).astype(np.int32)).astype(np.uint8)
    # Order blocks MSB-first and select the first `max_terms` valid ones.
    enc_m = enc[..., ::-1]
    valid_m = valid[..., ::-1]
    rank = np.cumsum(valid_m, axis=-1)                       # 1-based rank
    out = np.zeros(x.shape + (max_terms,), dtype=np.uint8)
    for t in range(max_terms):
        sel = valid_m & (rank == t + 1)                      # one-hot block
        out[..., t] = np.sum(enc_m * sel, axis=-1).astype(np.uint8)
    return out


def unpack_terms(packed):
    """Exact integer reconstruction from packed Comp-pattern metadata."""
    p = np.asarray(packed, dtype=np.int32)
    valid = (p >> _VALID_BIT) & 1
    sign = 1 - 2 * (p & 1)
    pos = (p >> _POS_BIT) & 1
    blk = (p >> _BLK_SHIFT) & 3
    vals = valid * sign * (1 << (2 * blk + pos))
    return np.sum(vals, axis=-1).astype(np.int32)


def comp_pattern_stats(x):
    """(n_comp_blocks, n_zero_blocks, comp_fraction) over a tensor — feeds
    the U_act computation: DB-PIM stores exactly the Comp blocks."""
    cls, ok = classify_blocks(np.asarray(x))
    assert ok, "CSD non-adjacency violated (impossible for valid CSD)"
    n_comp = int(np.sum(cls))
    n_total = int(cls.size)
    return n_comp, n_total - n_comp, n_comp / max(n_total, 1)
