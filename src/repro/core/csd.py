"""Canonical Signed Digit (CSD) encoding (Reitwiesner 1960).

CSD is the minimal-weight non-adjacent form (NAF): every integer has a unique
representation sum_i d_i 2^i with d_i in {-1, 0, +1} and d_i * d_{i+1} == 0.
For INT8 values (range [-128, 127]) eight digit positions (0..7) always
suffice: the highest NAF digit of |n| <= 128 sits at floor(log2(3*128/2)) = 7.

All functions are vectorized over arbitrary leading axes and jit-compatible.
Digit tensors use the trailing axis as the digit position (LSB first).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

NDIGITS = 8
INT8_MIN, INT8_MAX = -128, 127


def to_csd(x):
    """Convert integers in [-128, 127] to CSD digits, shape x.shape + (8,).

    Implements the NAF recurrence: z = 2 - (n mod 4) when n is odd else 0,
    n <- (n - z) / 2. Works on jnp or np arrays (int32 internally).
    """
    xp = jnp if isinstance(x, jnp.ndarray) else np
    n = xp.asarray(x, dtype=xp.int32)
    digits = []
    for _ in range(NDIGITS):
        odd = n & 1
        rem4 = n & 3
        # odd: digit = +1 if n % 4 == 1 else -1 (n % 4 == 3)
        z = xp.where(odd == 1, xp.where(rem4 == 1, 1, -1), 0).astype(xp.int32)
        digits.append(z)
        n = (n - z) >> 1
    return xp.stack(digits, axis=-1)


def from_csd(digits):
    """Inverse of :func:`to_csd`. Accepts any trailing digit count."""
    xp = jnp if isinstance(digits, jnp.ndarray) else np
    d = xp.asarray(digits, dtype=xp.int32)
    weights = (1 << xp.arange(d.shape[-1], dtype=xp.int32))
    return xp.sum(d * weights, axis=-1)


def csd_nonzero_count(x):
    """phi(x): number of non-zero CSD digits of each element."""
    xp = jnp if isinstance(x, jnp.ndarray) else np
    return xp.sum(to_csd(x) != 0, axis=-1).astype(xp.int32)


# ---------------------------------------------------------------------------
# Precomputed lookup tables over the full INT8 domain (tiny: 256 entries).
# Index convention: table[v + 128] corresponds to the value v.
# ---------------------------------------------------------------------------

_DOMAIN = np.arange(INT8_MIN, INT8_MAX + 1, dtype=np.int32)          # (256,)
CSD_DIGITS_TABLE = to_csd(_DOMAIN)                                   # (256, 8)
PHI_TABLE = np.sum(CSD_DIGITS_TABLE != 0, axis=-1).astype(np.int32)  # (256,)


def phi_lookup(x):
    """phi(x) via table lookup — cheapest jittable form for INT8 inputs."""
    xp = jnp if isinstance(x, jnp.ndarray) else np
    table = jnp.asarray(PHI_TABLE) if xp is jnp else PHI_TABLE
    idx = xp.asarray(x, dtype=xp.int32) - INT8_MIN
    return table[idx]


def verify_csd_properties(values=None):
    """Check the three CSD invariants on a value set (defaults: full INT8).

    Returns a dict of booleans; used by tests and by `benchmarks/fig3`.
    """
    if values is None:
        values = _DOMAIN
    values = np.asarray(values, dtype=np.int32)
    digits = to_csd(values)
    roundtrip = bool(np.all(from_csd(digits) == values))
    adjacent = digits[..., 1:] * digits[..., :-1]
    nonadjacent = bool(np.all(adjacent == 0))
    # Minimal weight: CSD non-zero count never exceeds binary popcount
    # (of the absolute value, the fair baseline for unsigned weight).
    popcnt = np.array([bin(abs(int(v))).count("1") for v in values.ravel()])
    minimal = bool(np.all(np.sum(digits != 0, axis=-1).ravel() <= np.maximum(popcnt, 1)))
    return {"roundtrip": roundtrip, "nonadjacent": nonadjacent, "minimal": minimal}


def mean_nonzero_reduction(bits: int = 8) -> float:
    """Average reduction of non-zero digits vs two's complement (paper: ~33%)."""
    vals = _DOMAIN
    csd_nnz = PHI_TABLE.astype(np.float64)
    twos = np.array([bin(int(v) & 0xFF).count("1") for v in vals], dtype=np.float64)
    nz = twos > 0
    return float(1.0 - csd_nnz[nz].sum() / twos[nz].sum())
