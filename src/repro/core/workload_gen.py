"""Synthetic-but-realistic weight/activation generation for the PIM model
benchmarks.

We cannot retrain CIFAR-100 models here (1-core CPU container), so the
performance benchmarks generate weight tensors whose *distributional* shape
matches trained networks (heavy-tailed, near-zero concentrated — the source
of the paper's phi_th in {0,1,2} spread), push them through the REAL hybrid
pipeline (block pruning -> FTA), and feed the resulting real metadata to the
cost model. `redundancy` controls the tail weight: redundant models (VGG19,
AlexNet) concentrate harder around zero => lower phi_th modes => bigger
hardware wins, exactly the paper's qualitative finding (Sec. VI-C).
"""

from __future__ import annotations

from typing import Dict, Sequence

import numpy as np

from . import fta, pruning
from .pim_model import (LayerGEMM, LayerSparsity, sparsity_from_export,
                        input_zero_col_fraction)

# Paper-motivated redundancy ranking (Sec. VI-C): redundant models (VGG19,
# AlexNet) have weight distributions concentrated on small integers after
# min-max INT8 quantization => per-filter phi_th mode of 1 is frequent;
# compact models (MobileNetV2, EfficientNetB0) spread wider => phi_th = 2
# dominates. `base_q` is the typical quantized magnitude.
# (base_q, dead_group_frac): typical quantized magnitude and the fraction of
# alpha-filter groups that training left essentially dead (FTA phi_th = 0).
# Redundant models (VGG19/AlexNet) carry many dead groups — the paper's
# explanation for VGG's >4x bit-only speedup ("filter thresholds vary
# between 0 and 2"); compact models have almost none.
MODEL_WEIGHT_STATS = {
    "alexnet": (5.0, 0.15),
    "vgg19": (5.0, 0.25),
    "resnet18": (6.0, 0.06),
    "mobilenetv2": (10.0, 0.02),
    "efficientnetb0": (10.0, 0.02),
}


def synth_quantized_weight(K: int, N: int, base_q: float, rng,
                           dead_frac: float = 0.0,
                           alpha: int = 8) -> np.ndarray:
    """INT8 weights with trained-network-like statistics.

    Per-filter Laplace scales drawn lognormally around `base_q` give the
    across-filter diversity that makes the FTA threshold vary in {0, 1, 2};
    per-group correlation (dead groups + shared group scale) mirrors the
    filter-importance correlation of trained convnets.
    """
    n_groups = max(N // alpha, 1)
    gscale = rng.lognormal(mean=0.0, sigma=0.5, size=(1, n_groups))
    dead = (rng.random((1, n_groups)) < dead_frac).astype(np.float64)
    gfac = np.repeat(gscale * (1.0 - dead), alpha, axis=1)[:, :N]
    scales = rng.lognormal(mean=np.log(base_q), sigma=0.3, size=(1, N))
    q = rng.laplace(0.0, 1.0, size=(K, N)) * scales * gfac
    return np.clip(np.round(q), -127, 127).astype(np.int32)


def synth_activation(M: int, K: int, rng) -> np.ndarray:
    """Post-ReLU int8 activations (for the IPU input bit-column statistic).

    Real post-BN/ReLU activations are zero-heavy with rare large outliers,
    so min-max INT8 quantization leaves the high bit-columns mostly zero
    (Fig. 3b). Modeled as ReLU'd Laplace with a thin outlier tail.
    """
    a = np.maximum(rng.laplace(0.0, 1.0, size=(M, K)), 0.0)
    n_out = max(int(a.size * 0.002), 1)
    a.ravel()[rng.integers(0, a.size, size=n_out)] *= 3.0
    amax = a.max() + 1e-8
    return np.round(a / amax * 127.0).astype(np.int32)


def layer_metadata(layer: LayerGEMM, value_sparsity: float,
                   base_q: float, rng,
                   with_input_stats: bool = True,
                   dead_frac: float = 0.0) -> LayerSparsity:
    """Run the real algorithm stack on synthetic weights for one layer."""
    alpha = pruning.DEFAULT_ALPHA
    N_pad = ((layer.N + alpha - 1) // alpha) * alpha
    q = synth_quantized_weight(layer.K, N_pad, base_q, rng, dead_frac, alpha)
    mask = np.asarray(pruning.block_prune_mask(
        q.astype(np.float32), value_sparsity, alpha))
    q_fta, phi_th = fta.fta_quantize(q, mask)
    in_frac = 0.0
    if with_input_stats:
        m_sample = min(layer.M, 64)
        acts = synth_activation(m_sample, min(layer.K, 4096), rng)
        # The skip is taken when a bit-column is zero across ALL inputs
        # broadcast that cycle: Tm macros x 8 cores run in lockstep under
        # the top controller => 128-input granularity, not 16.
        in_frac = input_zero_col_fraction(acts, group=128)
    return sparsity_from_export(q_fta, mask, phi_th, in_frac)


def model_metadata(layers: Sequence[LayerGEMM], value_sparsity: float,
                   model_name: str, seed: int = 0,
                   accel_kinds=("std", "pw", "fc")) -> Dict[str, LayerSparsity]:
    rng = np.random.default_rng(seed)
    base_q, dead = MODEL_WEIGHT_STATS.get(model_name, (4.5, 0.1))
    out = {}
    for layer in layers:
        if layer.kind not in accel_kinds:
            continue
        out[layer.name] = layer_metadata(layer, value_sparsity, base_q, rng,
                                         dead_frac=dead)
    return out
