"""Fixed-Threshold Approximation (FTA) — Alg. 1 of the paper, vectorized.

Per filter (output channel):
  1. phi(w) = CSD non-zero digit count of each (already INT8-quantized) weight.
  2. m = mode of phi over *unmasked* weights (mask==0 weights were removed by
     coarse block pruning and are excluded).
  3. Threshold rule:  all-zero filter -> 0;  m==0 -> 1;  1<=m<=2 -> m;
     m>2 -> 2  (phi_th is capped at 2 so metadata stays within 8 bits/weight).
  4. Re-project every unmasked weight to the nearest value in
     T(phi_th) = { t in INT8 : phi(t) == phi_th }  (exactly phi_th digits —
     the paper's example maps an unpruned literal 0 to 1 under phi_th=1).
     Masked weights stay 0.

Everything is expressed over the 256-entry INT8 domain, so both the
threshold decision and the projection are pure table lookups: jittable,
differentiable-through via STE at the QAT layer.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .csd import PHI_TABLE, INT8_MIN, INT8_MAX

MAX_PHI_TH = 2
DOMAIN = np.arange(INT8_MIN, INT8_MAX + 1, dtype=np.int32)


def threshold_table(phi_th: int) -> np.ndarray:
    """T(phi_th): all INT8 values with exactly phi_th non-zero CSD digits."""
    return DOMAIN[PHI_TABLE == phi_th]


def _build_projection_lut() -> np.ndarray:
    """LUT[phi_th, v+128] = nearest element of T(phi_th) to v.

    Ties resolve toward the larger value — the paper's walkthrough projects
    an unpruned 0 to +1 under phi_th=1. Shape (MAX_PHI_TH+1, 256), int32.
    """
    lut = np.zeros((MAX_PHI_TH + 1, DOMAIN.size), dtype=np.int32)
    for phi in range(MAX_PHI_TH + 1):
        tbl = threshold_table(phi)
        dist = np.abs(DOMAIN[None, :] - tbl[:, None])        # (|T|, 256)
        idx = dist.shape[0] - 1 - np.argmin(dist[::-1], axis=0)
        lut[phi] = tbl[idx]
    return lut


PROJECTION_LUT = _build_projection_lut()


def compute_thresholds(q_weights, mask):
    """phi_th per filter. `q_weights` int32 (..., K, N), filters on last axis.

    mask: same shape, 1 = kept by coarse pruning, 0 = pruned. Returns int32
    (..., N). jnp or np in, same kind out.
    """
    xp = jnp if isinstance(q_weights, jnp.ndarray) else np
    w = xp.asarray(q_weights, dtype=xp.int32)
    m = xp.asarray(mask, dtype=xp.int32)
    phi_tab = jnp.asarray(PHI_TABLE) if xp is jnp else PHI_TABLE
    phi = phi_tab[w - INT8_MIN] * m                          # masked -> 0
    # Mode over the filter (K) axis, counting only unmasked entries.
    # counts[c, ...] = #{k : unmasked and phi == c}, c in 0..8.
    counts = xp.stack([xp.sum((phi == c) & (m == 1), axis=-2)
                       for c in range(9)])                    # (9, ..., N)
    mode = xp.argmax(counts, axis=0).astype(xp.int32)        # ties -> smaller
    any_unmasked = xp.sum(m, axis=-2) > 0
    all_zero = xp.sum(xp.abs(w) * m, axis=-2) == 0
    th = xp.where(mode == 0, 1, xp.minimum(mode, MAX_PHI_TH))
    th = xp.where(all_zero | ~any_unmasked, 0, th)
    return th.astype(xp.int32)


def project(q_weights, mask, phi_th):
    """Nearest-in-T(phi_th) projection. Masked weights forced to 0.

    q_weights int (..., K, N); phi_th int (..., N) broadcast over K.
    """
    xp = jnp if isinstance(q_weights, jnp.ndarray) else np
    w = xp.asarray(q_weights, dtype=xp.int32)
    m = xp.asarray(mask, dtype=xp.int32)
    lut = jnp.asarray(PROJECTION_LUT) if xp is jnp else PROJECTION_LUT
    th = xp.asarray(phi_th, dtype=xp.int32)[..., None, :]    # (...,1,N)
    th = xp.broadcast_to(th, w.shape)
    proj = lut[th, w - INT8_MIN]
    # phi_th == 0 projects everything to 0 already (T(0) == {0}).
    return proj * m


def fta_quantize(q_weights, mask):
    """Full Alg. 1: thresholds + projection. Returns (w_fta, phi_th)."""
    th = compute_thresholds(q_weights, mask)
    return project(q_weights, mask, th), th


def achieved_bit_sparsity(w_fta, mask=None):
    """Fraction of zero CSD digits among stored (unmasked) weights — the
    paper's 'bit-level sparsity' (>= 75% guaranteed when phi_th <= 2)."""
    w = np.asarray(w_fta, dtype=np.int32)
    phi = PHI_TABLE[w - INT8_MIN]
    if mask is not None:
        keep = np.asarray(mask) == 1
        phi = phi[keep]
    if phi.size == 0:
        return 1.0
    return float(1.0 - phi.sum() / (8.0 * phi.size))
