"""DB-PIM core: the paper's algorithmic contribution, bit-true in JAX."""

from . import csd, dyadic, fta, pruning, qat, hybrid  # noqa: F401
