"""Hybrid-grained pruning pipeline (Fig. 4): the paper's three stages as a
reusable driver over arbitrary weight pytrees.

  stage 1  coarse-grained block-wise pruning  -> masks
  stage 2  FTA-aware QAT                      -> EMA scales + projected weights
  stage 3  final FTA quantization             -> FTAExport (q, scale, phi_th,
                                                 mask) + packed DB metadata

The driver is model-agnostic: it operates on a dict of 2-D weight matrices
(K, N) — callers flatten conv kernels via im2col-style reshape (Kh*Kw*Cin, Cout)
and LM projections directly as (d_in, d_out).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional

import numpy as np
import jax.numpy as jnp

from . import pruning, qat, fta, dyadic


@dataclass(frozen=True)
class HybridConfig:
    value_sparsity: float = 0.6        # coarse block-prune ratio
    alpha: int = pruning.DEFAULT_ALPHA
    ema_decay: float = 0.99
    # Layers can opt out (paper: dw-conv / routers are left dense).
    exclude: tuple = ()


def prune_tree(weights: Dict[str, jnp.ndarray], cfg: HybridConfig):
    """Stage 1: masks for every eligible tensor (others get all-ones)."""
    masks = {}
    for name, w in weights.items():
        if name in cfg.exclude or w.ndim != 2 or w.shape[-1] % cfg.alpha:
            masks[name] = jnp.ones_like(w, dtype=jnp.int32)
        else:
            masks[name] = pruning.block_prune_mask(w, cfg.value_sparsity,
                                                   cfg.alpha)
    return masks


def qat_step(weights, masks, ema_states, cfg: HybridConfig):
    """Stage 2 inner step: update EMA ranges, return FTA-projected fake-quant
    weights (STE) for the forward pass + new EMA states + thresholds."""
    new_states, w_fq, phi = {}, {}, {}
    for name, w in weights.items():
        st = ema_states.get(name) or qat.ema_init()
        st = qat.ema_update(st, w)
        new_states[name] = st
        if name in cfg.exclude:
            w_fq[name] = w
            phi[name] = None
            continue
        scale = qat.scale_of(st)
        w_fq[name], phi[name] = qat.fta_fake_quant(w, masks[name], scale)
    return w_fq, new_states, phi


def export_tree(weights, masks, ema_states, cfg: HybridConfig):
    """Stage 3: final FTA quantization + DB metadata packing per tensor."""
    out = {}
    for name, w in weights.items():
        if name in cfg.exclude:
            continue
        scale = qat.scale_of(ema_states[name])
        exp = qat.fta_export(w, masks[name], scale)
        packed = dyadic.pack_terms(np.asarray(exp.q))
        out[name] = {"export": exp, "packed_terms": packed}
    return out


def sparsity_report(exports) -> Dict[str, dict]:
    """Per-tensor compound sparsity stats — feeds the PIM cost model."""
    rep = {}
    for name, e in exports.items():
        exp = e["export"]
        mask = np.asarray(exp.mask)
        q = np.asarray(exp.q)
        v_s = pruning.value_sparsity(mask)
        b_s = fta.achieved_bit_sparsity(q, mask)
        rep[name] = {
            "value_sparsity": v_s,
            "bit_sparsity": b_s,
            "compound_sparsity": 1 - (1 - v_s) * (1 - b_s),
            "phi_th_hist": np.bincount(np.asarray(exp.phi_th), minlength=3)
                             .tolist(),
        }
    return rep
