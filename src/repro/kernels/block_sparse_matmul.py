"""Block-sparse matmul Pallas TPU kernel — the value-level sparsity path.

DB-PIM's sparse allocation network skips 1 x alpha pruned weight blocks.
On TPU the same insight maps to MXU-tile-granular block sparsity: weights
are stored COMPACTED — for every N-column tile only its surviving K-blocks
— plus an index table. HBM traffic and MXU work scale with (1 - sparsity),
exactly like the PIM array only storing surviving rows.

Layout (packed by ops.pack_block_sparse):
  w_blocks: (NT, MAXB, BK, BN)  surviving K-blocks per N tile, zero-padded
  idx:      (NT, MAXB) int32    source K-block index per slot (0-padded)

Kernel: grid (M/BM, NT, MAXB) with the K-block index scalar-prefetched so
the x BlockSpec can gather the matching activation block. Padded slots
multiply zero blocks (adds 0). The accumulator lives in the output tile
across the MAXB-innermost grid dim.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams, CostEstimate, resolve_interpret

BM, BK, BN = 128, 128, 128


def _kernel(idx_ref, x_ref, w_ref, o_ref, acc_ref, *, maxb: int):
    b = pl.program_id(2)

    @pl.when(b == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(b == maxb - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def block_sparse_matmul(x, w_blocks, idx, *, interpret: bool = None):
    """x (M, K) @ block-sparse W -> (M, N). N = NT * BN.

    interpret=None resolves to the backend default (compile on TPU),
    outside the jit boundary so the resolved bool is the cache key."""
    return _block_sparse_matmul(x, w_blocks, idx,
                                interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _block_sparse_matmul(x, w_blocks, idx, *, interpret: bool):
    M, K = x.shape
    NT, MAXB, _, _ = w_blocks.shape
    N = NT * BN
    grid = (M // BM, NT, MAXB)

    # work scales with the STORED blocks only (the value-sparsity saving)
    stored = NT * MAXB * BK * BN
    cost_kw = {} if CostEstimate is None else {"cost_estimate": CostEstimate(
        flops=2 * M * stored,
        bytes_accessed=(M * K * x.dtype.itemsize
                        + stored * w_blocks.dtype.itemsize
                        + NT * MAXB * 4 + M * N * x.dtype.itemsize),
        transcendentals=0)}

    return pl.pallas_call(
        functools.partial(_kernel, maxb=MAXB),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((BM, BK),
                             lambda m, n, b, idx_ref: (m, idx_ref[n, b])),
                pl.BlockSpec((None, None, BK, BN),
                             lambda m, n, b, idx_ref: (n, b, 0, 0)),
            ],
            out_specs=pl.BlockSpec((BM, BN), lambda m, n, b, idx_ref: (m, n)),
            scratch_shapes=[pltpu.VMEM((BM, BN), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        **cost_kw,
    )(idx, x, w_blocks)
