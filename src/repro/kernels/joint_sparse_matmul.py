"""Joint value-level x bit-level sparse matmul — the fused DB-PIM kernel.

This is the kernel the paper's headline gain rests on: value sparsity and
bit sparsity are exploited on the SAME layer, in one pass. The weight
operand is simultaneously

  * COMPACTED (value level): for every N-column tile only its surviving
    K-blocks are stored, exactly like ``block_sparse_matmul`` — the pruned
    1 x alpha blocks of the paper's sparse allocation network become
    MXU-tile-granular skipped blocks, so HBM weight traffic and MXU work
    scale with (1 - value_sparsity);
  * QUANTIZED (bit level): the surviving block payload is INT8 (the FTA
    projection makes the weights exactly representable as INT8 x one
    per-filter scale, as in ``fta_int8_matmul``), so each surviving byte
    is 2x cheaper than bf16 and 4x cheaper than f32.

Net weight traffic: ``(1 - value_sparsity) * 0.5`` of dense bf16.

Packed layout (produced by ``ops.pack_joint_sparse``):

  w_blocks : (NT, MAXB, BK, BN) int8   surviving K-blocks per N tile.
                                       Slots beyond a tile's real block
                                       count are ZERO payload (see below).
  idx      : (NT, MAXB) int32          source K-block index per slot;
                                       padded slots hold 0.
  scales   : (1, N) float32            per-filter (output-channel) scale;
                                       W_dense = scatter(w_blocks) * scales.

Kernel: grid (M/BM, NT, MAXB) with ``idx`` scalar-prefetched so the x
BlockSpec gathers the activation K-block matching each stored weight
block. The INT8 payload is dequantized tile-wise in VMEM to the
activation dtype, accumulated in fp32 across the MAXB-innermost grid dim,
and the per-filter scale is applied ONCE at the final store (scales
commute with the K reduction). Padded slots multiply an all-zero INT8
block — they contribute exactly 0 to the fp32 accumulator regardless of
which activation block ``idx`` points at.

Equivalence guarantee: on FTA-projected weights the INT8 x scale grid is
exact, so for f32 activations the kernel matches the dense reference
(``ref.joint_sparse_matmul_ref``) to fp32 accumulation tolerance.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams, CostEstimate, resolve_interpret

BM, BK, BN = 128, 128, 128


def _kernel(idx_ref, x_ref, w_ref, scale_ref, o_ref, acc_ref, *, maxb: int):
    b = pl.program_id(2)

    @pl.when(b == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # VMEM dequant: int8 -> activation dtype (int8 values are exact in
    # bf16 and f32). Padded slots are all-zero payload => contribute 0.
    w = w_ref[...].astype(x_ref.dtype)
    acc_ref[...] += jnp.dot(x_ref[...], w,
                            preferred_element_type=jnp.float32)

    @pl.when(b == maxb - 1)
    def _store():
        o_ref[...] = (acc_ref[...] * scale_ref[...].astype(jnp.float32)
                      ).astype(o_ref.dtype)


def _cost(M, K, NT, MAXB, bk, bn, x_itemsize, out_itemsize, w_itemsize):
    """Static CostEstimate: work scales with the STORED blocks only."""
    if CostEstimate is None:                      # very old jax
        return None
    stored = NT * MAXB * bk * bn
    return CostEstimate(
        flops=2 * M * stored,
        bytes_accessed=(M * K * x_itemsize        # activations
                        + stored * w_itemsize     # payload (int8/bf16)
                        + NT * MAXB * 4           # index table
                        + NT * bn * 4             # scales
                        + M * NT * bn * out_itemsize),
        transcendentals=0,
    )


def joint_sparse_matmul(x, w_blocks, idx, scales, *, out_dtype=None,
                        bm: int = BM, interpret: bool = None):
    """x (M, K) @ joint-packed W -> (M, N). N = NT * BN.

    ``w_blocks`` (NT, MAXB, BK, BN) int8, ``idx`` (NT, MAXB) int32,
    ``scales`` (1, N) f32 — see module docstring for the layout contract.
    ``bm`` may be any sublane multiple (8 f32 / 16 bf16) — the decode path
    uses a small row tile so a batch-4 step does not pad to 128 MXU rows.
    interpret=None resolves to the backend default (compile on TPU,
    interpret elsewhere; REPRO_PALLAS_INTERPRET overrides). Resolution
    happens OUTSIDE the jit boundary so the resolved bool is the cache
    key — flipping the env var mid-process cannot hit a stale executable.
    """
    return _joint_sparse_matmul(x, w_blocks, idx, scales,
                                out_dtype=out_dtype, bm=bm,
                                interpret=resolve_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("out_dtype", "bm", "interpret"))
def _joint_sparse_matmul(x, w_blocks, idx, scales, *, out_dtype,
                         bm: int, interpret: bool):
    M, K = x.shape
    NT, MAXB, bk, bn = w_blocks.shape
    N = NT * bn
    if M % bm:
        raise ValueError(f"M={M} must be a multiple of bm={bm} "
                         "(ops.joint_dense pads ragged batches)")
    out_dtype = x.dtype if out_dtype is None else out_dtype
    grid = (M // bm, NT, MAXB)

    cost = _cost(M, K, NT, MAXB, bk, bn, x.dtype.itemsize,
                 jnp.dtype(out_dtype).itemsize, w_blocks.dtype.itemsize)
    # only pass the kwarg where this jax knows it (CostEstimate is None
    # on versions whose pallas_call has no cost_estimate parameter)
    cost_kw = {} if cost is None else {"cost_estimate": cost}

    return pl.pallas_call(
        functools.partial(_kernel, maxb=MAXB),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk),
                             lambda m, n, b, idx_ref: (m, idx_ref[n, b])),
                pl.BlockSpec((None, None, bk, bn),
                             lambda m, n, b, idx_ref: (n, b, 0, 0)),
                pl.BlockSpec((1, bn), lambda m, n, b, idx_ref: (0, n)),
            ],
            out_specs=pl.BlockSpec((bm, bn),
                                   lambda m, n, b, idx_ref: (m, n)),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        **cost_kw,
    )(idx, x, w_blocks, scales)
