"""Version compat for the Pallas TPU API surface.

jax renamed `pltpu.TPUCompilerParams` -> `pltpu.CompilerParams` across
releases; resolve whichever this jax ships so the kernels import on both.
"""

from __future__ import annotations

from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")

# pl.CostEstimate is absent on very old jax; None disables the annotation.
CostEstimate = getattr(pl, "CostEstimate", None)
