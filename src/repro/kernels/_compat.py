"""Version compat + runtime flags for the Pallas TPU API surface.

jax renamed `pltpu.TPUCompilerParams` -> `pltpu.CompilerParams` across
releases; resolve whichever this jax ships so the kernels import on both.
"""

from __future__ import annotations

import os

from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")

# pl.CostEstimate is absent on very old jax; None disables the annotation.
CostEstimate = getattr(pl, "CostEstimate", None)

#: env override for the interpret default: "1"/"true" forces interpret
#: mode everywhere, "0"/"false" forces compiled kernels even off-TPU.
INTERPRET_ENV = "REPRO_PALLAS_INTERPRET"

_TRUTHY = ("1", "true", "yes", "on")
_FALSY = ("0", "false", "no", "off")


def default_interpret() -> bool:
    """Backend-aware interpret default for every Pallas kernel.

    TPU backends compile the kernels; everything else (CPU CI, GPU dev
    boxes) interprets them, since Mosaic only lowers for TPU. The
    ``REPRO_PALLAS_INTERPRET`` env var overrides in either direction.
    """
    env = os.environ.get(INTERPRET_ENV)
    if env is not None:
        v = env.strip().lower()
        if v in _TRUTHY:
            return True
        if v in _FALSY:
            return False
        raise ValueError(
            f"{INTERPRET_ENV}={env!r} not understood; use one of "
            f"{_TRUTHY + _FALSY}")
    import jax
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret) -> bool:
    """None -> backend default; everything else passes through as bool."""
    return default_interpret() if interpret is None else bool(interpret)
