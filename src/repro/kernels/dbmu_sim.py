"""Bit-true DBMU + CSD adder tree functional simulation (Pallas).

Emulates the DB-PIM macro datapath exactly as the hardware computes it:
inputs stream in BIT-SERIAL (sign-magnitude planes); each stored Comp
pattern (one 6T cell, sign s / position p = 2*blk + hi) ANDs the input bit
and the CSD-based adder tree recombines partials as

    out[n] = sum_k sum_bit sum_term  s * in_bit(k, bit) * 2^(bit + p)

The packed uint8 metadata layout comes from repro.core.dyadic.pack_terms
(bit0 sign, bit1 pos, bits2-3 block, bit4 valid). Result must equal the
integer matmul x_int8 @ dequant(packed) EXACTLY — this kernel is the
hardware-equivalence oracle for the whole compression pipeline.

Validated in interpret mode (the container has no TPU); the BlockSpec
tiling targets (8, 128)-aligned VMEM tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._compat import resolve_interpret

BM, BN = 8, 128
INPUT_BITS = 8
MAX_TERMS = 2


def _kernel(x_ref, w0_ref, w1_ref, o_ref):
    """x (BM, K) int32 (int8 range); w0/w1 (K, BN) packed term bytes."""
    x = x_ref[...]
    sign_x = jnp.where(x < 0, -1, 1)
    mag = jnp.abs(x)                                   # sign-magnitude view
    acc = jnp.zeros(o_ref.shape, jnp.int32)
    for t, w_ref in enumerate((w0_ref, w1_ref)):
        w = w_ref[...].astype(jnp.int32)
        valid = (w >> 4) & 1
        sign_w = 1 - 2 * (w & 1)
        pos = ((w >> 1) & 1) + 2 * ((w >> 2) & 3)      # 2*blk + hi/lo
        weight_term = valid * sign_w * (1 << pos)      # (K, BN)
        for bit in range(INPUT_BITS):
            in_bit = (mag >> bit) & 1                  # (BM, K) bit plane
            # bitwise AND of the broadcast input bit against Q/Q-bar is
            # the 1b x term product; the CSD adder tree applies the
            # (sign, position) metadata and the bit-plane shift.
            partial = jnp.dot((in_bit * sign_x).astype(jnp.float32),
                              weight_term.astype(jnp.float32),
                              preferred_element_type=jnp.float32)
            acc += (partial.astype(jnp.int32)) << bit
    o_ref[...] = acc


def dbmu_matmul(x_int8, packed, *, interpret: bool = None):
    """x (M, K) int8-range int32; packed (K, N, 2) uint8 -> (M, N) int32.

    interpret=None resolves to the backend default (compile on TPU),
    outside the jit boundary so the resolved bool is the cache key."""
    return _dbmu_matmul(x_int8, packed,
                        interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _dbmu_matmul(x_int8, packed, *, interpret: bool):
    M, K = x_int8.shape
    _, N, _ = packed.shape
    w0 = packed[..., 0]
    w1 = packed[..., 1]
    grid = (M // BM, N // BN)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BM, K), lambda m, n: (m, 0)),
            pl.BlockSpec((K, BN), lambda m, n: (0, n)),
            pl.BlockSpec((K, BN), lambda m, n: (0, n)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda m, n: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        interpret=interpret,
    )(x_int8, w0, w1)
