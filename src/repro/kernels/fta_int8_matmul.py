"""FTA/INT8 weight matmul Pallas TPU kernel — the bit-level sparsity path.

The PIM macro stores only Comp patterns; on TPU the equivalent saving is
bandwidth: FTA-projected weights are EXACTLY representable as INT8 x
per-filter scale, so they stay INT8 in HBM (2x less weight traffic than
bf16 — decode is weight-bound, so this is ~2x decode speedup) and are
dequantized tile-by-tile in VMEM before hitting the MXU in bf16.

The per-filter scale is applied once per output tile after the K
reduction (scales commute with the K sum), not per K-block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams, CostEstimate, resolve_interpret

BM, BK, BN = 128, 512, 128


def _kernel(x_ref, w_ref, scale_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = w_ref[...].astype(jnp.bfloat16)      # VMEM dequant: int8 -> bf16
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.bfloat16), w,
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _store():
        o_ref[...] = (acc_ref[...] * scale_ref[...].astype(jnp.float32)
                      ).astype(o_ref.dtype)


def fta_int8_matmul(x, w_q, scales, *, out_dtype=jnp.bfloat16,
                    interpret: bool = None):
    """x (M, K) bf16/f32 @ (w_q (K, N) int8 * scales (1, N) f32) -> (M, N).

    interpret=None resolves to the backend default (compile on TPU),
    outside the jit boundary so the resolved bool is the cache key."""
    return _fta_int8_matmul(x, w_q, scales, out_dtype=out_dtype,
                            interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("interpret", "out_dtype"))
def _fta_int8_matmul(x, w_q, scales, *, out_dtype, interpret: bool):
    M, K = x.shape
    _, N = w_q.shape
    nk = K // BK
    grid = (M // BM, N // BN, nk)

    # weight traffic is the INT8 bytes (the bit-level saving vs bf16)
    cost_kw = {} if CostEstimate is None else {"cost_estimate": CostEstimate(
        flops=2 * M * K * N,
        bytes_accessed=(M * K * x.dtype.itemsize + K * N + N * 4
                        + M * N * jnp.dtype(out_dtype).itemsize),
        transcendentals=0)}

    return pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BM, BK), lambda m, n, k: (m, k)),
            pl.BlockSpec((BK, BN), lambda m, n, k: (k, n)),
            pl.BlockSpec((1, BN), lambda m, n, k: (0, n)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((BM, BN), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        **cost_kw,
    )(x, w_q, scales)
