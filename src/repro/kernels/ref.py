"""Pure-jnp oracles for every kernel in this package."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.dyadic import unpack_terms


def block_sparse_matmul_ref(x, w_dense, mask):
    """Oracle for block_sparse_matmul: dense matmul with the pruned W."""
    return x @ (w_dense * mask.astype(w_dense.dtype))


def fta_int8_matmul_ref(x, w_q, scales, out_dtype=jnp.bfloat16):
    """Oracle for fta_int8_matmul."""
    w = w_q.astype(jnp.float32) * scales.astype(jnp.float32)
    return (x.astype(jnp.float32) @ w).astype(out_dtype)


def joint_sparse_matmul_ref(x, q_dense, mask, scales,
                            out_dtype=jnp.float32):
    """Oracle for joint_sparse_matmul: dense matmul against the pruned,
    dequantized INT8 weights (q * mask * per-filter scale)."""
    w = (jnp.asarray(q_dense, jnp.float32) * jnp.asarray(mask, jnp.float32)
         * jnp.asarray(scales, jnp.float32))
    return (x.astype(jnp.float32) @ w).astype(out_dtype)


def joint_packed_ref(x, packed, out_dtype=jnp.float32):
    """Oracle from the packed artifact itself (via unpack_joint_sparse)."""
    from . import ops
    w = jnp.asarray(ops.unpack_joint_sparse(packed))
    return (x.astype(jnp.float32) @ w).astype(out_dtype)


def dbmu_matmul_ref(x_int8, packed):
    """Oracle for dbmu_sim: integer matmul against the unpacked weights."""
    w = unpack_terms(np.asarray(packed))              # (K, N) int32
    return np.asarray(x_int8, np.int64) @ w.astype(np.int64)
