"""jit'd wrappers + packing utilities for the Pallas kernels."""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dyadic, fta, pruning, qat
from .block_sparse_matmul import BK, BN, block_sparse_matmul
from .dbmu_sim import dbmu_matmul
from .fta_int8_matmul import fta_int8_matmul
from .joint_sparse_matmul import BM as JBM, joint_sparse_matmul


def pack_block_sparse(w_dense: np.ndarray, mask: np.ndarray,
                      bk: int = BK, bn: int = BN):
    """Compact a masked weight matrix into gathered K-blocks per N tile.

    Returns (w_blocks (NT, MAXB, bk, bn), idx (NT, MAXB) int32). A K-block
    survives for an N tile iff any weight in the (bk, bn) tile is kept.
    MAXB = max surviving blocks over tiles (zero-padded elsewhere).
    """
    w = np.asarray(w_dense) * np.asarray(mask)
    K, N = w.shape
    assert K % bk == 0 and N % bn == 0
    kt, nt = K // bk, N // bn
    tiles = w.reshape(kt, bk, nt, bn)
    alive = np.abs(tiles).sum(axis=(1, 3)) > 0          # (kt, nt)
    maxb = max(int(alive.sum(axis=0).max()), 1)
    w_blocks = np.zeros((nt, maxb, bk, bn), w.dtype)
    idx = np.zeros((nt, maxb), np.int32)
    for n in range(nt):
        rows = np.nonzero(alive[:, n])[0]
        for b, kblk in enumerate(rows):
            w_blocks[n, b] = tiles[kblk, :, n, :]
            idx[n, b] = kblk
    return jnp.asarray(w_blocks), jnp.asarray(idx)


def sparse_dense(x, w_blocks, idx, interpret: bool = None):
    """Public op: block-sparse y = x @ W for 2D/3D activations."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    y = block_sparse_matmul(x2, w_blocks, idx, interpret=interpret)
    return y.reshape(shape[:-1] + (y.shape[-1],))


def fta_pack(w: jnp.ndarray, mask, value_sparsity: float = 0.0):
    """Full DB-PIM weight compilation: block prune -> FTA quantize ->
    (int8 qweights, scale, packed dyadic terms)."""
    scale = jnp.max(jnp.abs(w)) / 127.0
    q = qat.quantize_int8(w, scale)
    q_fta, phi = fta.fta_quantize(q, mask)
    packed = dyadic.pack_terms(np.asarray(q_fta))
    return q_fta.astype(jnp.int8), scale, packed, phi


def fta_dense(x, w_q, scales, interpret: bool = None):
    """Public op: y = x @ (int8 FTA weights x per-filter scales)."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    y = fta_int8_matmul(x2, w_q, scales, interpret=interpret)
    return y.reshape(shape[:-1] + (y.shape[-1],))


# ------------------------------------------------- joint value x bit -------

def random_tile_mask(rng, K: int, N: int, sparsity: float,
                     tile: int = 128) -> np.ndarray:
    """Whole-(tile x tile) random survival mask (ceil + crop, so ragged
    shapes work) — tile-granular value sparsity the kernels can actually
    skip. At least one tile always survives. Benchmarks and tests share
    this so their sparsity semantics cannot drift."""
    kt, nt = -(-K // tile), -(-N // tile)
    alive = rng.random((kt, nt)) >= sparsity
    if not alive.any():
        alive[0, 0] = True
    full = np.repeat(np.repeat(alive, tile, 0), tile, 1)
    return full[:K, :N].astype(np.int32)


def tile_prune_mask(w: np.ndarray, value_sparsity: float,
                    bk: int = BK, bn: int = BN) -> np.ndarray:
    """TPU-granular value pruning: drop the lowest-L2 (bk, bn) weight
    tiles at the target ratio (ceil + crop for ragged shapes).

    This is the MXU mapping of the paper's 1 x alpha sparse allocation
    network: the unit the joint/value kernels can actually SKIP is a
    whole weight tile, so pruning for the kernel path must happen at
    tile granularity — finer 1 x alpha pruning (core.pruning, used for
    the accuracy experiments) essentially never empties a full tile and
    would leave the packed layout dense. At least one tile survives.
    """
    K, N = w.shape
    kt, nt = -(-K // bk), -(-N // bn)
    wp = np.zeros((kt * bk, nt * bn), np.float32)
    wp[:K, :N] = w
    norms = (wp.reshape(kt, bk, nt, bn) ** 2).sum(axis=(1, 3))   # (kt, nt)
    alive = np.ones((kt, nt), bool)
    n_drop = min(int(round(value_sparsity * kt * nt)), kt * nt - 1)
    if n_drop > 0:
        order = np.argsort(norms, axis=None)                     # ascending
        alive.flat[order[:n_drop]] = False
    full = np.repeat(np.repeat(alive, bk, 0), bn, 1)[:K, :N]
    return full.astype(np.int32)


def tile_prune_mask_balanced(w: np.ndarray, value_sparsity: float,
                             bk: int = BK, bn: int = BN) -> np.ndarray:
    """Column-balanced tile pruning: drop the lowest-L2 ``round(vs * kt)``
    K-tiles in EVERY N-tile column (ceil + crop for ragged shapes).

    Unlike ``tile_prune_mask`` (global lowest-norm tiles, variable
    survivors per column), every column keeps exactly the same number of
    K-blocks — so MAXB == the survivor count, the packed layout carries
    ZERO padded slots, and a whole layer stack packs to one shared MAXB.
    This is the uniformity SparseP-style PIM serving needs: stored bytes
    equal ``(1 - vs)`` of dense exactly, per layer, per column.
    """
    K, N = w.shape
    kt, nt = -(-K // bk), -(-N // bn)
    wp = np.zeros((kt * bk, nt * bn), np.float32)
    wp[:K, :N] = w
    norms = (wp.reshape(kt, bk, nt, bn) ** 2).sum(axis=(1, 3))   # (kt, nt)
    n_drop = min(int(round(value_sparsity * kt)), kt - 1)
    alive = np.ones((kt, nt), bool)
    if n_drop > 0:
        order = np.argsort(norms, axis=0)                        # ascending
        for c in range(nt):
            alive[order[:n_drop, c], c] = False
    full = np.repeat(np.repeat(alive, bk, 0), bn, 1)[:K, :N]
    return full.astype(np.int32)


def quantize_int8_fta(w: np.ndarray, mask: np.ndarray,
                      fta_project: bool = True):
    """The bit-level compression step, shared by every packing path:
    per-filter symmetric INT8 scale over the kept weights, then (unless
    fta_project=False) the FTA projection, so the INT8 grid is exactly
    servable by the PIM macro.

    Returns (q int32 (K, N) masked + on the grid, scales f32 (1, N)).
    """
    m = np.asarray(mask, np.int32)
    amax = np.abs(w * m).max(axis=0)
    scales = (amax / 127.0 + 1e-12).astype(np.float32)
    q = np.clip(np.round(w * m / scales), -127, 127).astype(np.int32)
    if fta_project:
        q, _phi = fta.fta_quantize(q, m)
        q = np.asarray(q)
    return q * m, scales.reshape(1, -1)

class JointPacked(NamedTuple):
    """Compacted + quantized weight artifact for joint_sparse_matmul.

    ``w_blocks`` (NT, MAXB, bk, bn) int8 / ``idx`` (NT, MAXB) int32 /
    ``scales`` (1, N_pad) f32 / ``nblocks`` (NT,) int32 real blocks per
    tile (slots past it are zero payload). ``k``/``n`` are the original
    logical dims, ``k_pad`` the padded K the index table refers to.
    """
    w_blocks: jnp.ndarray
    idx: jnp.ndarray
    scales: jnp.ndarray
    nblocks: jnp.ndarray
    k: int
    n: int
    k_pad: int


def _tile_alive(m: np.ndarray, bk: int, bn: int) -> np.ndarray:
    """(kt, nt) bool: does the (bk, bn) mask tile keep any weight? Pads
    ragged shapes with zeros — THE survivor rule, shared by the per-layer
    pack and the stacked shared-MAXB pre-pass so they cannot drift."""
    K, N = m.shape
    kt, nt = -(-K // bk), -(-N // bn)
    mp = np.zeros((kt * bk, nt * bn), np.int32)
    mp[:K, :N] = m
    return mp.reshape(kt, bk, nt, bn).sum(axis=(1, 3)) > 0


def _quantize_and_compact(w, m, bk, bn, fta_project, maxb=None,
                          payload: str = "int8"):
    """Pad -> quantize -> compact one 2D layer. Returns numpy
    (w_blocks, idx, nblocks, scales, Kp, Np). maxb forces the slot count
    (stacked packs share one MAXB across layers); None uses this layer's
    own survivor maximum.

    payload "int8" is the joint/bit-level artifact (INT8 on the
    per-filter FTA scale grid); "bf16" keeps the surviving weights as
    raw bf16 with unit scales — the VALUE-ONLY serving layout, same
    compaction/index structure, no bit-level compression."""
    alive = _tile_alive(m, bk, bn)                              # (kt, nt)
    K, N = w.shape
    kp, npad = (-K) % bk, (-N) % bn
    w = np.pad(w, ((0, kp), (0, npad)))
    m = np.pad(m, ((0, kp), (0, npad)))
    Kp, Np = w.shape

    if payload == "int8":
        q, scales = quantize_int8_fta(w, m, fta_project=fta_project)
        q = q.astype(np.int8)
        pay_dtype = np.int8
    elif payload == "bf16":
        q = np.asarray(jnp.asarray(w * m, jnp.bfloat16))
        scales = np.ones((1, Np), np.float32)
        pay_dtype = q.dtype
    else:
        raise ValueError(f"payload {payload!r} not in ('int8', 'bf16')")

    kt, nt = Kp // bk, Np // bn
    if maxb is None:
        maxb = max(int(alive.sum(axis=0).max()), 1)
    tiles = q.reshape(kt, bk, nt, bn)
    w_blocks = np.zeros((nt, maxb, bk, bn), pay_dtype)
    idx = np.zeros((nt, maxb), np.int32)
    nblocks = np.zeros((nt,), np.int32)
    for n_t in range(nt):
        rows = np.nonzero(alive[:, n_t])[0]
        nblocks[n_t] = rows.size
        for b, kblk in enumerate(rows):
            w_blocks[n_t, b] = tiles[kblk, :, n_t, :]
            idx[n_t, b] = kblk
    return w_blocks, idx, nblocks, scales.reshape(1, Np), Kp, Np


def pack_joint_sparse(w_dense, mask=None, *, bk: int = BK, bn: int = BN,
                      value_sparsity: float = None,
                      fta_project: bool = True) -> JointPacked:
    """Full joint compilation: prune -> INT8/FTA quantize -> compact.

    A K-block survives for an N tile iff the (bk, bn) mask tile keeps any
    weight. When no mask is given and value_sparsity is set, pruning
    happens at (bk, bn) TILE granularity (tile_prune_mask) — the unit the
    kernel can skip. Surviving payload is INT8 on the per-filter-scale
    grid (FTA projection keeps it exactly representable); K and N are
    zero-padded to the tile size, so odd shapes pack fine.
    """
    w = np.asarray(w_dense, np.float32)
    K, N = w.shape
    if mask is None:
        m = (tile_prune_mask(w, value_sparsity, bk, bn) if value_sparsity
             else np.ones_like(w, np.int32))
    else:
        m = np.asarray(mask, np.int32)
    w_blocks, idx, nblocks, scales, Kp, _ = _quantize_and_compact(
        w, m, bk, bn, fta_project)
    return JointPacked(jnp.asarray(w_blocks), jnp.asarray(idx),
                       jnp.asarray(scales), jnp.asarray(nblocks), K, N, Kp)


class JointPackedStacked(NamedTuple):
    """Joint artifact for ALL L layers of one projection family, packed
    with one shared MAXB (= max survivors over layers; slots past a
    layer's real block count are zero payload, which the kernel treats as
    exact zeros). Every field is a single stacked array with a leading
    layer axis — the layout ``lax.scan`` can carry as per-layer xs, which
    is what lets the serving graph run the joint kernel end-to-end
    instead of per-layer.

    ``w_blocks`` (L, NT, MAXB, bk, bn) int8 / ``idx`` (L, NT, MAXB) int32
    / ``scales`` (L, 1, N_pad) f32 / ``nblocks`` (L, NT) int32.
    ``k``/``n``/``k_pad`` are shared static dims (identical across the
    stack by construction).
    """
    w_blocks: jnp.ndarray
    idx: jnp.ndarray
    scales: jnp.ndarray
    nblocks: jnp.ndarray
    k: int
    n: int
    k_pad: int

    @property
    def maxb(self) -> int:
        return self.w_blocks.shape[2]


def pack_joint_sparse_stacked(w_stack, masks=None, *, bk: int = BK,
                              bn: int = BN, value_sparsity: float = None,
                              fta_project: bool = True,
                              payload: str = "int8",
                              ) -> JointPackedStacked:
    """Stack-uniform joint compilation of (L, K, N) layer weights.

    Per layer: column-balanced tile pruning (``tile_prune_mask_balanced``
    — every N-tile column keeps the same number of K-blocks, so with no
    explicit masks MAXB is exactly ``kt - round(vs * kt)`` and NO padded
    slots exist anywhere in the stack) -> per-filter INT8/FTA
    quantization -> compaction into the shared-MAXB layout. With explicit
    ragged ``masks`` (L, K, N), MAXB is the max survivor count over the
    whole stack and short layers pad with zero-payload slots.

    payload "bf16" skips the bit level: surviving blocks carry the raw
    bf16 weights with unit scales — the value-ONLY serving layout
    (weight traffic (1 - vs) of dense bf16 instead of (1 - vs) * 0.5).
    The kernel is payload-dtype-agnostic (it dequantizes whatever the
    blocks hold to the activation dtype), so both layouts serve through
    the same ``joint_dense`` path.
    """
    w_stack = np.asarray(w_stack, np.float32)
    if w_stack.ndim != 3 or not w_stack.shape[0]:
        raise ValueError(f"w_stack must be (L, K, N), got {w_stack.shape}")
    L, K, N = w_stack.shape
    if masks is None:
        ms = [(tile_prune_mask_balanced(w_stack[l], value_sparsity, bk, bn)
               if value_sparsity else np.ones((K, N), np.int32))
              for l in range(L)]
    else:
        ms = [np.asarray(np.asarray(masks)[l], np.int32) for l in range(L)]

    # shared MAXB: max surviving K-blocks over every (layer, column) pair
    maxb = max(1, max(int(_tile_alive(ms[l], bk, bn).sum(axis=0).max())
                      for l in range(L)))

    wbs, idxs, nbs, scs = [], [], [], []
    for l in range(L):
        wb, idx, nb, sc, Kp, _ = _quantize_and_compact(
            w_stack[l], ms[l], bk, bn, fta_project, maxb=maxb,
            payload=payload)
        wbs.append(wb)
        idxs.append(idx)
        nbs.append(nb)
        scs.append(sc)
    return JointPackedStacked(
        jnp.asarray(np.stack(wbs)), jnp.asarray(np.stack(idxs)),
        jnp.asarray(np.stack(scs)), jnp.asarray(np.stack(nbs)),
        K, N, Kp)


class JointPackedGrouped(NamedTuple):
    """Joint artifact for a GROUPED projection family: all L layers x E
    group members (MoE experts) of one projection, packed with ONE shared
    MAXB over every (layer, member) pair. The leading layer axis rides a
    ``lax.scan`` exactly like JointPackedStacked; the second (group) axis
    is sliced by the per-expert dispatch loop inside the scan body.

    ``w_blocks`` (L, E, NT, MAXB, bk, bn) int8|bf16 / ``idx`` (L, E, NT,
    MAXB) int32 / ``scales`` (L, E, 1, N_pad) f32 / ``nblocks`` (L, E,
    NT) int32. ``k``/``n``/``k_pad`` are shared static dims.
    """
    w_blocks: jnp.ndarray
    idx: jnp.ndarray
    scales: jnp.ndarray
    nblocks: jnp.ndarray
    k: int
    n: int
    k_pad: int

    @property
    def maxb(self) -> int:
        return self.w_blocks.shape[3]


def pack_joint_sparse_grouped(w_group, masks=None, *, bk: int = BK,
                              bn: int = BN, value_sparsity: float = None,
                              fta_project: bool = True,
                              payload: str = "int8",
                              ) -> JointPackedGrouped:
    """Group-uniform joint compilation of (L, E, K, N) expert weights.

    The grouped pack is the stacked pack over the FLATTENED (L * E) axis
    — column-balanced tile pruning (``tile_prune_mask_balanced``) per
    (layer, expert) slice, per-filter INT8/FTA quantization, compaction —
    with the shared MAXB taken over every layer of every expert, then the
    (L, E) axes restored. Balanced self-pruning keeps every expert's
    survivor count identical per N-column, so MAXB == ``kt - round(vs *
    kt)`` with ZERO padded slots anywhere in the group; explicit ragged
    ``masks`` (L, E, K, N) pad short members with zero-payload slots.
    payload "bf16" is the value-only layout, exactly as in the stacked
    pack.
    """
    w_group = np.asarray(w_group, np.float32)
    if w_group.ndim != 4 or not (w_group.shape[0] and w_group.shape[1]):
        raise ValueError(f"w_group must be (L, E, K, N), "
                         f"got {w_group.shape}")
    L, E, K, N = w_group.shape
    flat_masks = None
    if masks is not None:
        flat_masks = np.asarray(masks, np.int32).reshape(L * E, K, N)
    flat = pack_joint_sparse_stacked(
        w_group.reshape(L * E, K, N), flat_masks, bk=bk, bn=bn,
        value_sparsity=value_sparsity, fta_project=fta_project,
        payload=payload)
    regroup = lambda a: a.reshape((L, E) + a.shape[1:])
    return JointPackedGrouped(
        regroup(flat.w_blocks), regroup(flat.idx), regroup(flat.scales),
        regroup(flat.nblocks), flat.k, flat.n, flat.k_pad)


def slice_joint_grouped(packed: JointPackedGrouped, l: int,
                        e: int) -> JointPacked:
    """Expert e of layer l as a per-projection JointPacked view."""
    return JointPacked(packed.w_blocks[l, e], packed.idx[l, e],
                       packed.scales[l, e], packed.nblocks[l, e],
                       packed.k, packed.n, packed.k_pad)


def unpack_joint_sparse_grouped(packed: JointPackedGrouped) -> np.ndarray:
    """Invert pack_joint_sparse_grouped -> dense fp32 (L, E, K, N)."""
    L, E = packed.w_blocks.shape[:2]
    return np.stack([
        np.stack([unpack_joint_sparse(slice_joint_grouped(packed, l, e))
                  for e in range(E)]) for l in range(L)])


def slice_joint_stacked(packed: JointPackedStacked, l: int) -> JointPacked:
    """Layer l's view of a stacked pack (the scan body does the same
    slicing implicitly through its xs)."""
    return JointPacked(packed.w_blocks[l], packed.idx[l], packed.scales[l],
                       packed.nblocks[l], packed.k, packed.n, packed.k_pad)


def unpack_joint_sparse_stacked(packed: JointPackedStacked) -> np.ndarray:
    """Invert pack_joint_sparse_stacked -> dense fp32 (L, K, N)."""
    return np.stack([unpack_joint_sparse(slice_joint_stacked(packed, l))
                     for l in range(packed.w_blocks.shape[0])])


def unpack_joint_sparse(packed: JointPacked) -> np.ndarray:
    """Invert pack_joint_sparse -> dense fp32 (K, N) == q * mask * scale.
    Payload-dtype-agnostic: int8 (joint/bit) and bf16 (value-only) blocks
    both scatter exactly into f32."""
    wb = np.asarray(packed.w_blocks).astype(np.float32)
    idx = np.asarray(packed.idx)
    nb = np.asarray(packed.nblocks)
    nt, _, bk, bn = wb.shape
    dense = np.zeros((packed.k_pad, nt * bn), np.float32)
    for n_t in range(nt):
        for b in range(int(nb[n_t])):
            kblk = int(idx[n_t, b])
            dense[kblk * bk:(kblk + 1) * bk,
                  n_t * bn:(n_t + 1) * bn] = wb[n_t, b]
    dense *= np.asarray(packed.scales)
    return dense[:packed.k, :packed.n]


def joint_storage_bytes(packed) -> int:
    """HBM bytes of a joint artifact (payload + index + scales); accepts
    JointPacked or JointPackedStacked (same field names)."""
    return int(packed.w_blocks.size + packed.idx.size * 4
               + packed.scales.size * 4)


def pick_row_tile(m: int, dtype) -> int:
    """Decode-tuned row tile: full 128-row MXU tiles for big batches, the
    smallest legal sublane multiple for small ones — a batch-4 decode
    step pads its activations to 8 (f32) / 16 (bf16) rows, not 128."""
    if m >= JBM:
        return JBM
    sub = 8 if jnp.dtype(dtype).itemsize >= 4 else 16
    return max(sub, sub * (-(-m // sub)))


def joint_dense(x, packed: JointPacked, interpret: bool = None,
                bm: int = None):
    """Public op: joint value x bit sparse y = x @ W for 2D/3D activations.

    Pads M to the kernel row tile and K to the packed K (both zero — padded
    K columns hit only pruned weight rows), slices the result back.
    bm=None picks the row tile from M (small-M decode tile; see
    pick_row_tile); interpret=None uses the backend default.
    """
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    M, K = x2.shape
    if K != packed.k:
        raise ValueError(f"activation K={K} != packed k={packed.k}")
    if bm is None:
        bm = pick_row_tile(M, x.dtype)
    mp = (-M) % bm
    x2 = jnp.pad(x2, ((0, mp), (0, packed.k_pad - K)))
    y = joint_sparse_matmul(x2, packed.w_blocks, packed.idx, packed.scales,
                            bm=bm, interpret=interpret)
    y = y[:M, :packed.n]
    return y.reshape(shape[:-1] + (packed.n,))


def dbmu_reference_check(x_int8, packed, interpret: bool = None):
    """Run the bit-true DBMU datapath."""
    return dbmu_matmul(jnp.asarray(x_int8, jnp.int32),
                       jnp.asarray(packed), interpret=interpret)
