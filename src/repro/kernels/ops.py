"""jit'd wrappers + packing utilities for the Pallas kernels."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dyadic, fta, pruning, qat
from .block_sparse_matmul import BK, BN, block_sparse_matmul
from .dbmu_sim import dbmu_matmul
from .fta_int8_matmul import fta_int8_matmul


def pack_block_sparse(w_dense: np.ndarray, mask: np.ndarray,
                      bk: int = BK, bn: int = BN):
    """Compact a masked weight matrix into gathered K-blocks per N tile.

    Returns (w_blocks (NT, MAXB, bk, bn), idx (NT, MAXB) int32). A K-block
    survives for an N tile iff any weight in the (bk, bn) tile is kept.
    MAXB = max surviving blocks over tiles (zero-padded elsewhere).
    """
    w = np.asarray(w_dense) * np.asarray(mask)
    K, N = w.shape
    assert K % bk == 0 and N % bn == 0
    kt, nt = K // bk, N // bn
    tiles = w.reshape(kt, bk, nt, bn)
    alive = np.abs(tiles).sum(axis=(1, 3)) > 0          # (kt, nt)
    maxb = max(int(alive.sum(axis=0).max()), 1)
    w_blocks = np.zeros((nt, maxb, bk, bn), w.dtype)
    idx = np.zeros((nt, maxb), np.int32)
    for n in range(nt):
        rows = np.nonzero(alive[:, n])[0]
        for b, kblk in enumerate(rows):
            w_blocks[n, b] = tiles[kblk, :, n, :]
            idx[n, b] = kblk
    return jnp.asarray(w_blocks), jnp.asarray(idx)


def sparse_dense(x, w_blocks, idx, interpret: bool = True):
    """Public op: block-sparse y = x @ W for 2D/3D activations."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    y = block_sparse_matmul(x2, w_blocks, idx, interpret=interpret)
    return y.reshape(shape[:-1] + (y.shape[-1],))


def fta_pack(w: jnp.ndarray, mask, value_sparsity: float = 0.0):
    """Full DB-PIM weight compilation: block prune -> FTA quantize ->
    (int8 qweights, scale, packed dyadic terms)."""
    scale = jnp.max(jnp.abs(w)) / 127.0
    q = qat.quantize_int8(w, scale)
    q_fta, phi = fta.fta_quantize(q, mask)
    packed = dyadic.pack_terms(np.asarray(q_fta))
    return q_fta.astype(jnp.int8), scale, packed, phi


def fta_dense(x, w_q, scales, interpret: bool = True):
    """Public op: y = x @ (int8 FTA weights x per-filter scales)."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    y = fta_int8_matmul(x2, w_q, scales, interpret=interpret)
    return y.reshape(shape[:-1] + (y.shape[-1],))


def dbmu_reference_check(x_int8, packed, interpret: bool = True):
    """Run the bit-true DBMU datapath."""
    return dbmu_matmul(jnp.asarray(x_int8, jnp.int32),
                       jnp.asarray(packed), interpret=interpret)
