"""Observability subsystem (repro.obs): the zero-overhead-when-off
contract, trace structural invariants under a seeded fault plan, the
recompile sentinel, exact waterfall attribution, log-bucketed latency
histograms, the Chrome-trace converter, and the report CLI."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.mesh import make_test_mesh
from repro.models import init_cache, init_params
from repro.obs import (LogHistogram, RecompileError, RecompileSentinel,
                       Tracer, engine_waterfall, serving_cost_by_kind,
                       to_chrome_trace, validate)
from repro.obs.trace import TraceError, load
from repro.serving import FaultPlan, Request, ServeEngine
from repro.sparsity.sparse_linear import (build_stacked_tables,
                                          strip_packed_projections)

N_SLOTS = 2
MAX_LEN = 48
CHUNK = 4


def _cfg(arch="tinyllama-1.1b", **kw):
    return get_config(arch, reduced=True, **kw).scaled(
        n_layers=2, d_model=32, vocab_size=64, **{})


def _requests(n=5, gen=5):
    return [Request(rid=i, prompt=list(range(1, 5 + i)), gen_len=gen,
                    arrival=i) for i in range(n)]


def _run(cfg, params, *, tracer=None, fault_plan=None, n=5):
    engine = ServeEngine(cfg, params, n_slots=N_SLOTS, max_len=MAX_LEN,
                         prefill_chunk=CHUNK, tracer=tracer,
                         fault_plan=fault_plan)
    outputs = engine.run(_requests(n))
    return engine, outputs


@pytest.fixture(scope="module")
def tiny():
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def chaos_traced(tiny):
    """One seeded-fault traced run shared by the structural tests."""
    cfg, params = tiny
    plan = FaultPlan.generate(seed=3, n_ticks=60, rate=0.3,
                              n_slots=N_SLOTS)
    tracer = Tracer(arch=cfg.name, meta={"case": "test"})
    engine, outputs = _run(cfg, params, tracer=tracer, fault_plan=plan)
    return engine, tracer


# ------------------------------------------------ zero-overhead-when-off --

def test_tracer_off_is_bitwise_free(tiny):
    """The tentpole contract: tracer attached vs not — SAME generated
    tokens (bitwise) and SAME device-call count. Instrumentation must
    observe the engine, never steer it."""
    cfg, params = tiny
    traced_engine, traced_out = _run(cfg, params, tracer=Tracer(cfg.name))
    bare_engine, bare_out = _run(cfg, params)
    assert traced_out == bare_out
    ts, bs = traced_engine.metrics.summary(), bare_engine.metrics.summary()
    assert ts["device_calls"] == bs["device_calls"]
    assert ts["calls_by_kind"] == bs["calls_by_kind"]
    assert ts["engine_ticks"] == bs["engine_ticks"]


# ---------------------------------------------------- trace invariants ----

def test_trace_validates_under_faults(chaos_traced):
    """A chaotic traced run still satisfies every structural invariant:
    meta-first, monotone clocks, closed LIFO spans, call-within-tick
    containment, exclusive per-slot intervals."""
    engine, tracer = chaos_traced
    stats = validate(tracer.records)
    assert stats["spans"] > 0 and stats["intervals"] > 0
    s = engine.metrics.summary()
    names = [r["name"] for r in tracer.records if r.get("type") == "event"]
    # the fault plan landed -> the lifecycle events must be in the trace
    assert s["n_faults"] > 0 and "fault" in names
    assert s["replays"] == names.count("replay")
    assert names.count("admit") >= 5          # every request admitted
    # one tick span per engine tick, device calls covered by call spans
    ticks = [r for r in tracer.records
             if r.get("type") == "span" and r["name"] == "tick"]
    calls = [r for r in tracer.records
             if r.get("type") == "span" and r["name"] == "call"]
    assert len(ticks) == s["engine_ticks"]
    assert len(calls) == s["device_calls"]


def test_trace_roundtrip_and_report(chaos_traced, tmp_path, capsys):
    """dump -> load roundtrips; the report CLI renders the trace and the
    Chrome converter emits a loadable Perfetto JSON."""
    engine, tracer = chaos_traced
    for kind, wf in engine_waterfall(engine).items():
        tracer.waterfall(kind, wf["rows"], wf["total"])
    path = tmp_path / "trace.jsonl"
    tracer.dump(str(path))
    records = load(str(path))
    assert validate(records) == validate(tracer.records)

    from repro.launch.report import main as report_main
    chrome = tmp_path / "chrome.json"
    assert report_main([str(path), "--chrome", str(chrome)]) == 0
    out = capsys.readouterr().out
    for section in ("TIMELINE", "SLOTS", "QUEUE DEPTH", "WATERFALL",
                    "FAULTS"):
        assert section in out, f"report missing {section} section"
    ct = json.loads(chrome.read_text())
    assert any(e.get("ph") == "X" for e in ct["traceEvents"])


def test_span_nesting_is_lifo_enforced():
    tr = Tracer()
    t = tr.begin("tick", 0)
    c = tr.begin("call", 0)
    with pytest.raises(TraceError):
        tr.end(t)                  # closing the outer span first
    tr.end(c)
    tr.end(t)
    with pytest.raises(TraceError):
        tr.end(t)                  # double close


def test_dump_refuses_open_spans(tmp_path):
    tr = Tracer()
    tr.begin("tick", 0)
    with pytest.raises(TraceError):
        tr.dump(str(tmp_path / "x.jsonl"))


def test_validate_rejects_malformed():
    tr = Tracer()
    s = tr.begin("tick", 0)
    tr.end(s)
    bad = [dict(r) for r in tr.records]
    bad[1]["name"] = "mystery"
    with pytest.raises(TraceError):
        validate(bad)
    with pytest.raises(TraceError):
        validate(tr.records[1:])   # no meta record
    # ticks must be monotone
    tr2 = Tracer()
    a = tr2.begin("tick", 5)
    tr2.end(a)
    b = tr2.begin("tick", 4)
    tr2.end(b)
    with pytest.raises(TraceError):
        validate(tr2.records)


# ------------------------------------------------------------- sentinel ---

def test_sentinel_catches_shape_varying_jit():
    """A jitted fn fed two shapes compiles twice; check() must raise.
    The same fn fed one shape repeatedly stays at one compile."""
    fixed = jax.jit(lambda x: x * 2)
    varying = jax.jit(lambda x: x + 1)
    sent = RecompileSentinel()
    sent.register("fixed@test", fixed)
    sent.register("varying@test", varying)
    for _ in range(3):
        fixed(jnp.zeros((4,)))
    varying(jnp.zeros((4,)))
    sent.check()                              # 1 compile each: fine
    varying(jnp.zeros((8,)))                  # shape change -> recompile
    with pytest.raises(RecompileError, match="varying@test"):
        sent.check()
    assert sent.counts()["varying@test"] == 2
    assert sent.counts()["fixed@test"] == 1


def test_engine_sentinel_one_compile_per_step(tiny):
    """After a full serve, every registered (call_kind, arch) key sits
    at exactly one compile — the fixed-shape no-recompile contract."""
    cfg, params = tiny
    engine, _ = _run(cfg, params)
    counts = engine.sentinel.counts()
    assert counts and all(c <= 1 for c in counts.values()), counts
    assert any(k.startswith("decode@") for k in counts)


# ------------------------------------------------------------ waterfall ---

@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mamba2-1.3b"])
def test_waterfall_rows_sum_exactly_to_weight_bytes(arch):
    """Every modeled weight byte lands in exactly one parameter-path row:
    sum(rows) == weight_bytes with NO tolerance, stacked tables
    included (closure-const attribution)."""
    cfg = get_config(arch, reduced=True, dbpim_mode="joint").scaled(
        n_layers=2, d_model=64, vocab_size=64)
    params = init_params(cfg, jax.random.PRNGKey(0))
    tables = build_stacked_tables(params, cfg)
    assert tables is not None
    params = strip_packed_projections(params, cfg)
    mesh = make_test_mesh()
    cache = init_cache(cfg, N_SLOTS, MAX_LEN)
    cache["pos"] = jnp.zeros((N_SLOTS,), jnp.int32)
    if "attn" in cache and "pos" in cache["attn"]:
        cache["attn"]["pos"] = jnp.zeros((N_SLOTS,), jnp.int32)
    costs = serving_cost_by_kind(cfg, mesh, params, cache,
                                 n_slots=N_SLOTS, prefill_chunk=CHUNK,
                                 tables=tables,
                                 include_exact_fallback=True)
    assert "decode" in costs
    for kind, acc in costs.items():
        rows = acc["weight_bytes_by_path"]
        assert rows, f"{kind}: empty waterfall"
        assert sum(rows.values()) == acc["weight_bytes"], kind
        # stacked serving: the packed tables must be attributed by name,
        # not lumped into a fallback bucket
        assert any(p.startswith("tables/") for p in rows), (kind, rows)


# ------------------------------------------------------------ histogram ---

def test_log_histogram_percentiles_and_merge():
    rng = np.random.default_rng(0)
    vals = rng.lognormal(mean=-3.0, sigma=1.0, size=4000)
    h = LogHistogram()
    for v in vals:
        h.add(float(v))
    for q in (0.5, 0.95, 0.99):
        exact = float(np.quantile(vals, q))
        est = h.percentile(q)
        # log-bucketed: estimate within one bucket (growth factor ~9%)
        assert abs(est - exact) / exact < 0.10, (q, est, exact)
    # merge(a, b) == histogram of concatenation
    h1, h2 = LogHistogram(), LogHistogram()
    for v in vals[:2000]:
        h1.add(float(v))
    for v in vals[2000:]:
        h2.add(float(v))
    h1.merge(h2)
    d1, d = h1.to_dict(), h.to_dict()
    assert d1["buckets"] == d["buckets"] and d1["count"] == d["count"]
    # raw-value running sums differ only by float addition order
    assert d1["total"] == pytest.approx(d["total"])
    # dict roundtrip
    h3 = LogHistogram.from_dict(h.to_dict())
    assert h3.percentile(0.5) == h.percentile(0.5)
    s = h.summary_ms()
    assert s["count"] == 4000 and s["p50_ms"] > 0


# ---------------------------------------------------------------- chrome --

def test_chrome_trace_structure():
    tr = Tracer(arch="x")
    t = tr.begin("tick", 0)
    c = tr.begin("call", 0, kind="decode")
    tr.end(c)
    tr.end(t)
    tr.event("admit", 0, rid=7, slot=1)
    tr.interval(slot=1, rid=7, admit_tick=0, release_tick=3)
    ct = to_chrome_trace(tr.records)
    phases = {e["ph"] for e in ct["traceEvents"]}
    assert {"X", "i", "M"} <= phases
    # the interval lands on the slot's own track (tid = slot + 1)
    ivs = [e for e in ct["traceEvents"]
           if e["ph"] == "X" and e.get("tid") == 2]
    assert len(ivs) == 1 and "rid7" in ivs[0]["name"]
    json.dumps(ct)                            # must be serializable
