"""Substrate tests: data pipeline, optimizer, checkpointing, compression,
fault tolerance, sharding rules."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.checkpoint import CheckpointManager, load_checkpoint, \
    save_checkpoint
from repro.configs import get_config
from repro.data import SyntheticLMDataset
from repro.optim import adamw_init, adamw_update, cosine_with_warmup
from repro.runtime import sharding as shr
from repro.runtime.compression import EFCompressor, compress_tree
from repro.runtime.fault import ElasticMeshPlan, StragglerMonitor, \
    run_resilient


# ---------------------------------------------------------------- data -----

def test_data_deterministic_and_host_sharded():
    cfg = get_config("tinyllama-1.1b", reduced=True)
    ds = SyntheticLMDataset(cfg, 8, 32, seed=1)
    b1, b2 = ds.batch_at(7), ds.batch_at(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(ds.batch_at(8)["tokens"], b1["tokens"])
    # host sharding: different hosts see different slices, same shapes
    d0 = SyntheticLMDataset(cfg, 8, 32, seed=1, host_id=0, n_hosts=2)
    d1 = SyntheticLMDataset(cfg, 8, 32, seed=1, host_id=1, n_hosts=2)
    assert d0.batch_at(0)["tokens"].shape == (4, 32)
    assert not np.array_equal(d0.batch_at(0)["tokens"],
                              d1.batch_at(0)["tokens"])


def test_data_is_learnable_structure():
    cfg = get_config("tinyllama-1.1b", reduced=True)
    ds = SyntheticLMDataset(cfg, 4, 256, seed=0)
    toks = ds.batch_at(0)["tokens"]
    # Zipf head: the most common token should be much more frequent than
    # the uniform rate.
    _, counts = np.unique(toks, return_counts=True)
    assert counts.max() / toks.size > 3.0 / cfg.vocab_size


# ------------------------------------------------------------ optimizer ----

def test_adamw_converges_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    st = adamw_init(params)
    for _ in range(300):
        grads = {"w": 2 * params["w"]}
        params, st = adamw_update(params, grads, st, lr=0.05,
                                  weight_decay=0.0)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


def test_adamw_int8_state_tracks_fp32():
    rng = np.random.default_rng(0)
    w0 = jnp.asarray(rng.normal(0, 1, (512,)), jnp.float32)
    p_fp = {"w": w0}
    p_q = {"w": w0}
    st_fp = adamw_init(p_fp)
    st_q = adamw_init(p_q, int8_state=True)
    assert isinstance(st_q.m["w"], dict)           # block-quantized
    for i in range(20):
        g = {"w": jnp.sin(w0 * (i + 1))}
        p_fp, st_fp = adamw_update(p_fp, g, st_fp, lr=1e-2)
        p_q, st_q = adamw_update(p_q, g, st_q, lr=1e-2)
    err = float(jnp.max(jnp.abs(p_fp["w"] - p_q["w"])))
    assert err < 0.5            # bounded drift (bnb-style re-quant noise)
    # and the int8-state optimizer still optimizes: quadratic convergence
    p = {"w": jnp.linspace(-4.0, 4.0, 512)}
    st = adamw_init(p, int8_state=True)
    assert isinstance(st.m["w"], dict)
    for _ in range(300):
        p, st = adamw_update(p, {"w": 2 * p["w"]}, st, lr=0.05,
                             weight_decay=0.0)
    assert float(jnp.max(jnp.abs(p["w"]))) < 0.1


def test_cosine_schedule_shape():
    assert float(cosine_with_warmup(0)) == pytest.approx(1e-5)
    assert float(cosine_with_warmup(100)) == pytest.approx(1e-3, rel=0.02)
    assert float(cosine_with_warmup(10000)) == pytest.approx(1e-7, abs=1e-6)


# ----------------------------------------------------------- checkpoint ----

def test_checkpoint_roundtrip_bf16(tmp_path):
    tree = {"a": jnp.asarray([1.5, 2.5], jnp.bfloat16),
            "b": {"c": jnp.arange(6, dtype=jnp.int32).reshape(2, 3)}}
    save_checkpoint(str(tmp_path), 3, tree, extra={"x": 1})
    restored, step, extra = load_checkpoint(str(tmp_path), tree)
    assert step == 3 and extra == {"x": 1}
    assert str(restored["a"].dtype) == "bfloat16"
    np.testing.assert_array_equal(np.asarray(restored["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))


def test_checkpoint_gc_and_latest(tmp_path):
    tree = {"w": jnp.zeros(4)}
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(str(tmp_path), s, tree, keep=2)
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(steps) == 2 and steps[-1].endswith("5".zfill(10))


def test_checkpoint_manager_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=2)
    tree = {"w": jnp.ones(8)}
    mgr.maybe_save(1, tree)          # skipped (every=2)
    mgr.maybe_save(2, tree)
    mgr.wait()
    restored = mgr.restore_or_none(tree)
    assert restored is not None and restored[1] == 2


# ----------------------------------------------------------- compression ---

def test_compress_tree_small_error():
    rng = np.random.default_rng(1)
    g = {"w": jnp.asarray(rng.normal(0, 1, (1024,)), jnp.float32)}
    c = compress_tree(g)
    err = float(jnp.max(jnp.abs(c["w"] - g["w"])))
    assert err <= float(jnp.max(jnp.abs(g["w"]))) / 127 + 1e-6


def test_error_feedback_reduces_bias():
    rng = np.random.default_rng(2)
    g = jnp.asarray(rng.normal(0, 1, (1024,)), jnp.float32)
    ef = EFCompressor.init({"w": g})
    total_c = jnp.zeros_like(g)
    for _ in range(50):
        comp, ef = ef.compress({"w": g})
        total_c += comp["w"]
    # accumulated compressed sum converges to accumulated true sum
    rel = float(jnp.linalg.norm(total_c - 50 * g)
                / jnp.linalg.norm(50 * g))
    assert rel < 0.01


# --------------------------------------------------------------- fault -----

def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor()
    for _ in range(20):
        assert not mon.record(0.1)
    assert mon.record(1.0)


def test_run_resilient_restarts_and_degrades():
    calls = {"n": 0}
    saved = {"step": 0}

    def loop(start, plan):
        calls["n"] += 1
        for s in range(start, 50):
            if calls["n"] <= 2 and s == 10 + calls["n"]:
                raise RuntimeError("injected failure")
            saved["step"] = s + 1
        return 50

    plan = ElasticMeshPlan(data_parallel=4, model_parallel=2)
    final = run_resilient(loop, total_steps=50,
                          restore_step=lambda: saved["step"],
                          plan=plan)
    assert final == 50 and calls["n"] == 3


def test_elastic_plan_floor():
    plan = ElasticMeshPlan(1, 16)
    with pytest.raises(RuntimeError):
        plan.degrade()


# -------------------------------------------------------------- sharding ---

def test_param_rules_divisibility_fallback():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # use a fake 16x16 mesh via spec check only
    import unittest.mock as mock
    fake = mock.Mock()
    fake.axis_names = ("data", "model")
    fake.shape = {"data": 16, "model": 16}
    # arctic heads: 56*128=7168 divisible; kv 8*128=1024 divisible
    spec = shr.first_fit((4096, 7168), [(None, "model"), (None, None)], fake)
    assert spec == P(None, "model")
    # something not divisible falls back
    spec = shr.first_fit((4096, 100), [(None, "model"), (None, None)], fake)
    assert spec == P(None, None)


def test_zero1_extends_largest_free_dim():
    import unittest.mock as mock
    fake = mock.Mock()
    fake.axis_names = ("data", "model")
    fake.shape = {"data": 16, "model": 16}
    out = shr.zero1_spec(P(None, "model"), (4096, 12288), fake)
    assert out == P("data", "model")
    # already dp-sharded spec is left alone
    out2 = shr.zero1_spec(P("data", "model"), (4096, 12288), fake)
    assert out2 == P("data", "model")
