"""Tests for the DB-PIM performance model: ordering/monotonicity invariants
and the paper's headline reproduction bands."""

import numpy as np
import pytest

from repro.configs.paper_cnns import CNN_MODELS
from repro.core import pim_model as pm
from repro.core.workload_gen import model_metadata, layer_metadata

ACCEL = ("std", "pw", "fc")


def _speedup(name, vs, **kw):
    layers = [l for l in CNN_MODELS[name]() if l.kind in ACCEL]
    md = model_metadata(layers, vs, name, seed=0)
    dense = pm.evaluate_dense_baseline(layers)
    ours = pm.evaluate_model(layers, md, **kw)
    return dense.cycles / ours.cycles, 1 - ours.energy_pj / dense.energy_pj


def test_vgg19_fig11_band():
    # Paper: 5.50x at 75%, 8.10x at 90%; savings 73.68% -> 83.90%.
    sp75, es75 = _speedup("vgg19", 0.0, use_input_bit=False)
    sp90, es90 = _speedup("vgg19", 0.6, use_input_bit=False)
    assert 4.5 < sp75 < 6.5
    assert 7.0 < sp90 < 9.5
    assert 0.65 < es75 < 0.80
    assert 0.80 < es90 < 0.93
    assert sp90 > sp75 and es90 > es75


def test_model_ordering_matches_paper():
    # VGG19 > ResNet18 > MobileNetV2 in hardware gains (Sec. VI-C).
    sps = {m: _speedup(m, 0.6, use_input_bit=False)[0]
           for m in ("vgg19", "resnet18", "mobilenetv2")}
    assert sps["vgg19"] > sps["resnet18"] > sps["mobilenetv2"]


def test_hybrid_beats_single_sparsity():
    # Fig. 12: hybrid > bit-only > value-only for every model.
    for name in ("vgg19", "mobilenetv2"):
        layers = CNN_MODELS[name]()
        md = model_metadata(layers, 0.6, name, seed=0)
        dense = pm.evaluate_dense_baseline(layers)
        hyb = pm.evaluate_model(layers, md)
        bit = pm.evaluate_model(layers, md, use_value=False)
        val = pm.evaluate_model(layers, md, use_weight_bit=False,
                                use_input_bit=False)
        s = lambda r: dense.cycles / r.cycles
        assert s(hyb) > s(bit) > s(val) > 1.0


def test_speedup_monotone_in_sparsity():
    sps = [_speedup("resnet18", v, use_input_bit=False)[0]
           for v in (0.0, 0.2, 0.4, 0.6)]
    assert all(b >= a - 0.15 for a, b in zip(sps, sps[1:]))  # ~monotone


def test_u_act_beats_dense_baseline():
    layers = [l for l in CNN_MODELS["vgg19"]() if l.kind in ACCEL]
    md = model_metadata(layers, 0.6, "vgg19", seed=0)
    ours = pm.evaluate_model(layers, md)
    dense = pm.evaluate_dense_baseline(layers)
    assert ours.u_act > 0.6            # paper: ~80%
    assert ours.u_act > dense.u_act    # dense stores zero bits


def test_sparsity_metadata_consistency():
    rng = np.random.default_rng(0)
    layer = pm.LayerGEMM("l", M=64, K=128, N=64)
    sp = layer_metadata(layer, 0.5, 5.0, rng)
    assert sp.value_sparsity == pytest.approx(0.5, abs=0.02)
    assert sum(sp.phi_hist) == 64
    assert sp.k_eff <= sp.k_eff_max8 <= layer.K
    assert sp.macro_loads >= sp.col_loads / 16


def test_dense_baseline_cycles_formula():
    cfg = pm.DEFAULT_PIM
    layer = pm.LayerGEMM("l", M=4, K=256, N=16)
    rep = pm.dense_baseline_layer(layer, cfg)
    # 1 M-tile x 1 N-tile x 16 row-cycles x 8 bits
    assert rep.cycles == 16 * 8


def test_simd_layers_identical_in_both_systems():
    layers = CNN_MODELS["mobilenetv2"]()
    md = model_metadata(layers, 0.6, "mobilenetv2", seed=0)
    ours = pm.evaluate_model(layers, md)
    dense = pm.evaluate_dense_baseline(layers)
    dw_ours = [r.cycles for l, r in zip(layers, ours.layers) if l.kind == "dw"]
    dw_dense = [r.cycles for l, r in zip(layers, dense.layers) if l.kind == "dw"]
    assert dw_ours == dw_dense
