"""Paging lane: page allocator, paged == contiguous, preempt/resume.

Pins the paged-cache contract (serving.paging + ServeEngine paged mode):

  * the allocator's invariants (no double ownership, conservation,
    ordered slot pages, all-or-nothing grow) survive seeded churn;
  * a paged engine with an ample pool serves a trace BITWISE identical
    to the contiguous engine, with ZERO extra recompiles — the page
    table is a fixed-shape per-call operand, not a shape change;
  * an oversubscribed pool preempts under page pressure and every
    stream — including the preempted ones, resumed by journaled-record
    replay — still finishes bitwise identical to contiguous;
  * oversized requests are judged against PAGED capacity (slot cap AND
    whole-pool cap), so page-pressure preemption can never livelock;
  * the queue-side completion estimate stays a lower bound but adds the
    page-wait floor when the free pool cannot cover a prompt;
  * a paged engine killed between ticks restores from snapshot +
    journal tail (page tables, admission ages, preempted deque) and
    resumes bitwise (also in the durability lane).

Fast lane: run alone with ``pytest -m paging``.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.serving import (EngineCrash, FaultPlan, PageAllocError,
                           PageAllocator, Request, ServeEngine,
                           WorkloadSpec, make_trace)
from repro.serving.faults import FaultEvent

pytestmark = pytest.mark.paging

SPEC = WorkloadSpec(n_requests=10, arrival_rate=1.0, prompt_len=(3, 10),
                    gen_len=(3, 6), dist="uniform", seed=7)
ENGINE_KW = dict(n_slots=3, max_len=24, prefill_chunk=4)
PAGE_SIZE = 4
TIGHT_PAGES = 8        # < n_slots * max_len/page_size = 18: oversubscribed
SNAPSHOT_EVERY = 6
CRASH_TICKS = (8, 13)  # both past the first snapshot tick


# ------------------------------------------------- allocator unit tests

def test_allocator_deterministic_lowest_first():
    a = PageAllocator(n_pages=6, n_slots=2, max_pages_per_slot=4,
                      page_size=4)
    assert a.grow(0, 2) and a.grow(1, 1)
    assert a.slot_pages() == [[0, 1], [2]]
    a.release(0)
    assert a.grow(1, 3)               # released ids are reused low-first
    assert a.slot_pages() == [[], [2, 0, 1]]
    assert a.free_pages + a.used_pages == a.n_pages
    a.check()


def test_allocator_grow_is_all_or_nothing():
    a = PageAllocator(n_pages=4, n_slots=2, max_pages_per_slot=4,
                      page_size=4)
    assert a.grow(0, 3)
    v = a.version
    assert not a.grow(1, 2)           # needs 2, only 1 free: takes NOTHING
    assert a.version == v and a.free_pages == 1
    assert not a.grow(0, 5)           # slot cap: 5 > max_pages_per_slot
    assert a.grow(0, 3)               # no-op grow succeeds, no version bump
    assert a.version == v
    a.check()


def test_allocator_churn_invariants():
    """Seeded random alloc/grow/release churn never breaks check()."""
    rng = np.random.default_rng(13)
    a = PageAllocator(n_pages=12, n_slots=4, max_pages_per_slot=6,
                      page_size=4)
    for _ in range(500):
        s = int(rng.integers(0, 4))
        op = rng.random()
        if op < 0.55:
            a.grow(s, int(rng.integers(1, 8)))
        elif op < 0.85:
            a.release(s)
        else:
            a.load_slot_pages(a.slot_pages())   # snapshot round-trip
        a.check()
        assert a.free_pages + a.used_pages == a.n_pages
        tab = a.table()
        for s2 in range(4):
            own = a.slot_pages()[s2]
            assert list(tab[s2, :len(own)]) == own
            assert (tab[s2, len(own):] == -1).all()


def test_allocator_rejects_corrupt_snapshot_tables():
    a = PageAllocator(n_pages=4, n_slots=2, max_pages_per_slot=4,
                      page_size=4)
    with pytest.raises(PageAllocError):
        a.load_slot_pages([[0, 1], [1]])        # shared page
    with pytest.raises(PageAllocError):
        a.load_slot_pages([[0], [9]])           # out of range
    with pytest.raises(PageAllocError):
        a.load_slot_pages([[0]])                # wrong slot count


# ----------------------------------------------------------- engine lane

@pytest.fixture(scope="module")
def served():
    cfg = get_config("tinyllama-1.1b", reduced=True).scaled(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    trace = make_trace(SPEC, cfg.vocab_size)
    engine = ServeEngine(cfg, params, **ENGINE_KW)
    ref_out = engine.run(trace)
    return cfg, params, trace, ref_out


def test_paged_ample_pool_is_bitwise_with_zero_recompiles(served):
    """Full static capacity in pages: no preemption possible, outputs
    bitwise the contiguous engine's, and every step compiles exactly
    once — paging moves page ids, never shapes."""
    cfg, params, trace, ref_out = served
    engine = ServeEngine(cfg, params, paged=True, page_size=PAGE_SIZE,
                         **ENGINE_KW)
    out = engine.run(trace)
    assert out == ref_out
    s = engine.metrics.summary()
    assert s["n_preemptions"] == 0 and s["page_alloc_failures"] == 0
    assert engine.sentinel is not None
    assert all(n == 1 for n in engine.sentinel.counts().values()), \
        engine.sentinel.counts()
    engine.page_alloc.check()


def test_tight_pool_preempts_and_resumes_bitwise(served):
    """The oversubscribed pool: page pressure must actually preempt at
    least once, and EVERY stream — preempted ones resumed by journaled-
    record replay — still matches the contiguous run bitwise."""
    cfg, params, trace, ref_out = served
    engine = ServeEngine(cfg, params, paged=True, page_size=PAGE_SIZE,
                         n_pages=TIGHT_PAGES, **ENGINE_KW)
    out = engine.run(trace)
    s = engine.metrics.summary()
    assert s["n_preemptions"] >= 1
    assert s["page_alloc_failures"] >= 1
    assert out == ref_out                     # all streams, bitwise
    assert s["pages_used_max"] <= TIGHT_PAGES
    engine.page_alloc.check()
    assert engine.page_alloc.free_pages == TIGHT_PAGES  # all released


def test_oversized_judged_against_paged_capacity(served):
    """A request whose total exceeds the POOL (even though it fits the
    per-slot cap) must be rejected at submit — admitting it would make
    page-pressure preemption livelock."""
    cfg, params, _, _ = served
    engine = ServeEngine(cfg, params, paged=True, page_size=PAGE_SIZE,
                         n_pages=2, **ENGINE_KW)
    big = Request(rid=0, prompt=tuple(range(1, 10)), gen_len=4)  # 13 > 8
    assert not engine.submit(big)
    assert engine.rejected[0] == "oversized"
    small = Request(rid=1, prompt=(1, 2, 3), gen_len=2)
    assert engine.submit(small)
    strict = ServeEngine(cfg, params, paged=True, page_size=PAGE_SIZE,
                         n_pages=2, strict=True, **ENGINE_KW)
    with pytest.raises(ValueError, match="page pool"):
        strict.submit(big)


def test_min_ticks_to_done_adds_page_wait_floor(served):
    """queued=True adds exactly +1 tick when the free pool cannot cover
    the prompt's pages — admission can't happen this tick, but one
    release could free everything, so the estimate stays a lower
    bound."""
    cfg, params, _, _ = served
    engine = ServeEngine(cfg, params, paged=True, page_size=PAGE_SIZE,
                         n_pages=3, **ENGINE_KW)
    base = engine._min_ticks_to_done(8, 3)
    assert engine._min_ticks_to_done(8, 3, queued=True) == base  # fits
    engine.page_alloc.grow(0, 2)      # 1 page left < pages_for(8) = 2
    assert engine._min_ticks_to_done(8, 3, queued=True) == base + 1
    assert engine._min_ticks_to_done(8, 3) == base    # in-flight: no wait
    engine.page_alloc.release(0)
    assert engine._min_ticks_to_done(8, 3, queued=True) == base


@pytest.mark.durability
def test_paged_kill_chaos_restart_is_bitwise(served, tmp_path):
    """Paged + oversubscribed + killed at two seeded ticks: restore
    rebuilds the page tables, admission ages, and preempted deque from
    snapshot v2 + journal tail, and every stream finishes bitwise the
    contiguous run, with replayed prefill bounded by the cadence."""
    cfg, params, trace, ref_out = served
    jpath = str(tmp_path / "j.jsonl")
    snapdir = str(tmp_path / "snaps")
    plan = FaultPlan(events=tuple(
        FaultEvent(tick=t, kind="engine_crash") for t in CRASH_TICKS))
    kw = dict(paged=True, page_size=PAGE_SIZE, n_pages=TIGHT_PAGES,
              **ENGINE_KW)
    engine = ServeEngine(cfg, params, journal=jpath, snapshot_dir=snapdir,
                         snapshot_every=SNAPSHOT_EVERY, fault_plan=plan,
                         **kw)
    crashes, outputs = 0, None
    try:
        outputs = engine.run(trace)
    except EngineCrash as e:
        crashes, last_tick = 1, e.tick
    while outputs is None:
        engine = ServeEngine.restore(cfg, params, snapshot_dir=snapdir,
                                     journal_path=jpath, fault_plan=plan)
        assert engine.paged and engine.page_size == PAGE_SIZE
        assert engine.n_pages == TIGHT_PAGES
        engine.page_alloc.check()
        assert engine.tick_count > last_tick   # the crash never re-fires
        st = engine.restore_stats
        assert st["replayed_prefill_tokens"] \
            <= SNAPSHOT_EVERY * max(st["slots_restored"], 1)
        try:
            outputs = engine.resume()
        except EngineCrash as e:
            crashes, last_tick = crashes + 1, e.tick
    assert crashes == len(CRASH_TICKS)
    assert outputs == ref_out


def test_paged_snapshot_geometry_mismatch_refused(served, tmp_path):
    """A snapshot from a paged engine must not restore into a different
    page geometry — silently remapping page ids would cross-wire KV."""
    from repro.checkpoint import latest_step
    from repro.serving.snapshot import SnapshotError, restore_engine_state
    cfg, params, trace, _ = served
    jpath = str(tmp_path / "j.jsonl")
    snapdir = str(tmp_path / "snaps")
    engine = ServeEngine(cfg, params, journal=jpath, snapshot_dir=snapdir,
                         snapshot_every=SNAPSHOT_EVERY, paged=True,
                         page_size=PAGE_SIZE, n_pages=TIGHT_PAGES,
                         **ENGINE_KW)
    engine.run(trace)
    contiguous = ServeEngine(cfg, params, **ENGINE_KW)
    with pytest.raises(SnapshotError, match="paged"):
        restore_engine_state(contiguous, snapdir, latest_step(snapdir),
                             journal_path=jpath)


def test_workload_longtail_dists():
    """lognormal / zipf generation stays in-range, skews short, and the
    default gen_dist keeps older traces bit-identical."""
    base = WorkloadSpec(n_requests=200, prompt_len=(3, 16), gen_len=(3, 8),
                        dist="lognormal", gen_dist="zipf", seed=5)
    trace = make_trace(base, vocab_size=100)
    plens = [r.prompt_len for r in trace]
    glens = [r.gen_len for r in trace]
    assert all(3 <= p <= 16 for p in plens)
    assert all(3 <= g <= 8 for g in glens)
    # right-skew: the median sits in the bottom half of the range
    assert sorted(plens)[len(plens) // 2] < (3 + 16) / 2
    assert sorted(glens)[len(glens) // 2] < (3 + 8) / 2
    legacy = WorkloadSpec(n_requests=20, seed=3)
    assert legacy.gen_dist == "uniform"
    explicit = dataclasses.replace(legacy, gen_dist="uniform")
    assert make_trace(legacy, 64) == make_trace(explicit, 64)
