"""Chunked (flash-style) attention must match the dense reference."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A
from repro.models.config import ModelConfig


def _mk_cfg(window=0):
    return ModelConfig(name="t", family="dense", n_layers=1, d_model=64,
                       n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=64,
                       window=window)


@pytest.mark.parametrize("window", [0, 64])
def test_chunked_matches_dense(window):
    cfg = _mk_cfg(window)
    rng = np.random.default_rng(0)
    B, S, H, hd = 2, 256, 4, 16
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, H, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, H, hd)), jnp.float32)
    dense = A._sdpa(q, k, v, A.causal_mask(S, S, window), jnp.float32)
    chunked = A._chunked_sdpa(q, k, v, cfg, jnp.float32, chunk=64)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense),
                               atol=2e-5, rtol=2e-5)
