"""Shared serving-test drivers (imported by test_serving_engine.py and
test_parallel_prefill.py): feed a prompt batch into a fresh per-slot
cache through sequential decode steps, or through fixed-shape
decode_chunk calls with ragged tails — the two prefill paths every
equivalence test compares."""

import jax.numpy as jnp
import numpy as np

from repro.models import decode_chunk, decode_step, init_cache


def stepwise_prefill(params, cfg, prompts, max_len, tables=None):
    """Reference: every prompt token through the (B, 1) decode step."""
    B, P = prompts.shape
    cache = init_cache(cfg, B, max_len)
    cache["pos"] = jnp.zeros((B,), jnp.int32)
    logits = None
    for t in range(P):
        logits, cache = decode_step(params, cache,
                                    jnp.asarray(prompts[:, t:t + 1]), cfg,
                                    tables=tables)
    return logits, cache


def chunked_prefill(params, cfg, prompts, max_len, chunk, tables=None):
    """The prompt through ceil(P/chunk) decode_chunk calls (ragged tail
    via n_valid); chunk math is cfg-dispatched (exact vs parallel SSD)."""
    B, P = prompts.shape
    cache = init_cache(cfg, B, max_len)
    cache["pos"] = jnp.zeros((B,), jnp.int32)
    logits = None
    for s in range(0, P, chunk):
        n = min(chunk, P - s)
        toks = np.zeros((B, chunk), np.int32)
        toks[:, :n] = prompts[:, s:s + n]
        logits, cache = decode_chunk(params, cache, jnp.asarray(toks),
                                     jnp.full((B,), n, jnp.int32), cfg,
                                     tables=tables)
    return logits, cache
