"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode.

Also property tests: the DBMU bit-serial datapath must equal the integer
matmul EXACTLY for any FTA-compliant weights (hardware equivalence of the
whole compression pipeline).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # optional: only the seeded property test below needs hypothesis
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import dyadic, fta, pruning
from repro.kernels import ops, ref
from repro.kernels.block_sparse_matmul import block_sparse_matmul
from repro.kernels.fta_int8_matmul import fta_int8_matmul


# ------------------------------------------------------ block-sparse -------

@pytest.mark.parametrize("M,K,N", [(128, 256, 128), (256, 512, 256),
                                   (128, 128, 384)])
@pytest.mark.parametrize("sparsity", [0.0, 0.5])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_sparse_matmul(M, K, N, sparsity, dtype):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (M, K)), dtype)
    w = rng.normal(0, 1, (K, N)).astype(np.float32)
    mask = np.asarray(pruning.block_prune_mask(w, sparsity, alpha=8))
    # block-tile mask: zero whole (BK, BN) tiles for kernel-level sparsity
    kt, nt = K // 128, N // 128
    tile_alive = rng.random((kt, nt)) > sparsity
    tile_mask = np.repeat(np.repeat(tile_alive, 128, 0), 128, 1)
    w_blocks, idx = ops.pack_block_sparse(w * tile_mask,
                                          np.ones_like(w, np.int32))
    got = block_sparse_matmul(x, w_blocks.astype(dtype), idx)
    want = ref.block_sparse_matmul_ref(x, jnp.asarray(w, dtype),
                                       jnp.asarray(tile_mask))
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 8)


def test_block_sparse_traffic_scales_with_sparsity():
    rng = np.random.default_rng(1)
    w = rng.normal(0, 1, (512, 256)).astype(np.float32)
    kt = 512 // 128
    tile_alive = np.zeros((kt, 2), bool)
    tile_alive[0, :] = True                      # 75% block sparsity
    tile_mask = np.repeat(np.repeat(tile_alive, 128, 0), 128, 1)
    w_blocks, idx = ops.pack_block_sparse(w * tile_mask,
                                          np.ones_like(w, np.int32))
    assert w_blocks.shape[1] == 1                # stores only alive blocks


# ---------------------------------------------------------- int8 FTA -------

@pytest.mark.parametrize("M,K,N", [(128, 512, 128), (256, 1024, 256)])
def test_fta_int8_matmul(M, K, N):
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(0, 1, (M, K)), jnp.bfloat16)
    w_q = jnp.asarray(rng.integers(-127, 128, (K, N)), jnp.int8)
    scales = jnp.asarray(rng.uniform(0.005, 0.02, (1, N)), jnp.float32)
    got = fta_int8_matmul(x, w_q, scales)
    want = ref.fta_int8_matmul_ref(x, w_q, scales)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=0.5)


def test_fta_matmul_exact_on_fta_grid():
    """FTA weights are exactly representable: int8 path == float path."""
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.normal(0, 0.05, (512, 128)), jnp.float32)
    mask = jnp.ones((512, 128), jnp.int32)
    q, scale, packed, phi = ops.fta_pack(w, mask)
    x = jnp.asarray(rng.normal(0, 1, (128, 512)), jnp.float32)
    got = ops.fta_dense(x, q, jnp.full((1, 128), scale))
    w_fta = q.astype(jnp.float32) * scale
    want = (x @ w_fta).astype(jnp.bfloat16)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=0.3)


# ------------------------------------------------------------- DBMU --------

def test_dbmu_bit_true_equivalence():
    """Bit-serial AND + CSD adder tree == integer matmul, exactly."""
    rng = np.random.default_rng(4)
    q = rng.integers(-127, 128, (64, 128), dtype=np.int32)
    mask = np.ones_like(q)
    q_fta, _ = fta.fta_quantize(q, mask)
    packed = dyadic.pack_terms(q_fta)
    x = rng.integers(-127, 128, (16, 64), dtype=np.int32)
    got = np.asarray(ops.dbmu_reference_check(x, packed))
    want = ref.dbmu_matmul_ref(x, packed)
    np.testing.assert_array_equal(got, want.astype(np.int32))


if HAVE_HYPOTHESIS:
    @given(st.integers(min_value=0, max_value=2 ** 31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_dbmu_bit_true_random_seeds(seed):
        rng = np.random.default_rng(seed)
        q = rng.integers(-127, 128, (8, 128), dtype=np.int32)
        q_fta, _ = fta.fta_quantize(q, np.ones_like(q))
        packed = dyadic.pack_terms(q_fta)
        x = rng.integers(-127, 128, (8, 8), dtype=np.int32)
        got = np.asarray(ops.dbmu_reference_check(x, packed))
        np.testing.assert_array_equal(got, ref.dbmu_matmul_ref(x, packed))
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_dbmu_bit_true_random_seeds():
        pass
