"""Unit + property tests for CSD encoding, dyadic blocks, and FTA (Alg. 1)."""

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r "
           "requirements-dev.txt); skipping instead of dying at collection")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import csd, dyadic, fta, pruning, qat


# ---------------------------------------------------------------- CSD ------

def test_csd_roundtrip_full_domain():
    props = csd.verify_csd_properties()
    assert props["roundtrip"] and props["nonadjacent"] and props["minimal"]


def test_csd_paper_examples():
    # Paper Tab. I: 67 = 0100_0101bar, -67 mirrored; -67 = -2^6 - 2^2 + 2^0.
    d67 = np.asarray(csd.to_csd(np.array(67)))
    assert csd.from_csd(d67) == 67
    assert list(d67) == [-1, 0, 1, 0, 0, 0, 1, 0]  # LSB-first: 67=64+4-1
    dm67 = np.asarray(csd.to_csd(np.array(-67)))
    assert list(dm67) == [1, 0, -1, 0, 0, 0, -1, 0]
    assert csd.from_csd(dm67) == -67


def test_csd_mean_reduction_approx_paper():
    # Paper cites ~33% fewer non-zero bits than two's complement on average.
    red = csd.mean_nonzero_reduction()
    assert 0.25 < red < 0.45


@given(st.integers(min_value=-128, max_value=127))
@settings(max_examples=256, deadline=None)
def test_csd_properties_hypothesis(v):
    d = np.asarray(csd.to_csd(np.array(v)))
    assert csd.from_csd(d) == v
    assert np.all(d[1:] * d[:-1] == 0)          # non-adjacent
    assert np.all(np.isin(d, [-1, 0, 1]))


def test_csd_jnp_matches_np():
    x = np.arange(-128, 128, dtype=np.int32)
    np.testing.assert_array_equal(np.asarray(csd.to_csd(jnp.asarray(x))),
                                  csd.to_csd(x))


# ------------------------------------------------------------- dyadic ------

def test_dyadic_blocks_are_zero_or_comp():
    x = np.arange(-128, 128, dtype=np.int32)
    _, ok = dyadic.classify_blocks(x)
    assert ok  # non-adjacency => never two non-zeros inside one block


@given(st.lists(st.integers(min_value=-128, max_value=127),
                min_size=1, max_size=64))
@settings(max_examples=100, deadline=None)
def test_pack_unpack_exact_when_phi_le_2(vals):
    x = np.array(vals, dtype=np.int32)
    phi = csd.phi_lookup(x)
    x2 = x[phi <= 2]
    if x2.size == 0:
        return
    packed = dyadic.pack_terms(x2)
    np.testing.assert_array_equal(dyadic.unpack_terms(packed), x2)


def test_pack_drops_lsb_terms_beyond_max():
    # 0b01010101 = 85 has phi=4 -> packed keeps 2 MSB terms only.
    x = np.array([85], dtype=np.int32)
    assert int(csd.phi_lookup(x)[0]) >= 3
    packed = dyadic.pack_terms(x)
    recon = dyadic.unpack_terms(packed)
    assert recon[0] != 85  # lossy by design; FTA pre-projection prevents this


# ---------------------------------------------------------------- FTA ------

def test_fta_tables():
    assert list(fta.threshold_table(0)) == [0]
    t1 = fta.threshold_table(1)
    # T(1) = +-2^i within INT8: +1..+64 (7 values) and -1..-128 (8 values).
    expect = {2 ** i for i in range(7)} | {-(2 ** i) for i in range(8)}
    assert set(int(v) for v in t1) == expect


def test_fta_paper_walkthrough():
    # Paper Sec. IV-C: f0 = {-63, 0, 64, 0, 0, -8, 13},
    # mask = {1, 0, 1, 1, 0, 1, 1}, phi = {2,0,1,0,0,1,3}, mode=1, th=1,
    # projected -> {-64, 0, 64, 1, 0, -8, 16}.
    f0 = np.array([-63, 0, 64, 0, 0, -8, 13], dtype=np.int32)[:, None]
    mask = np.array([1, 0, 1, 1, 0, 1, 1], dtype=np.int32)[:, None]
    phi = csd.phi_lookup(f0[:, 0])
    np.testing.assert_array_equal(phi, [2, 0, 1, 0, 0, 1, 3])
    th = fta.compute_thresholds(f0, mask)
    assert int(th[0]) == 1
    out = fta.project(f0, mask, th)
    np.testing.assert_array_equal(out[:, 0], [-64, 0, 64, 1, 0, -8, 16])


def test_fta_threshold_rules():
    # all-zero filter -> 0
    w = np.zeros((4, 1), dtype=np.int32)
    m = np.ones_like(w)
    assert int(fta.compute_thresholds(w, m)[0]) == 0
    # mode 0 with nonzero weights -> 1
    w = np.array([0, 0, 0, 3], dtype=np.int32)[:, None]
    assert int(fta.compute_thresholds(w, m)[0]) == 1
    # mode > 2 capped at 2: phi(85)=4 hmm use several values with phi>=3
    w = np.array([85, 85, 85, 85], dtype=np.int32)[:, None]
    assert int(fta.compute_thresholds(w, m)[0]) == 2


@given(st.integers(min_value=0, max_value=2),
       st.lists(st.integers(min_value=-127, max_value=127),
                min_size=4, max_size=32))
@settings(max_examples=100, deadline=None)
def test_fta_projection_invariants(phi_th, vals):
    w = np.array(vals, dtype=np.int32)[:, None]
    mask = np.ones_like(w)
    th = np.full((1,), phi_th, dtype=np.int32)
    out = fta.project(w, mask, th)
    phis = csd.phi_lookup(out[:, 0])
    assert np.all(phis == phi_th)            # exact digit count
    tbl = fta.threshold_table(phi_th)
    # nearest: no table element strictly closer
    for v, o in zip(w[:, 0], out[:, 0]):
        assert abs(o - v) == np.min(np.abs(tbl - v))


def test_fta_projection_jnp_matches_np():
    rng = np.random.default_rng(0)
    w = rng.integers(-127, 128, size=(64, 16), dtype=np.int32)
    m = rng.integers(0, 2, size=(64, 16), dtype=np.int32)
    th_np = fta.compute_thresholds(w, m)
    th_j = fta.compute_thresholds(jnp.asarray(w), jnp.asarray(m))
    np.testing.assert_array_equal(np.asarray(th_j), th_np)
    np.testing.assert_array_equal(
        np.asarray(fta.project(jnp.asarray(w), jnp.asarray(m), th_j)),
        fta.project(w, m, th_np))


def test_fta_bit_sparsity_guarantee():
    rng = np.random.default_rng(1)
    w = rng.integers(-127, 128, size=(128, 32), dtype=np.int32)
    m = np.ones_like(w)
    q, th = fta.fta_quantize(w, m)
    assert np.all(np.asarray(th) <= 2)
    assert fta.achieved_bit_sparsity(q, m) >= 0.75   # paper's guarantee


# ------------------------------------------------------------ pruning ------

def test_block_prune_exact_ratio_and_blocks():
    rng = np.random.default_rng(2)
    w = rng.normal(size=(64, 32)).astype(np.float32)
    mask = pruning.block_prune_mask(w, 0.5, alpha=8)
    assert pruning.value_sparsity(mask) == pytest.approx(0.5)
    # mask constant within each 1x8 block
    mb = np.asarray(mask).reshape(64, 4, 8)
    assert np.all(mb.min(-1) == mb.max(-1))


def test_block_prune_removes_smallest_norms():
    w = np.ones((4, 8), dtype=np.float32)
    w[0, :] = 0.01   # weakest row of blocks
    mask = np.asarray(pruning.block_prune_mask(w, 0.25, alpha=8))
    assert mask[0].sum() == 0 and mask[1:].sum() == 24


# ---------------------------------------------------------------- QAT ------

def test_fake_quant_ste_gradient_identity():
    import jax
    g = jax.grad(lambda x: jnp.sum(qat.fake_quant(x, jnp.float32(0.1))))(
        jnp.linspace(-1, 1, 16))
    np.testing.assert_allclose(np.asarray(g), np.ones(16))


def test_fta_fake_quant_values_on_grid():
    import jax
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32))
    mask = jnp.ones((32, 16), dtype=jnp.int32)
    scale = jnp.float32(np.abs(np.asarray(w)).max() / 127.0)
    w_fq, phi = qat.fta_fake_quant(w, mask, scale)
    q = np.round(np.asarray(w_fq) / float(scale)).astype(np.int32)
    phis = csd.phi_lookup(q)
    np.testing.assert_array_equal(phis, np.broadcast_to(
        np.asarray(phi)[None, :], q.shape))
    # export/dequant roundtrip is lossless on the fake-quant values
    exp = qat.fta_export(w, mask, scale)
    np.testing.assert_allclose(np.asarray(qat.dequant(exp)),
                               np.asarray(w_fq), rtol=0, atol=1e-6)


def test_ema_range_tracking():
    st_ = qat.ema_init()
    st_ = qat.ema_update(st_, jnp.asarray([-2.0, 2.0]))
    assert float(st_.amax) == pytest.approx(2.0, rel=1e-5)
    st_ = qat.ema_update(st_, jnp.asarray([0.0, 4.0]))
    assert 2.0 < float(st_.amax) < 4.0
