"""Durability lane: write-ahead journal, snapshot/restore, kill-chaos.

Pins the crash-safety contract (serving.journal / serving.snapshot /
ServeEngine.restore):

  * the journal is prefix-trusted — recovery stops at the FIRST bad
    frame (torn tail, flipped bit) and resume truncates to it;
  * journaling + snapshotting are PASSIVE — outputs and device-call
    count bitwise/count-identical to a bare run;
  * an engine killed between ticks restores from the latest snapshot +
    journal tail and resumes every stream BITWISE, with replayed
    prefill work bounded by the snapshot cadence;
  * a writer killed MID-snapshot (stray tmp dir) never corrupts the
    latest published snapshot;
  * duplicate rids are rejected (recorded, or raised under strict);
  * EngineStuckError carries the on-disk journal/trace paths.

Fast lane: run alone with ``pytest -m durability``.
"""

from __future__ import annotations

import json
import os

import jax
import pytest

from repro.checkpoint import latest_step
from repro.configs import get_config
from repro.models import init_params
from repro.serving import (EngineCrash, EngineStuckError, FaultPlan,
                           INJECTABLE_KINDS, Journal, MetricsRecorder,
                           ServeEngine, WorkloadSpec, fold_records,
                           make_trace, read_journal)
from repro.serving.faults import FaultEvent
from repro.serving.journal import frame

pytestmark = pytest.mark.durability

SPEC = WorkloadSpec(n_requests=4, arrival_rate=0.5, prompt_len=(3, 10),
                    gen_len=(4, 6), dist="uniform", seed=11)
ENGINE_KW = dict(n_slots=2, max_len=24, prefill_chunk=4)
SNAPSHOT_EVERY = 3
CRASH_TICKS = (5, 9)


# --------------------------------------------------- journal unit tests

def _write_journal(path, records):
    j = Journal(str(path))
    for r in records:
        j.append(r["kind"], r["tick"], **{k: v for k, v in r.items()
                                          if k not in ("kind", "tick")})
    j.commit()
    j.close()
    return j.offset


RECS = [
    {"kind": "submit", "tick": 0, "rid": 1, "prompt": [3, 1, 4],
     "gen_len": 4, "arrival": 0, "deadline": None},
    {"kind": "admit", "tick": 1, "rid": 1, "slot": 0, "skips": 0},
    {"kind": "token", "tick": 2, "rid": 1, "token": 7},
    {"kind": "token", "tick": 3, "rid": 1, "token": 9},
    {"kind": "done", "tick": 4, "rid": 1},
]


def test_journal_roundtrip(tmp_path):
    p = tmp_path / "j.jsonl"
    end = _write_journal(p, RECS)
    recs, off, torn = read_journal(str(p))
    assert recs == RECS
    assert off == end == p.stat().st_size
    assert not torn


def test_journal_prefix_trust_on_corruption(tmp_path):
    """A flipped byte mid-file invalidates EVERYTHING after it — a
    record is only trusted if every record before it is intact."""
    p = tmp_path / "j.jsonl"
    _write_journal(p, RECS)
    raw = p.read_bytes()
    # corrupt one payload byte inside the second frame
    second = raw.index(b"\n") + 1 + 12
    p.write_bytes(raw[:second] + b"#" + raw[second + 1:])
    recs, off, torn = read_journal(str(p))
    assert recs == RECS[:1]
    assert torn
    assert off == raw.index(b"\n") + 1


def test_journal_torn_tail_truncated_on_resume(tmp_path):
    """A partial final frame (crash mid-write) is dropped; resume
    truncates to the last good frame and appends after it."""
    p = tmp_path / "j.jsonl"
    _write_journal(p, RECS)
    good = p.stat().st_size
    with open(p, "ab") as f:                   # torn tail: half a frame
        f.write(frame({"kind": "token", "tick": 5, "rid": 1,
                       "token": 2})[:-9])
    recs, off, torn = read_journal(str(p))
    assert torn and off == good and recs == RECS

    j = Journal(str(p), resume=True)
    assert j.records_recovered == len(RECS)
    assert p.stat().st_size == good            # tail truncated
    j.append("token", 5, rid=1, token=2)
    j.commit()
    j.close()
    recs, _, torn = read_journal(str(p))
    assert not torn
    assert recs[-1] == {"kind": "token", "tick": 5, "rid": 1, "token": 2}


def test_fold_records():
    fold = fold_records(RECS + [
        {"kind": "admit", "tick": 5, "rid": 2, "slot": 0, "skips": 1},
        {"kind": "shed", "tick": 6, "rid": 3, "reason": "deadline"},
    ])
    assert fold["tokens"] == {1: [7, 9]}
    assert fold["token_ticks"] == {1: [2, 3]}
    assert 1 in fold["done"]
    assert fold["admits"][0]["rid"] == 2       # LAST admit wins the slot
    assert set(fold["admitted"]) == {1, 2}
    assert fold["shed"][3]["reason"] == "deadline"
    assert fold["last_tick"] == 6
    assert fold_records([])["last_tick"] == -1


# ------------------------------------------------- fault-plan coverage

def test_engine_crash_is_valid_but_never_sampled():
    e = FaultEvent(tick=4, kind="engine_crash")
    plan = FaultPlan(events=(e,))
    assert plan.crash_at(4) and not plan.crash_at(3)
    with pytest.raises(ValueError):
        FaultEvent(tick=0, kind="power_cut")
    # generate() must NOT sample crashes: existing seeded schedules stay
    # bit-identical, and crashes are a harness-level choice
    plan = FaultPlan.generate(seed=0, n_ticks=500, rate=0.9, n_slots=2)
    assert {ev.kind for ev in plan.events} <= set(INJECTABLE_KINDS)


# --------------------------------------------------------- engine lane

@pytest.fixture(scope="module")
def served():
    cfg = get_config("tinyllama-1.1b", reduced=True).scaled(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    trace = make_trace(SPEC, cfg.vocab_size)
    engine = ServeEngine(cfg, params, **ENGINE_KW)
    ref_out = engine.run(trace)
    return cfg, params, trace, ref_out, engine


def test_journal_and_snapshots_are_passive(served, tmp_path):
    """journal=None is the default; turning the durability layer ON must
    not change outputs or the device-call count (host-side only)."""
    cfg, params, trace, ref_out, ref_engine = served
    engine = ServeEngine(cfg, params, journal=str(tmp_path / "j.jsonl"),
                         snapshot_dir=str(tmp_path / "snaps"),
                         snapshot_every=SNAPSHOT_EVERY, **ENGINE_KW)
    out = engine.run(trace)
    assert out == ref_out
    assert (engine.metrics.summary()["device_calls"]
            == ref_engine.metrics.summary()["device_calls"])
    recs, _, torn = read_journal(str(tmp_path / "j.jsonl"))
    assert not torn
    fold = fold_records(recs)
    assert set(fold["done"]) == set(ref_out)
    assert {rid: t for rid, t in fold["tokens"].items()} == ref_out
    assert latest_step(str(tmp_path / "snaps")) is not None


def test_kill_chaos_restart_is_bitwise(served, tmp_path):
    """The tentpole guard: killed at two seeded ticks, restored from
    snapshot + journal tail each time, every stream finishes bitwise
    identical to the uninterrupted run, and the journal-evidenced
    re-prefill work stays under snapshot_every x slots_restored."""
    cfg, params, trace, ref_out, _ = served
    jpath = str(tmp_path / "j.jsonl")
    snapdir = str(tmp_path / "snaps")
    plan = FaultPlan(events=tuple(
        FaultEvent(tick=t, kind="engine_crash") for t in CRASH_TICKS))
    engine = ServeEngine(cfg, params, journal=jpath, snapshot_dir=snapdir,
                         snapshot_every=SNAPSHOT_EVERY, fault_plan=plan,
                         **ENGINE_KW)
    crashes, outputs = 0, None
    try:
        outputs = engine.run(trace)
    except EngineCrash as e:
        crashes, last_tick = 1, e.tick
    while outputs is None:
        engine = ServeEngine.restore(cfg, params, snapshot_dir=snapdir,
                                     journal_path=jpath, fault_plan=plan)
        st = engine.restore_stats
        assert engine.tick_count > last_tick   # the crash never re-fires
        assert st["replayed_prefill_tokens"] \
            <= SNAPSHOT_EVERY * max(st["slots_restored"], 1)
        try:
            outputs = engine.resume()
        except EngineCrash as e:
            crashes, last_tick = crashes + 1, e.tick
    assert crashes == len(CRASH_TICKS)
    assert outputs == ref_out
    # the journal now tells the whole story once, torn-free
    recs, _, torn = read_journal(jpath)
    assert not torn
    assert {r: t for r, t in fold_records(recs)["tokens"].items()} == ref_out


def test_restore_tolerates_stray_mid_snapshot_tmp_dir(served, tmp_path):
    """A writer killed MID-snapshot leaves a .tmp-* dir; latest_step must
    stay at the previous published step, restore must work, and the next
    save must sweep the carcass."""
    cfg, params, trace, ref_out, _ = served
    jpath = str(tmp_path / "j.jsonl")
    snapdir = tmp_path / "snaps"
    engine = ServeEngine(cfg, params, journal=jpath, snapshot_dir=str(snapdir),
                         snapshot_every=SNAPSHOT_EVERY, **ENGINE_KW)
    engine.run(trace)
    good = latest_step(str(snapdir))
    stray = snapdir / ".tmp-99-12345"
    stray.mkdir()
    (stray / "leaf00000.npy").write_bytes(b"half-written garbage")
    assert latest_step(str(snapdir)) == good   # tmp dirs are invisible
    restored = ServeEngine.restore(cfg, params, snapshot_dir=str(snapdir),
                                   journal_path=jpath)
    assert restored.restore_stats["from_step"] == good
    assert restored.resume() == ref_out        # everything already done
    restored.save_snapshot()                   # next save sweeps the tmp
    assert not stray.exists()


def test_duplicate_rid_rejected_and_recorded(served, tmp_path):
    cfg, params, trace, _, _ = served
    jpath = str(tmp_path / "j.jsonl")
    engine = ServeEngine(cfg, params, journal=jpath, **ENGINE_KW)
    engine.submit(trace[0])
    engine.submit(trace[0])                    # same rid again
    assert engine.duplicate_rids == [trace[0].rid]
    assert len(engine.queue) == 1              # the original survives
    row = engine.metrics.requests[trace[0].rid]
    assert row.outcome != "rejected"           # first submission intact
    engine.journal.commit()
    recs, _, _ = read_journal(jpath)
    rejects = [r for r in recs if r["kind"] == "reject"]
    assert rejects and rejects[0]["reason"] == "duplicate_rid"
    # strict admission escalates to a raise
    strict = ServeEngine(cfg, params, strict=True, **ENGINE_KW)
    strict.submit(trace[0])
    with pytest.raises(ValueError, match="duplicate"):
        strict.submit(trace[0])


def test_stuck_error_carries_artifact_paths(served, tmp_path):
    from repro.obs import Tracer
    cfg, params, trace, _, _ = served
    jpath = str(tmp_path / "j.jsonl")
    tpath = str(tmp_path / "t.jsonl")
    engine = ServeEngine(cfg, params, max_ticks=2, journal=jpath,
                         tracer=Tracer(arch=cfg.name, path=tpath),
                         **ENGINE_KW)
    with pytest.raises(EngineStuckError) as ei:
        engine.run(trace)
    err = ei.value
    assert err.journal_path == jpath and os.path.exists(jpath)
    assert err.trace_path == tpath and os.path.exists(tpath)
    recs, _, torn = read_journal(jpath)
    assert recs and not torn                   # committed pre-raise


def test_metrics_state_dict_roundtrip(served):
    """The snapshot serializes metrics via state_dict(): it must be pure
    JSON and rebuild a recorder whose summary matches exactly."""
    _, _, _, _, engine = served
    sd = engine.metrics.state_dict()
    sd2 = json.loads(json.dumps(sd))           # survives the manifest
    m = MetricsRecorder()
    m.load_state_dict(sd2)
    a, b = m.summary(), engine.metrics.summary()
    for k, v in b.items():
        assert a[k] == v, f"summary[{k!r}] drifted: {a[k]} vs {v}"
