"""Fault-tolerant serving: the blast-radius / bitwise-recovery contract.

Fast lane (pytest -m fault_tolerance): unit tests for the injection
harness (serving.faults — deterministic schedules, event validation),
the shared runtime fault primitives (StragglerMonitor,
ElasticMeshPlan), and engine-level containment on a tiny reduced
config: a zero-fault plan is free, every fault kind is detected and
contained to its slot, recovery-by-replay reproduces the fault-free
token stream bitwise, the per-request fault budget converges to
shedding, and SLO deadlines shed both queued and in-flight requests.
benchmarks/serve_engine_bench.py holds the same contract at workload
scale (BENCH key ``chaos``)."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.runtime.fault import ElasticMeshPlan, StragglerMonitor
from repro.serving import (EngineStuckError, Request, ServeEngine,
                           WorkloadSpec, make_trace)
from repro.serving.faults import (FAULT_KINDS, FaultEvent, FaultPlan,
                                  InjectedFault)

pytestmark = pytest.mark.fault_tolerance


# ------------------------------------------------ runtime primitives ----

def test_straggler_monitor_warmup_then_flags_outliers():
    m = StragglerMonitor(window=10, threshold=2.0, warmup=5)
    for _ in range(4):
        assert not m.record(0.01)
    # 5th sample reaches warmup: 1.0 >> 2 x median(0.01...) -> straggler
    assert m.record(1.0)
    assert m.flagged == 1
    assert not m.record(0.01)
    assert m.flagged == 1


def test_straggler_monitor_no_flag_during_warmup():
    m = StragglerMonitor(warmup=10)
    for _ in range(3):
        m.record(0.01)
    assert not m.record(5.0)          # would be an outlier, still warming up
    assert m.flagged == 0


def test_straggler_monitor_window_bound():
    m = StragglerMonitor(window=10)
    for _ in range(25):
        m.record(0.01)
    assert len(m.times) == 10


def test_elastic_mesh_plan_degrades_data_parallel_only():
    plan = ElasticMeshPlan(data_parallel=4, model_parallel=2)
    d = plan.degrade()
    assert (d.data_parallel, d.model_parallel) == (2, 2)
    d = d.degrade()
    assert (d.data_parallel, d.model_parallel) == (1, 2)
    with pytest.raises(RuntimeError):
        d.degrade()


# ------------------------------------------------- injection harness ----

def test_fault_plan_generate_is_deterministic():
    """Same seed + parameters => bit-identical schedule; a different
    seed diverges (the reproducibility contract the chaos bench rests
    on)."""
    kw = dict(n_ticks=200, rate=0.3, n_slots=4)
    a = FaultPlan.generate(seed=5, **kw)
    b = FaultPlan.generate(seed=5, **kw)
    assert a == b and a.events == b.events
    assert len(a.events) > 0
    assert FaultPlan.generate(seed=6, **kw) != a
    for e in a.events:
        assert e.kind in FAULT_KINDS
        assert 0 <= e.slot < 4 and 0 <= e.tick < 200


def test_fault_event_validates_kind_and_call():
    with pytest.raises(ValueError):
        FaultEvent(tick=0, kind="meteor_strike")
    with pytest.raises(ValueError):
        FaultEvent(tick=0, kind="nan_logits", call="embed")


def test_check_step_honors_repeat_and_call_scope():
    plan = FaultPlan(events=(
        FaultEvent(tick=3, kind="step_exception", call="decode", repeat=2),))
    with pytest.raises(InjectedFault):
        plan.check_step(3, "decode", attempt=0)
    with pytest.raises(InjectedFault):
        plan.check_step(3, "decode", attempt=1)
    plan.check_step(3, "decode", attempt=2)     # repeat budget exhausted
    plan.check_step(3, "prefill", attempt=0)    # other call untouched
    plan.check_step(2, "decode", attempt=0)     # other tick untouched


def test_slot_queries_scope_by_tick_and_call():
    plan = FaultPlan(events=(
        FaultEvent(tick=1, kind="nan_logits", call="decode", slot=2),
        FaultEvent(tick=1, kind="cache_corruption", slot=3),))
    assert plan.logit_slots(1, "decode") == [2]
    assert plan.logit_slots(1, "prefill") == []
    assert plan.logit_slots(0, "decode") == []
    assert plan.cache_slots(1) == [3]
    assert plan.cache_slots(2) == []
    assert FaultPlan.none().events == ()


# --------------------------------------------------- engine containment --

SPEC = WorkloadSpec(n_requests=4, arrival_rate=0.0, prompt_len=(3, 8),
                    gen_len=(3, 5), dist="uniform", seed=11)


@pytest.fixture(scope="module")
def served():
    cfg = get_config("tinyllama-1.1b", reduced=True).scaled(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    trace = make_trace(SPEC, cfg.vocab_size)
    return cfg, params, trace


def _run(served, plan, **kw):
    cfg, params, trace = served
    eng = ServeEngine(cfg, params, n_slots=2, max_len=24, prefill_chunk=4,
                      fault_plan=plan, **kw)
    return eng, eng.run(trace)


@pytest.fixture(scope="module")
def ref(served):
    eng, out = _run(served, None)
    return eng.metrics.summary(), out


def test_zero_fault_plan_is_free(served, ref):
    """The ISSUE's no-overhead guard: an engine driven by an EMPTY fault
    plan produces bitwise the outputs of one with no plan at all, with
    the exact same device-call count."""
    ref_s, ref_out = ref
    eng, out = _run(served, FaultPlan.none())
    assert out == ref_out
    s = eng.metrics.summary()
    assert s["device_calls"] == ref_s["device_calls"]
    assert s["n_faults"] == 0 and s["replays"] == 0 and s["retries"] == 0


def test_nan_logits_contained_to_one_slot_and_recovered(served, ref):
    """A NaN-logits fault fails ONLY the targeted slot; its request
    replays and every stream still matches the fault-free run bitwise."""
    ref_s, ref_out = ref
    plan = FaultPlan(events=(
        FaultEvent(tick=2, kind="nan_logits", call="any", slot=0),))
    eng, out = _run(served, plan)
    assert out == ref_out
    s = eng.metrics.summary()
    assert s["faults"].get("nonfinite_logits", 0) >= 1
    assert s["replays"] >= 1
    assert s["goodput"] == 1.0
    # containment: exactly one request was charged the fault + replay
    hit = [r for r in eng.metrics.requests.values() if r.faults > 0]
    assert len(hit) == 1 and hit[0].replays >= 1


def test_transient_step_exception_absorbed_by_retry(served, ref):
    """repeat=1 models a blip one retry clears: no quarantine, no extra
    SUCCESSFUL device calls (injection raises pre-dispatch), outputs
    bitwise unchanged."""
    ref_s, ref_out = ref
    plan = FaultPlan(events=(
        FaultEvent(tick=1, kind="step_exception", call="any", repeat=1),))
    eng, out = _run(served, plan)
    assert out == ref_out
    s = eng.metrics.summary()
    assert s["retries"] >= 1
    assert s["replays"] == 0                      # retry, not replay
    assert s["device_calls"] == ref_s["device_calls"]


def test_persistent_step_exception_quarantines_participants(served, ref):
    """repeat past the retry budget: every slot in the failed call
    quarantines, replays, and the streams still finish bitwise."""
    ref_s, ref_out = ref
    plan = FaultPlan(events=(
        FaultEvent(tick=1, kind="step_exception", call="any", repeat=99),))
    eng, out = _run(served, plan, max_step_retries=2)
    assert out == ref_out
    s = eng.metrics.summary()
    assert s["faults"]["step_exception"] >= 3     # 3 failed attempts min
    assert s["replays"] >= 1
    assert s["goodput"] == 1.0


def test_cache_corruption_detected_by_propagation(served, ref):
    """Poisoned cache slices have no direct detector — the NaN surfaces
    as non-finite logits at the slot's next device call, which
    quarantines it; replay restores the stream bitwise. Tick 1 slot 0
    is mid-decode with two tokens out, so the replay record is prompt +
    emitted stream, not just the prompt."""
    ref_s, ref_out = ref
    plan = FaultPlan(events=(
        FaultEvent(tick=1, kind="cache_corruption", slot=0),))
    eng, out = _run(served, plan)
    assert out == ref_out
    s = eng.metrics.summary()
    assert s["faults"].get("cache_corruption", 0) == 1
    assert s["faults"].get("nonfinite_logits", 0) >= 1   # the detection
    assert s["replays"] >= 1 and s["goodput"] == 1.0


def test_fault_budget_sheds_instead_of_livelocking(served, ref):
    """max_replays=0: the first quarantine exhausts the budget and the
    request is shed ("fault_budget"); the other streams finish bitwise."""
    ref_s, ref_out = ref
    plan = FaultPlan(events=(
        FaultEvent(tick=2, kind="nan_logits", call="any", slot=0),))
    eng, out = _run(served, plan, max_replays=0)
    s = eng.metrics.summary()
    assert s["n_shed"] == 1 and s["replays"] == 0
    shed = [r for r in eng.metrics.requests.values() if r.outcome == "shed"]
    assert len(shed) == 1 and shed[0].reason == "fault_budget"
    assert s["n_completed"] == SPEC.n_requests - 1
    for r in eng.metrics.requests.values():
        if r.outcome == "done":
            assert out[r.rid] == ref_out[r.rid]


# --------------------------------------------- admission + SLO shedding --

def test_oversized_and_queue_full_are_recorded_not_raised(served):
    cfg, params, _ = served
    eng = ServeEngine(cfg, params, n_slots=2, max_len=8, queue_cap=1)
    big = Request(rid=0, prompt=tuple(range(1, 8)), gen_len=8)
    ok = Request(rid=1, prompt=(1, 2), gen_len=2)
    extra = Request(rid=2, prompt=(3, 4), gen_len=2)
    assert eng.submit(big) is False
    assert eng.rejected[0] == "oversized"
    assert eng.submit(ok) is True
    assert eng.submit(extra) is False             # bounded queue
    assert eng.rejected[2] == "queue_full"
    s = eng.metrics.summary()
    assert s["n_rejected"] == 2
    assert eng.metrics.requests[0].outcome == "rejected"
    assert eng.metrics.requests[2].reason == "queue_full"


def test_hopeless_queued_request_shed_before_taking_a_slot(served):
    cfg, params, _ = served
    eng = ServeEngine(cfg, params, n_slots=2, max_len=24, prefill_chunk=4)
    doomed = Request(rid=0, prompt=tuple(range(1, 7)), gen_len=4,
                     deadline=1.0)                # needs >= 5 ticks
    fine = Request(rid=1, prompt=(1, 2, 3), gen_len=3, deadline=50.0)
    out = eng.run([doomed, fine])
    assert 0 not in out                           # never held a slot
    r0 = eng.metrics.requests[0]
    assert r0.outcome == "shed" and r0.reason == "deadline"
    assert r0.admitted_tick is None
    assert eng.metrics.requests[1].outcome == "done"


def test_in_flight_request_preempted_when_fault_breaks_deadline(served):
    """A request whose deadline was reachable at admission is preempted
    the tick a fault's replay cost makes it unreachable."""
    cfg, params, _ = served
    plan = FaultPlan(events=(
        FaultEvent(tick=1, kind="nan_logits", call="decode", slot=0),))
    eng = ServeEngine(cfg, params, n_slots=1, max_len=24, prefill_chunk=4,
                      fault_plan=plan)
    # fault-free: chunk at tick 0 emits token 1, then one per tick ->
    # done at tick 3 == the deadline, with zero slack for a replay
    req = Request(rid=0, prompt=(1, 2, 3, 4), gen_len=4, deadline=3.0)
    out = eng.run([req])
    r = eng.metrics.requests[0]
    assert r.outcome == "shed" and r.reason == "deadline"
    assert r.faults >= 1                          # the fault that broke it
    assert len(out[0]) < req.gen_len              # preempted mid-stream
    assert eng.metrics.summary()["n_shed"] == 1


def test_engine_stuck_error_carries_post_mortem(served):
    cfg, params, trace = served
    eng = ServeEngine(cfg, params, n_slots=2, max_len=24, prefill_chunk=4,
                      max_ticks=2)
    with pytest.raises(EngineStuckError) as ei:
        eng.run(trace)
    e = ei.value
    assert isinstance(e.outputs, dict)
    assert e.slot_log and e.slot_log[0].admit_tick == 0
    assert e.summary["engine_ticks"] >= 2
