"""Parallel-form (SSD) chunked SSM prefill: tolerance-equivalence to the
sequential decode recurrence across chunk sizes and stacked-table modes,
the prefill_exact bitwise fallback, and the per-call-kind cost tags."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.mesh import make_test_mesh
from repro.launch.steps import (build_prefill_chunk_step,
                                build_slot_decode_step)
from repro.models import decode_chunk, init_cache, init_params
from repro.models.ssm import PARALLEL_PREFILL_ATOL
from repro.sparsity.sparse_linear import build_stacked_tables

ARCH = "mamba2-1.3b"


def _cfg(mode=None, **kw):
    cfg = get_config(ARCH, reduced=True, dbpim_mode=mode)
    return cfg.scaled(dtype="float32", dbpim_value_sparsity=0.5, **kw)


def _tables(cfg, params):
    if not cfg.dbpim or cfg.dbpim_mode == "dense":
        return None
    tables = build_stacked_tables(params, cfg, bk=32, bn=32)
    assert tables is not None
    return tables


from conftest import chunked_prefill as _chunked
from conftest import stepwise_prefill as _stepwise


def _assert_close(tree_a, tree_b, atol):
    for a, b in zip(jax.tree_util.tree_leaves(tree_a),
                    jax.tree_util.tree_leaves(tree_b)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=atol)


# ----------------------------------------- equivalence contract ----------

@pytest.mark.parametrize("mode", [None, "value", "joint"])
@pytest.mark.parametrize("chunk,plen", [(1, 5), (4, 8), (8, 8), (4, 11)])
def test_parallel_prefill_matches_sequential_decode(mode, chunk, plen):
    """The tentpole contract: the parallel SSD chunk (ONE read of the
    stacked in/out projections per chunk) lands within
    PARALLEL_PREFILL_ATOL of feeding the prompt through sequential decode
    steps — logits, SSM state, conv window, and positions — for dense,
    value-payload, and joint stacked tables, including ragged prompts
    (plen not a chunk multiple)."""
    cfg = _cfg(mode)
    assert not cfg.prefill_exact          # parallel is the default
    params = init_params(cfg, jax.random.PRNGKey(0))
    tables = _tables(cfg, params)
    prompts = np.random.default_rng(1).integers(
        1, cfg.vocab_size, (3, plen)).astype(np.int32)
    atol = PARALLEL_PREFILL_ATOL[cfg.dtype]
    ls, cs = _stepwise(params, cfg, prompts, 16, tables=tables)
    lp, cp = _chunked(params, cfg, prompts, 16, chunk, tables=tables)
    np.testing.assert_allclose(np.asarray(ls, np.float32),
                               np.asarray(lp, np.float32), atol=atol)
    np.testing.assert_array_equal(np.asarray(cs["pos"]),
                                  np.asarray(cp["pos"]))
    _assert_close(cs["ssm"], cp["ssm"], atol)


def test_prefill_exact_restores_bit_identity():
    """cfg.prefill_exact=True routes the chunk back through the per-token
    recurrence: BITWISE equal to sequential decode, at C x the
    projection traffic."""
    cfg = _cfg(None, prefill_exact=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = np.random.default_rng(2).integers(
        1, cfg.vocab_size, (2, 7)).astype(np.int32)
    ls, cs = _stepwise(params, cfg, prompts, 16)
    lc, cc = _chunked(params, cfg, prompts, 16, chunk=4)
    np.testing.assert_array_equal(np.asarray(ls), np.asarray(lc))
    for a, b in zip(jax.tree_util.tree_leaves(cs),
                    jax.tree_util.tree_leaves(cc)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_parallel_chunk_zero_valid_slot_exactly_untouched():
    """Invalid slots (n_valid=0) are masked by zeroing dt — an EXACT
    identity on the state (state * exp(0) + 0), and the conv gather at
    cursor 0 returns the carried window bit-for-bit."""
    cfg = _cfg(None)
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = np.random.default_rng(3).integers(
        1, cfg.vocab_size, (2, 4)).astype(np.int32)
    _, cache = _stepwise(params, cfg, prompts, 16)
    toks = np.zeros((2, 4), np.int32)
    toks[0] = prompts[0]
    _, cache2 = decode_chunk(params, cache, jnp.asarray(toks),
                             jnp.asarray([4, 0], jnp.int32), cfg)
    assert int(cache2["pos"][0]) == 8 and int(cache2["pos"][1]) == 4
    for key in ("conv", "state"):
        np.testing.assert_array_equal(
            np.asarray(cache["ssm"][key])[:, 1],
            np.asarray(cache2["ssm"][key])[:, 1])


def test_parallel_prefill_mixed_ragged_slots():
    """Slots at DIFFERENT cursors in one chunk (the engine's steady
    state): each slot's trajectory matches its own sequential decode."""
    cfg = _cfg("joint")
    params = init_params(cfg, jax.random.PRNGKey(0))
    tables = _tables(cfg, params)
    rng = np.random.default_rng(4)
    atol = PARALLEL_PREFILL_ATOL[cfg.dtype]
    p0 = rng.integers(1, cfg.vocab_size, (1, 6)).astype(np.int32)
    p1 = rng.integers(1, cfg.vocab_size, (1, 3)).astype(np.int32)
    # batch run: slot0 advances 4 then 2; slot1 advances 3 then idles
    cache = init_cache(cfg, 2, 16)
    cache["pos"] = jnp.zeros((2,), jnp.int32)
    toks = np.zeros((2, 4), np.int32)
    toks[0] = p0[0, :4]
    toks[1, :3] = p1[0]
    _, cache = decode_chunk(params, cache, jnp.asarray(toks),
                            jnp.asarray([4, 3], jnp.int32), cfg,
                            tables=tables)
    toks = np.zeros((2, 4), np.int32)
    toks[0, :2] = p0[0, 4:]
    logits, cache = decode_chunk(params, cache, jnp.asarray(toks),
                                 jnp.asarray([2, 0], jnp.int32), cfg,
                                 tables=tables)
    assert cache["pos"].tolist() == [6, 3]
    # per-slot sequential references
    l0, c0 = _stepwise(params, cfg, p0, 16, tables=tables)
    l1, c1 = _stepwise(params, cfg, p1, 16, tables=tables)
    np.testing.assert_allclose(np.asarray(logits[0], np.float32),
                               np.asarray(l0[0], np.float32), atol=atol)
    for key in ("conv", "state"):
        np.testing.assert_allclose(
            np.asarray(cache["ssm"][key], np.float32)[:, 0],
            np.asarray(c0["ssm"][key], np.float32)[:, 0], atol=atol)
        np.testing.assert_allclose(
            np.asarray(cache["ssm"][key], np.float32)[:, 1],
            np.asarray(c1["ssm"][key], np.float32)[:, 0], atol=atol)


# ------------------------------------------------- config + cost tags ----

def test_supports_parallel_prefill_predicate():
    assert _cfg(None).supports_parallel_prefill
    assert not get_config("tinyllama-1.1b",
                          reduced=True).supports_parallel_prefill
    assert not get_config("mixtral-8x7b",
                          reduced=True).supports_parallel_prefill


def test_get_config_prefill_exact_kwarg():
    assert get_config(ARCH, reduced=True, prefill_exact=True).prefill_exact
    assert not get_config(ARCH, reduced=True).prefill_exact


def test_step_builders_tag_call_kinds():
    """Cost attribution (jaxpr_cost.analyze_call_kinds) keys off the step
    builders' call_kind tags: SSM chunks are "prefill_parallel" by
    default, "prefill_chunk_exact" under cfg.prefill_exact, attention
    chunks always exact, decode steps "decode"."""
    mesh = make_test_mesh()
    ssm = _cfg(None)
    fn, _ = build_prefill_chunk_step(ssm, mesh)
    assert fn.call_kind == "prefill_parallel"
    fn, _ = build_prefill_chunk_step(ssm.scaled(prefill_exact=True), mesh)
    assert fn.call_kind == "prefill_chunk_exact"
    attn = get_config("tinyllama-1.1b", reduced=True)
    fn, _ = build_prefill_chunk_step(attn, mesh)
    assert fn.call_kind == "prefill_chunk_exact"
    fn, _ = build_slot_decode_step(ssm, mesh)
    assert fn.call_kind == "decode"


def test_parallel_chunk_reads_projections_once():
    """The perf contract, measured on the jaxpr: the parallel chunk's
    weight bytes are far below the exact chunk's (which re-reads the
    in/out projections once per token) — and the decode step reads the
    same weights as one parallel chunk (both read once)."""
    from repro.runtime.jaxpr_cost import analyze_call_kinds
    mesh = make_test_mesh()
    # the CI bench config (bf16 + default value sparsity + kernel tiles):
    # the >= 4x contract is stated there — an f32 unembedding would
    # dilute the ratio (it is paid once per chunk on BOTH paths)
    cfg = get_config(ARCH, reduced=True, dbpim_mode="joint")
    params = init_params(cfg, jax.random.PRNGKey(0))
    tables = build_stacked_tables(params, cfg)
    assert tables is not None
    from repro.sparsity.sparse_linear import strip_packed_projections
    params = strip_packed_projections(params, cfg)
    B, C = 2, 8
    cache = init_cache(cfg, B, 16)
    cache["pos"] = jnp.zeros((B,), jnp.int32)
    toks = jnp.zeros((B, C), jnp.int32)
    nv = jnp.full((B,), C, jnp.int32)
    par_fn, _ = build_prefill_chunk_step(cfg, mesh, stacked_tables=tables)
    ex_fn, _ = build_prefill_chunk_step(cfg.scaled(prefill_exact=True),
                                        mesh, stacked_tables=tables)
    kinds = analyze_call_kinds({
        par_fn.call_kind: (par_fn, (params, cache, toks, nv)),
        ex_fn.call_kind: (ex_fn, (params, cache, toks, nv))})
    par = kinds["prefill_parallel"]["weight_bytes"]
    ex = kinds["prefill_chunk_exact"]["weight_bytes"]
    assert par < ex / 2, (par, ex)
    # per prompt token the parallel chunk must beat the exact chunk >= 4x
    assert par / ex <= 0.25, (par, ex)
