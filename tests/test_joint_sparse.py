"""Joint value x bit sparse kernel: pack/unpack round-trip, kernel-vs-
dense-reference equivalence across sparsity ratios and odd shapes, the
padded-slot zero guard, and the mode dispatch through the model layers.

Property tests need hypothesis; everything else runs without it.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.joint_sparse_matmul import joint_sparse_matmul

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


_tile_mask = ops.random_tile_mask


def _dense_quant(w, mask):
    """Independent dense recomputation of the pack's quantization step."""
    from repro.core import fta
    m = np.asarray(mask, np.int32)
    amax = np.abs(w * m).max(axis=0)
    scales = (amax / 127.0 + 1e-12).astype(np.float32)
    q = np.clip(np.round(w * m / scales), -127, 127).astype(np.int32)
    q, _ = fta.fta_quantize(q, m)
    return np.asarray(q) * m, scales.reshape(1, -1)


# ------------------------------------------------- pack/unpack round-trip --

@pytest.mark.parametrize("K,N", [(256, 256), (200, 100), (512, 384),
                                 (128, 130)])
@pytest.mark.parametrize("sparsity", [0.0, 0.5, 0.9])
def test_pack_unpack_roundtrip(K, N, sparsity):
    rng = np.random.default_rng(0)
    w = rng.laplace(0, 0.02, (K, N)).astype(np.float32)
    mask = _tile_mask(rng, K, N, sparsity)
    packed = ops.pack_joint_sparse(w, mask)
    got = ops.unpack_joint_sparse(packed)
    q, scales = _dense_quant(w, mask)
    np.testing.assert_allclose(got, q.astype(np.float32) * scales,
                               rtol=0, atol=1e-7)


def test_pack_compacts_dead_tiles():
    rng = np.random.default_rng(1)
    K, N = 512, 256
    mask = np.zeros((K, N), np.int32)
    mask[:128] = 1                       # 1 of 4 K-blocks survives
    w = rng.normal(0, 0.02, (K, N)).astype(np.float32)
    packed = ops.pack_joint_sparse(w, mask)
    assert packed.w_blocks.shape[1] == 1             # MAXB == survivors
    assert packed.w_blocks.dtype == jnp.int8         # bit-level payload
    assert ops.joint_storage_bytes(packed) < 2 * K * N * (1 / 4)


if HAVE_HYPOTHESIS:
    @given(st.integers(min_value=1, max_value=40),
           st.integers(min_value=1, max_value=40),
           st.integers(min_value=0, max_value=2 ** 31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_pack_unpack_roundtrip_property(K, N, seed):
        rng = np.random.default_rng(seed)
        w = rng.laplace(0, 0.05, (K, N)).astype(np.float32)
        mask = (rng.random((K, N)) > 0.3).astype(np.int32)
        packed = ops.pack_joint_sparse(w, mask, bk=8, bn=8)
        got = ops.unpack_joint_sparse(packed)
        q, scales = _dense_quant(w, mask)
        np.testing.assert_allclose(got, q.astype(np.float32) * scales,
                                   rtol=0, atol=1e-7)


# ------------------------------------------- kernel vs dense reference ----

@pytest.mark.parametrize("M,K,N", [(128, 256, 256), (96, 200, 100),
                                   (1, 384, 130), (256, 512, 384)])
@pytest.mark.parametrize("sparsity", [0.0, 0.5, 0.9])
def test_joint_matches_dense_reference(M, K, N, sparsity):
    """The acceptance guarantee: on FTA-projected weights the joint kernel
    equals the dense reference to fp32 accumulation tolerance."""
    rng = np.random.default_rng(2)
    w = rng.laplace(0, 0.02, (K, N)).astype(np.float32)
    mask = _tile_mask(rng, K, N, sparsity)
    packed = ops.pack_joint_sparse(w, mask)
    x = jnp.asarray(rng.normal(0, 1, (M, K)), jnp.float32)
    got = np.asarray(ops.joint_dense(x, packed), np.float32)
    q, scales = _dense_quant(w, mask)
    want = np.asarray(ref.joint_sparse_matmul_ref(x, q, mask, scales),
                      np.float32)
    assert got.shape == (M, N)
    np.testing.assert_allclose(got, want, rtol=1e-5,
                               atol=1e-5 * max(np.abs(want).max(), 1.0))


def test_joint_bf16_activations():
    rng = np.random.default_rng(3)
    w = rng.laplace(0, 0.02, (256, 128)).astype(np.float32)
    packed = ops.pack_joint_sparse(w, _tile_mask(rng, 256, 128, 0.5))
    x = jnp.asarray(rng.normal(0, 1, (128, 256)), jnp.bfloat16)
    got = ops.joint_dense(x, packed)
    assert got.dtype == jnp.bfloat16
    want = ref.joint_packed_ref(x, packed)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=0.3)


def test_joint_3d_activations():
    rng = np.random.default_rng(4)
    w = rng.laplace(0, 0.02, (256, 128)).astype(np.float32)
    packed = ops.pack_joint_sparse(w, _tile_mask(rng, 256, 128, 0.5))
    x = jnp.asarray(rng.normal(0, 1, (2, 32, 256)), jnp.float32)
    got = ops.joint_dense(x, packed)
    assert got.shape == (2, 32, 128)
    flat = ops.joint_dense(x.reshape(64, 256), packed)
    np.testing.assert_array_equal(np.asarray(got).reshape(64, 128),
                                  np.asarray(flat))


# ------------------------------------------------- padded-slot guard ------

def test_padded_slots_contribute_exactly_zero():
    """Tiles with fewer than MAXB surviving blocks pad with idx=0 and a
    zero INT8 payload; whatever activation block the padded slot gathers,
    its contribution must be exactly 0."""
    rng = np.random.default_rng(5)
    bk = bn = bm = 128
    # column tile 0 keeps K-blocks {0, 1}; column tile 1 keeps only {1}
    # => MAXB = 2 and tile 1 slot 1 is a padded slot pointing at block 0.
    mask = np.zeros((2 * bk, 2 * bn), np.int32)
    mask[:, :bn] = 1
    mask[bk:, bn:] = 1
    w = rng.laplace(0, 0.02, mask.shape).astype(np.float32)
    packed = ops.pack_joint_sparse(w, mask)
    assert packed.w_blocks.shape[1] == 2
    assert int(packed.nblocks[1]) == 1
    assert int(packed.idx[1, 1]) == 0                  # padded slot
    assert not np.any(np.asarray(packed.w_blocks)[1, 1])  # zero payload

    # huge activations in K-block 0: any padded-slot leakage would blow up
    # the second output tile far beyond fp32 rounding of the true value.
    x = np.ones((bm, 2 * bk), np.float32)
    x[:, :bk] = 1e6
    got = np.asarray(joint_sparse_matmul(
        jnp.asarray(x), packed.w_blocks, packed.idx, packed.scales))
    want = x @ ops.unpack_joint_sparse(packed)
    # tolerance scaled to the 1e6-magnitude probe (fp32 accumulation
    # order differs between kernel and reference); real leakage would be
    # off by ~1e6 x weight scale, orders of magnitude beyond this.
    np.testing.assert_allclose(got, want, rtol=1e-5,
                               atol=1e-5 * np.abs(want).max())
    # the decisive guard: tile-1 columns depend ONLY on K-block 1, so
    # flipping the block-0 activations the padded slot gathers must leave
    # them BIT-IDENTICAL (0 payload x anything == exact fp32 zero).
    x2 = x.copy()
    x2[:, :bk] = -1e6
    got2 = np.asarray(joint_sparse_matmul(
        jnp.asarray(x2), packed.w_blocks, packed.idx, packed.scales))
    np.testing.assert_array_equal(got[:, bn:], got2[:, bn:])


# ------------------------------------------------- mode dispatch ----------

def test_kernel_mode_dispatch_through_layers():
    """cfg.dbpim_mode selects the kernel path through apply_mlp; every
    mode must reproduce its own reference semantics."""
    from repro.models.config import ModelConfig
    from repro.models.layers import apply_mlp, init_mlp, make_matmul
    from repro.sparsity.sparse_linear import (KERNEL_MODES,
                                              build_kernel_tables)

    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=256,
                      n_heads=4, n_kv_heads=4, d_ff=384, vocab_size=64,
                      dtype="float32", dbpim=True,
                      dbpim_value_sparsity=0.5)
    p = init_mlp(cfg, jax.random.PRNGKey(0), 256, 384)
    named = {k: np.asarray(v, np.float32) for k, v in p.items()}
    x = jnp.asarray(np.random.default_rng(6).normal(0, 1, (2, 64, 256)),
                    jnp.float32)
    dense = apply_mlp(p, x, cfg)
    for mode in KERNEL_MODES:
        mcfg = cfg.scaled(dbpim_mode=mode)
        tables = build_kernel_tables(named, mcfg)
        y = apply_mlp(p, x, mcfg, dense_fn=make_matmul(mcfg, tables))
        assert y.shape == dense.shape and y.dtype == dense.dtype
        if mode == "dense":
            np.testing.assert_array_equal(np.asarray(y), np.asarray(dense))
        else:                      # compressed: close but not identical
            assert float(jnp.max(jnp.abs(y - dense))) > 0.0
            assert np.isfinite(np.asarray(y, np.float32)).all()


def test_registry_selects_joint_mode():
    from repro.configs.registry import get_config
    cfg = get_config("tinyllama-1.1b", reduced=True, dbpim_mode="joint")
    assert cfg.dbpim and cfg.dbpim_mode == "joint"
    with pytest.raises(KeyError):
        get_config("tinyllama-1.1b", dbpim_mode="nope")


# ------------------------------------------------- cost accounting --------

def test_jaxpr_cost_charges_packed_traffic():
    """The roofline walker must charge the pallas_call its stored-bytes
    traffic and the CostEstimate FLOPs (2 flops per stored INT8 weight
    per activation row)."""
    from repro.runtime.jaxpr_cost import analyze
    rng = np.random.default_rng(7)
    w = rng.laplace(0, 0.02, (512, 256)).astype(np.float32)
    packed = ops.pack_joint_sparse(w, _tile_mask(rng, 512, 256, 0.5))
    x = jnp.zeros((128, 512), jnp.float32)
    cost = analyze(lambda x: ops.joint_dense(x, packed), x)
    stored = int(packed.w_blocks.size)
    assert cost["pallas_flops"] == 2 * 128 * stored
    assert cost["pallas_bytes"] >= stored              # payload charged...
    assert cost["pallas_bytes"] < stored + 4 * x.size + 4 * 128 * 256 + 4096
    assert cost["dot_flops"] >= cost["pallas_flops"]
