"""Stacked joint-sparse serving path: uniform-MAXB pack round-trip,
scan-stacked forward/decode vs the dense FTA reference on reduced
tinyllama (dense family) and mamba2 (SSM family), the ragged-batch
small-M decode tile, and the serving-graph/weight-traffic guarantees.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ops
from repro.kernels._compat import INTERPRET_ENV, default_interpret
from repro.models import decode_step, forward, init_cache, init_params
from repro.runtime.jaxpr_cost import analyze
from repro.sparsity.sparse_linear import (build_stacked_tables,
                                          reconstruct_stacked_params,
                                          strip_packed_projections)

ARCHS = ("tinyllama-1.1b", "mamba2-1.3b")


def _quant_ref(w, mask):
    """Independent dense recomputation of the pack's quantization step."""
    from repro.core import fta
    m = np.asarray(mask, np.int32)
    amax = np.abs(w * m).max(axis=0)
    scales = (amax / 127.0 + 1e-12).astype(np.float32)
    q = np.clip(np.round(w * m / scales), -127, 127).astype(np.int32)
    q, _ = fta.fta_quantize(q, m)
    return (np.asarray(q) * m).astype(np.float32) * scales.reshape(1, -1)


def _setup(arch, vs=0.5, dtype="float32", mode="joint"):
    cfg = get_config(arch, reduced=True, dbpim_mode=mode).scaled(
        dtype=dtype, dbpim_value_sparsity=vs)
    params = init_params(cfg, jax.random.PRNGKey(0))
    tables = build_stacked_tables(params, cfg, bk=32, bn=32)
    assert tables is not None
    return cfg, params, tables


# ------------------------------------------------ stacked pack layout -----

def test_stacked_pack_shares_maxb_and_zero_pads_short_layers():
    """Ragged per-layer masks: MAXB is the max survivor count over the
    whole stack; layers with fewer survivors pad with zero-payload slots
    (the exact-zero contribution the kernel guarantees)."""
    rng = np.random.default_rng(0)
    L, K, N, bk = 3, 128, 64, 32
    masks = np.ones((L, K, N), np.int32)
    masks[0, bk:] = 0                      # layer 0 keeps 1 of 4 K-blocks
    masks[1, 2 * bk:] = 0                  # layer 1 keeps 2
    ws = rng.laplace(0, 0.02, (L, K, N)).astype(np.float32)
    p = ops.pack_joint_sparse_stacked(ws, masks, bk=bk, bn=32)
    assert p.maxb == 4                     # layer 2 keeps all 4
    nb = np.asarray(p.nblocks)
    assert nb[0].max() == 1 and nb[1].max() == 2 and (nb[2] == 4).all()
    wb = np.asarray(p.w_blocks)
    for l in range(L):
        for n_t in range(wb.shape[1]):
            assert not wb[l, n_t, nb[l, n_t]:].any()   # padded slots zero
    # round-trip: each layer reproduces its own pruned/quantized dense ref
    dense = ops.unpack_joint_sparse_stacked(p)
    assert dense.shape == (L, K, N)
    for l in range(L):
        np.testing.assert_allclose(dense[l], _quant_ref(ws[l], masks[l]),
                                   rtol=0, atol=1e-7)


@pytest.mark.parametrize("K,N", [(256, 256), (200, 100)])
def test_stacked_balanced_prune_has_no_padded_slots(K, N):
    """Column-balanced pruning => every (layer, column) stores exactly
    MAXB real blocks: the stacked layout carries zero padding and stored
    bytes scale with (1 - vs) exactly."""
    rng = np.random.default_rng(1)
    ws = rng.laplace(0, 0.02, (4, K, N)).astype(np.float32)
    p = ops.pack_joint_sparse_stacked(ws, value_sparsity=0.5, bk=32, bn=32)
    nb = np.asarray(p.nblocks)
    assert (nb == p.maxb).all()
    kt = p.k_pad // 32
    assert p.maxb == kt - int(round(0.5 * kt))
    assert p.w_blocks.shape[2] == p.maxb


def test_stacked_pack_rejects_bad_shapes():
    with pytest.raises(ValueError):
        ops.pack_joint_sparse_stacked(np.zeros((4, 4)), value_sparsity=0.5)


# ------------------------------------- forward / decode vs reference ------

@pytest.mark.parametrize("arch", ARCHS)
def test_stacked_forward_matches_dense_fta_reference(arch):
    """The acceptance guarantee: the scan-stacked joint forward equals a
    plain forward over the FTA-reconstructed (pruned + dequantized)
    weights to fp32 tolerance — for the dense and SSM families."""
    cfg, params, tables = _setup(arch)
    recon = reconstruct_stacked_params(params, tables, cfg)
    toks = jnp.asarray(np.random.default_rng(2).integers(
        1, cfg.vocab_size, (2, 32)), jnp.int32)
    got = forward(params, toks, cfg, tables=tables)
    want = forward(recon, toks, cfg)
    assert got.shape == want.shape
    tol = 1e-4 * max(float(jnp.max(jnp.abs(want))), 1.0)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)
    # and the compressed path is genuinely different from uncompressed
    assert float(jnp.max(jnp.abs(want - forward(params, toks, cfg)))) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_ragged_batch_decode_step_matches_reference(arch):
    """Batch-4 decode (ragged M, far below the 128 MXU row tile) through
    the stacked tables: logits and caches match the FTA reference."""
    cfg, params, tables = _setup(arch)
    recon = reconstruct_stacked_params(params, tables, cfg)
    cache = init_cache(cfg, 4, 16)
    tok = jnp.asarray([[3], [5], [7], [11]], jnp.int32)
    got, cache_j = decode_step(params, cache, tok, cfg, tables=tables)
    want, cache_r = decode_step(recon, cache, tok, cfg)
    tol = 1e-4 * max(float(jnp.max(jnp.abs(want))), 1.0)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)
    # serving drops the dense projection copies: placeholders + tables
    # must produce bit-identical logits (mm never reads the weight arg)
    stripped = strip_packed_projections(params, cfg)
    sbytes = sum(l.size * l.dtype.itemsize
                 for l in jax.tree_util.tree_leaves(stripped))
    pbytes = sum(l.size * l.dtype.itemsize
                 for l in jax.tree_util.tree_leaves(params))
    assert sbytes < pbytes
    got_s, _ = decode_step(stripped, cache, tok, cfg, tables=tables)
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(got))
    for leaf_j, leaf_r in zip(jax.tree_util.tree_leaves(cache_j),
                              jax.tree_util.tree_leaves(cache_r)):
        np.testing.assert_allclose(
            np.asarray(leaf_j, np.float32), np.asarray(leaf_r, np.float32),
            atol=1e-4 * max(float(np.abs(np.asarray(leaf_r)).max()), 1.0))


def test_small_m_row_tile_selection():
    """The decode-tuned tile: small batches pad to the sublane minimum
    (8 f32 / 16 bf16), not to 128 MXU rows; large M keeps full tiles."""
    assert ops.pick_row_tile(4, jnp.float32) == 8
    assert ops.pick_row_tile(4, jnp.bfloat16) == 16
    assert ops.pick_row_tile(8, jnp.float32) == 8
    assert ops.pick_row_tile(100, jnp.float32) == 104
    assert ops.pick_row_tile(128, jnp.float32) == 128
    assert ops.pick_row_tile(1000, jnp.bfloat16) == 128
    # correctness at M=4 (internally padded to one 8-row tile)
    rng = np.random.default_rng(3)
    w = rng.laplace(0, 0.02, (64, 96)).astype(np.float32)
    packed = ops.pack_joint_sparse(w, value_sparsity=0.5, bk=32, bn=32)
    x = jnp.asarray(rng.normal(0, 1, (4, 64)), jnp.float32)
    got = ops.joint_dense(x, packed)
    want = x @ jnp.asarray(ops.unpack_joint_sparse(packed))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ----------------------------------------------- value-only (bf16) --------

@pytest.mark.parametrize("arch", ARCHS)
def test_value_mode_packs_bf16_payload_and_serves(arch):
    """dbpim_mode="value" builds bf16-PAYLOAD stacked tables (compacted
    blocks hold the raw pruned weights, unit scales — value level only,
    no bit-level grid) and serves forward + decode through the scan to
    the same tolerance contract as joint."""
    cfg, params, tables = _setup(arch, mode="value")
    for t in tables.arrays.values():
        assert t["w_blocks"].dtype == jnp.bfloat16
        assert np.asarray(t["scales"] == 1.0).all()
    recon = reconstruct_stacked_params(params, tables, cfg)
    toks = jnp.asarray(np.random.default_rng(6).integers(
        1, cfg.vocab_size, (2, 16)), jnp.int32)
    got = forward(params, toks, cfg, tables=tables)
    want = forward(recon, toks, cfg)
    tol = 1e-4 * max(float(jnp.max(jnp.abs(want))), 1.0)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)
    cache = init_cache(cfg, 2, 8)
    tok = jnp.asarray([[3], [5]], jnp.int32)
    gl, _ = decode_step(params, cache, tok, cfg, tables=tables)
    wl, _ = decode_step(recon, cache, tok, cfg)
    np.testing.assert_allclose(
        np.asarray(gl, np.float32), np.asarray(wl, np.float32),
        atol=1e-4 * max(float(jnp.max(jnp.abs(wl))), 1.0))


def test_value_mode_payload_is_unquantized_and_halves_traffic_vs_dense():
    """The value payload is the PRUNED weights themselves (bf16 cast, not
    the INT8 grid), and at 0.5 value sparsity the decode weight traffic
    lands strictly between joint (x0.25 on eligible bytes) and dense."""
    cfg, params, tables = _setup("tinyllama-1.1b", mode="value")
    # unpacked value tables == bf16(weights) * mask, NOT a 127-level grid
    name, t = next(iter(tables.arrays.items()))
    k, n, k_pad = tables.static[name]
    packed = ops.JointPackedStacked(t["w_blocks"], t["idx"], t["scales"],
                                    t["nblocks"], k, n, k_pad)
    dense = ops.unpack_joint_sparse_stacked(packed)
    kept = dense[dense != 0]
    w0 = np.asarray(params["blocks"]["attn"][name]
                    if name in ("wq", "wk", "wv", "wo")
                    else params["blocks"]["mlp"][name], np.float32)
    bf16_vals = np.asarray(jnp.asarray(w0, jnp.bfloat16), np.float32)
    assert np.isin(kept, bf16_vals).all()

    cache = init_cache(cfg, 4, 16)
    tok = jnp.ones((4, 1), jnp.int32)
    dense_wb = analyze(lambda p, c, t_: decode_step(p, c, t_, cfg),
                       params, cache, tok)["weight_bytes"]
    value_wb = analyze(
        lambda p, c, t_: decode_step(p, c, t_, cfg, tables=tables),
        params, cache, tok)["weight_bytes"]
    _, _, joint_tables = _setup("tinyllama-1.1b", mode="joint")
    joint_wb = analyze(
        lambda p, c, t_: decode_step(p, c, t_, cfg, tables=joint_tables),
        params, cache, tok)["weight_bytes"]
    assert joint_wb < value_wb < dense_wb


# ----------------------------------------- serving graph + traffic --------

def test_joint_mode_changes_compiled_serving_graph():
    """dbpim_mode="joint" must change the decode-step HLO: the joint
    pallas kernel appears in the jaxpr, and weight bytes per decode step
    drop to <= 0.55x dense at 0.5 value sparsity (the (1 - vs) * 0.5
    contract plus index/scale overhead and the mode-independent
    unembedding)."""
    cfg, params, tables = _setup("tinyllama-1.1b")
    cache = init_cache(cfg, 4, 16)
    tok = jnp.ones((4, 1), jnp.int32)

    dense_jaxpr = str(jax.make_jaxpr(
        lambda p, c, t: decode_step(p, c, t, cfg))(params, cache, tok))
    joint_jaxpr = str(jax.make_jaxpr(
        lambda p, c, t: decode_step(p, c, t, cfg, tables=tables))(
            params, cache, tok))
    assert "pallas_call" not in dense_jaxpr
    assert "pallas_call" in joint_jaxpr

    dense_cost = analyze(lambda p, c, t: decode_step(p, c, t, cfg),
                         params, cache, tok)
    joint_cost = analyze(
        lambda p, c, t: decode_step(p, c, t, cfg, tables=tables),
        params, cache, tok)
    assert dense_cost["weight_bytes"] > 0
    ratio = joint_cost["weight_bytes"] / dense_cost["weight_bytes"]
    assert ratio <= 0.55, f"joint/dense weight traffic {ratio:.3f} > 0.55"


def test_mismatched_tables_raise_instead_of_misserving():
    """Every family packs now (segmented per-kind scans), so the guard
    moved: tables packed for one segment layout must be rejected by a
    model with a different one — a single-"blocks" tinyllama pack handed
    to jamba's seg00..seg03 stack, or a pre-segmentation raw
    StackedKernelTables object, would otherwise die as a cryptic scan
    shape error deep inside the kernel."""
    cfg = get_config("jamba-v0.1-52b", reduced=True, dbpim_mode="joint")
    params = init_params(cfg, jax.random.PRNGKey(0))
    jt = build_stacked_tables(params, cfg)
    assert jt is not None and set(jt.segments) == \
        {"seg00", "seg01", "seg02", "seg03"}
    cfg_t, params_t, tables = _setup("tinyllama-1.1b")
    with pytest.raises(ValueError, match="segment layout"):
        decode_step(params, init_cache(cfg, 1, 8),
                    jnp.ones((1, 1), jnp.int32), cfg, tables=tables)
    with pytest.raises(ValueError, match="segment layout"):
        forward(params, jnp.ones((1, 8), jnp.int32), cfg, tables=tables)
    # a bare per-segment pack (no .segments) is not servable either
    with pytest.raises(ValueError, match="segmented pack"):
        forward(params_t, jnp.ones((1, 8), jnp.int32), cfg_t,
                tables=tables.segments["blocks"])


def test_serve_step_rejects_conflicting_weight_formats():
    from repro.launch.mesh import make_test_mesh
    from repro.launch.steps import build_serve_step
    cfg, params, tables = _setup("tinyllama-1.1b")
    with pytest.raises(ValueError):
        build_serve_step(cfg, make_test_mesh(), int8_weights=True,
                         stacked_tables=tables)


# ------------------------------------------------ interpret default -------

def test_backend_aware_interpret_default(monkeypatch):
    monkeypatch.delenv(INTERPRET_ENV, raising=False)
    # this suite runs on CPU: the default must be interpret, not compile
    assert default_interpret() is (jax.default_backend() != "tpu")
    monkeypatch.setenv(INTERPRET_ENV, "0")
    assert default_interpret() is False
    monkeypatch.setenv(INTERPRET_ENV, "true")
    assert default_interpret() is True
    monkeypatch.setenv(INTERPRET_ENV, "bogus")
    with pytest.raises(ValueError):
        default_interpret()
