"""Serving engine: chunked cache-filling prefill (bit-identical to
stepwise decode), slot scheduler invariants under randomized traces,
stale-cache zeroing on slot refill, and the thin serve CLI."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import decode_chunk, init_cache, init_params
from repro.serving import (Request, ServeEngine, WorkloadSpec, assemble_chunk,
                           make_trace)
from repro.sparsity.sparse_linear import build_stacked_tables

ARCHS = ("tinyllama-1.1b", "mamba2-1.3b")


def _cfg(arch, dtype="float32", mode=None, **kw):
    cfg = get_config(arch, reduced=True, dbpim_mode=mode)
    return cfg.scaled(dtype=dtype, dbpim_value_sparsity=0.5, **kw)


def _exact(cfg):
    """BITWISE chunk==stepwise tests pin the exact per-token recurrence:
    the SSM default is the parallel SSD form, which is tolerance-equal
    only (tests/test_parallel_prefill.py owns that contract)."""
    return cfg.scaled(prefill_exact=True) if cfg.family == "ssm" else cfg


from conftest import chunked_prefill as _chunked
from conftest import stepwise_prefill as _stepwise


# ------------------------------------------------- chunked == stepwise ----

@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("plen", [3, 5, 8])      # 5, 3: NOT chunk multiples
def test_chunked_prefill_bit_identical_to_stepwise(arch, plen):
    """The acceptance guarantee: a chunked prefill (chunk=4, ragged tail)
    produces BIT-IDENTICAL caches and first-token logits to feeding the
    prompt through sequential decode steps — transformer and SSM (on the
    exact-recurrence path; the parallel SSD default is tolerance-equal
    and tested in test_parallel_prefill.py)."""
    cfg = _exact(_cfg(arch))
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = np.random.default_rng(1).integers(
        1, cfg.vocab_size, (3, plen)).astype(np.int32)
    ls, cs = _stepwise(params, cfg, prompts, 16)
    lc, cc = _chunked(params, cfg, prompts, 16, chunk=4)
    np.testing.assert_array_equal(np.asarray(ls), np.asarray(lc))
    for a, b in zip(jax.tree_util.tree_leaves(cs),
                    jax.tree_util.tree_leaves(cc)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("arch", ARCHS)
def test_chunked_prefill_bit_identical_through_joint_tables(arch):
    """Same guarantee with the stacked joint-sparse tables threaded
    through both paths (prompt chunks run the DB-PIM kernel too)."""
    cfg = _exact(_cfg(arch, mode="joint"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    tables = build_stacked_tables(params, cfg, bk=32, bn=32)
    assert tables is not None
    prompts = np.random.default_rng(2).integers(
        1, cfg.vocab_size, (2, 7)).astype(np.int32)
    ls, cs = _stepwise(params, cfg, prompts, 16, tables=tables)
    lc, cc = _chunked(params, cfg, prompts, 16, chunk=4, tables=tables)
    np.testing.assert_array_equal(np.asarray(ls), np.asarray(lc))
    for a, b in zip(jax.tree_util.tree_leaves(cs),
                    jax.tree_util.tree_leaves(cc)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("arch", ARCHS)
def test_chunk_with_zero_valid_leaves_cache_untouched(arch):
    """Slots with n_valid=0 (idle while neighbors prefill) must come out
    of a chunk step with their cache slices and position unchanged."""
    cfg = _cfg(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = np.random.default_rng(3).integers(
        1, cfg.vocab_size, (2, 4)).astype(np.int32)
    _, cache = _stepwise(params, cfg, prompts, 16)          # both slots at 4
    toks = np.zeros((2, 4), np.int32)
    toks[0] = prompts[0]
    _, cache2 = decode_chunk(params, cache, jnp.asarray(toks),
                             jnp.asarray([4, 0], jnp.int32), cfg)
    assert int(cache2["pos"][0]) == 8 and int(cache2["pos"][1]) == 4
    # slot 1's slices (batch axis 1 in both cache families) are untouched
    sub = cache.get("attn") or cache["ssm"]
    sub2 = cache2.get("attn") or cache2["ssm"]
    for key in sub:
        a, b = np.asarray(sub[key]), np.asarray(sub2[key])
        if a.ndim >= 2:
            np.testing.assert_array_equal(a[:, 1], b[:, 1])


def test_chunked_prefill_rejects_unsupported_families():
    cfg = get_config("mixtral-8x7b", reduced=True)          # MoE + window
    assert not cfg.supports_chunked_prefill
    params = init_params(cfg, jax.random.PRNGKey(0))
    cache = init_cache(cfg, 1, 8)
    with pytest.raises(ValueError):
        decode_chunk(params, cache, jnp.ones((1, 4), jnp.int32),
                     jnp.asarray([4], jnp.int32), cfg)


# ------------------------------------------------------ engine behaviour --

def test_engine_chunked_and_full_modes_generate_identically():
    """Prefill policy changes the schedule, never the tokens."""
    cfg = _cfg("tinyllama-1.1b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    trace = make_trace(WorkloadSpec(n_requests=5, arrival_rate=1.0,
                                    prompt_len=(2, 10), gen_len=(2, 5),
                                    seed=4), cfg.vocab_size)
    outs = {}
    for mode in ("chunked", "full"):
        eng = ServeEngine(cfg, params, n_slots=2, max_len=24,
                          prefill_chunk=4, prefill_mode=mode)
        outs[mode] = eng.run(trace)
        s = eng.metrics.summary()
        assert s["n_completed"] == 5
    assert outs["chunked"] == outs["full"]


def test_engine_scheduler_invariants_random_trace():
    """Randomized arrivals: every admitted request completes with exactly
    gen_len tokens, each request is admitted exactly once, and no slot
    ever hosts two requests at the same time."""
    cfg = _cfg("tinyllama-1.1b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    trace = make_trace(WorkloadSpec(n_requests=10, arrival_rate=2.0,
                                    prompt_len=(1, 9), gen_len=(1, 6),
                                    dist="uniform", seed=11),
                       cfg.vocab_size)
    eng = ServeEngine(cfg, params, n_slots=3, max_len=16, prefill_chunk=4)
    outputs = eng.run(trace)

    assert sorted(outputs) == [r.rid for r in trace]        # all complete
    for r in trace:
        assert len(outputs[r.rid]) == r.gen_len
    admits = [iv.rid for iv in eng.slot_log]
    assert sorted(admits) == sorted(r.rid for r in trace)   # exactly once
    by_slot = {}
    for iv in eng.slot_log:
        assert iv.release_tick is not None
        by_slot.setdefault(iv.slot, []).append(iv)
    for ivs in by_slot.values():
        ivs.sort(key=lambda iv: iv.admit_tick)
        for a, b in zip(ivs, ivs[1:]):
            assert a.release_tick <= b.admit_tick           # no overlap
    # queue depth was recorded and drains to zero
    assert eng.metrics.ticks[-1].queue_depth == 0


@pytest.mark.parametrize("arch", ARCHS)
def test_refilled_slot_matches_fresh_batch(arch):
    """The stale-cache regression: a request served by a REUSED slot
    (previous occupant's KV/SSM state must be zeroed at admission) gets
    bit-identical first-token logits and tokens to the same request
    served by a fresh engine. SSM states have no causal mask — without
    the zeroing, mamba2 fails this."""
    cfg = _cfg(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    reqs = [Request(rid=i, prompt=tuple(
        int(t) for t in rng.integers(1, cfg.vocab_size, 6)),
        gen_len=4, arrival=0.0) for i in range(2)]

    shared = ServeEngine(cfg, params, n_slots=1, max_len=16,
                         prefill_chunk=4)
    out_shared = shared.run(reqs)
    assert len(shared.slot_log) == 2 and \
        {iv.slot for iv in shared.slot_log} == {0}          # slot reused

    fresh = ServeEngine(cfg, params, n_slots=1, max_len=16,
                        prefill_chunk=4)
    out_fresh = fresh.run([reqs[1]])
    assert out_shared[1] == out_fresh[1]
    np.testing.assert_array_equal(
        np.asarray(shared.first_logits[1], np.float32),
        np.asarray(fresh.first_logits[1], np.float32))


def test_spf_scheduler_invariants_random_trace():
    """SPF admission keeps every scheduler invariant FIFO holds: all
    requests complete with exactly gen_len tokens, one admission each, no
    slot overlap — and the queue-jump count never exceeds the age cap."""
    cfg = _cfg("tinyllama-1.1b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    trace = make_trace(WorkloadSpec(n_requests=10, arrival_rate=2.0,
                                    prompt_len=(1, 9), gen_len=(1, 6),
                                    dist="bimodal", seed=11),
                       cfg.vocab_size)
    eng = ServeEngine(cfg, params, n_slots=2, max_len=16, prefill_chunk=4,
                      schedule="spf", spf_age_cap=3)
    outputs = eng.run(trace)
    assert sorted(outputs) == [r.rid for r in trace]
    for r in trace:
        assert len(outputs[r.rid]) == r.gen_len
    admits = [iv.rid for iv in eng.slot_log]
    assert sorted(admits) == sorted(r.rid for r in trace)
    by_slot = {}
    for iv in eng.slot_log:
        assert iv.release_tick is not None
        by_slot.setdefault(iv.slot, []).append(iv)
    for ivs in by_slot.values():
        ivs.sort(key=lambda iv: iv.admit_tick)
        for a, b in zip(ivs, ivs[1:]):
            assert a.release_tick <= b.admit_tick
    # skip entries die at admission (bounded scheduler state); the final
    # counts land in per-request metrics and respect the age cap
    assert eng.skips == {}
    assert max(r.skips for r in eng.metrics.requests.values()) <= 3


def test_spf_no_starvation_under_short_prompt_stream():
    """The starvation bound: a long prompt that keeps being queue-jumped
    by later-arriving short prompts becomes urgent after spf_age_cap
    jumps and is admitted ahead of the remaining shorts — it can never
    be deferred indefinitely."""
    cfg = _cfg("tinyllama-1.1b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(6)
    cap = 2
    # rid0 occupies the single slot at t=0, so the long prompt (rid1,
    # also t=0) must QUEUE while later shorts keep arriving — each
    # admission that picks a later-arriving short over it is one jump
    blocker = Request(rid=0, prompt=tuple(
        int(t) for t in rng.integers(1, cfg.vocab_size, 2)),
        gen_len=2, arrival=0.0)
    long_req = Request(rid=1, prompt=tuple(
        int(t) for t in rng.integers(1, cfg.vocab_size, 10)),
        gen_len=2, arrival=0.0)
    shorts = [Request(rid=i, prompt=tuple(
        int(t) for t in rng.integers(1, cfg.vocab_size, 2)),
        gen_len=2, arrival=float(i - 1)) for i in range(2, 9)]
    eng = ServeEngine(cfg, params, n_slots=1, max_len=16, prefill_chunk=4,
                      schedule="spf", spf_age_cap=cap)
    outputs = eng.run([blocker, long_req] + shorts)
    assert sorted(outputs) == list(range(9))             # all complete
    assert eng.metrics.requests[1].skips == cap          # jumped cap times
    # urgent after `cap` jumps: only the blocker plus at most `cap`
    # shorts ran before the long prompt — it is never deferred past that
    order = [iv.rid for iv in sorted(eng.slot_log,
                                     key=lambda iv: iv.admit_tick)]
    assert order.index(1) <= cap + 1


def test_spf_no_starvation_simultaneous_arrivals():
    """The closed-loop batch corner (arrival_rate=0: every request at
    t=0): skip counts must still rise on every shortest-first pass-over,
    so a long prompt in an all-at-once batch is admitted after at most
    spf_age_cap shorter requests, never last-by-default."""
    cfg = _cfg("tinyllama-1.1b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(8)
    cap = 2
    reqs = [Request(rid=0, prompt=tuple(
        int(t) for t in rng.integers(1, cfg.vocab_size, 10)),
        gen_len=2, arrival=0.0)]
    reqs += [Request(rid=i, prompt=tuple(
        int(t) for t in rng.integers(1, cfg.vocab_size, 2)),
        gen_len=2, arrival=0.0) for i in range(1, 6)]
    eng = ServeEngine(cfg, params, n_slots=1, max_len=16, prefill_chunk=4,
                      schedule="spf", spf_age_cap=cap)
    outputs = eng.run(reqs)
    assert sorted(outputs) == list(range(6))
    assert max(r.skips for r in eng.metrics.requests.values()) <= cap
    order = [iv.rid for iv in sorted(eng.slot_log,
                                     key=lambda iv: iv.admit_tick)]
    assert order.index(0) <= cap              # urgent after cap pass-overs


def test_spf_fifo_equal_results_same_trace():
    """Scheduling changes ADMISSION ORDER only: the token streams per
    request are identical under fifo and spf (each request's math is
    independent of when its slot was granted)."""
    cfg = _cfg("tinyllama-1.1b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    trace = make_trace(WorkloadSpec(n_requests=6, arrival_rate=1.5,
                                    prompt_len=(2, 12), gen_len=(2, 4),
                                    dist="bimodal", seed=9),
                       cfg.vocab_size)
    outs = {}
    for schedule in ("fifo", "spf"):
        eng = ServeEngine(cfg, params, n_slots=2, max_len=24,
                          prefill_chunk=4, schedule=schedule)
        outs[schedule] = eng.run(trace)
    assert outs["fifo"] == outs["spf"]


def test_engine_rejects_bad_schedule():
    cfg = _cfg("tinyllama-1.1b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        ServeEngine(cfg, params, n_slots=1, max_len=8, schedule="lifo")


def test_engine_rejects_oversized_requests():
    """Default: an oversized request is a RECORDED rejection (one
    malformed request must not abort a trace); strict=True restores the
    hard raise. tests/test_fault_tolerance.py covers the recorded-
    rejection path end-to-end."""
    cfg = _cfg("tinyllama-1.1b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, n_slots=1, max_len=8, prefill_chunk=4)
    assert eng.submit(Request(rid=0, prompt=(1,) * 6, gen_len=4)) is False
    assert eng.rejected[0] == "oversized"
    strict = ServeEngine(cfg, params, n_slots=1, max_len=8,
                         prefill_chunk=4, strict=True)
    with pytest.raises(ValueError):
        strict.submit(Request(rid=0, prompt=(1,) * 6, gen_len=4))


def test_assemble_chunk_ragged():
    prompts = {0: np.arange(1, 6, dtype=np.int32),       # 5 tokens
               2: np.arange(10, 13, dtype=np.int32)}     # 3 tokens
    tokens, n_valid = assemble_chunk(prompts, {0: 4, 2: 0}, 3, 4)
    assert tokens.shape == (3, 4) and n_valid.tolist() == [1, 0, 3]
    assert tokens[0, 0] == 5 and tokens[2, :3].tolist() == [10, 11, 12]
    assert not tokens[1].any()


# ------------------------------------------------------------- serve CLI --

def test_serve_cli_drives_engine(capsys):
    from repro.launch.serve import main
    out = main(["--arch", "tinyllama-1.1b", "--reduced", "--batch", "2",
                "--max-len", "16", "--requests", "3", "--gen-len", "3",
                "--prompt-len", "2", "6", "--prefill-chunk", "4",
                "--dbpim-mode", "joint"])
    assert len(out) == 3 and all(len(v) == 3 for v in out.values())
    assert "tokens/step" in capsys.readouterr().out
