"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + finiteness; one decode step against a small cache."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import (SHAPES, decode_step, forward, init_cache,
                          init_params, loss_fn, param_count)
from repro.models.inputs import make_decode_token, make_train_batch
from repro.models.transformer import encode

ARCHS = list_archs()
B, S = 2, 32


@pytest.fixture(scope="module")
def arch_state():
    cache = {}
    def get(arch):
        if arch not in cache:
            cfg = get_config(arch, reduced=True)
            params = init_params(cfg, jax.random.PRNGKey(0))
            cache[arch] = (cfg, params)
        return cache[arch]
    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch, arch_state):
    cfg, params = arch_state(arch)
    batch = make_train_batch(cfg, B, S)
    loss = loss_fn(params, batch, cfg)
    assert loss.shape == ()
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_grads(arch, arch_state):
    cfg, params = arch_state(arch)
    batch = make_train_batch(cfg, B, S)
    loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg)
    assert np.isfinite(float(loss))
    flat, _ = jax.tree_util.tree_flatten(grads)
    assert all(np.all(np.isfinite(np.asarray(g, dtype=np.float32)))
               for g in flat)
    # at least one non-zero gradient tensor
    assert any(float(jnp.max(jnp.abs(g.astype(jnp.float32)))) > 0
               for g in flat)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch, arch_state):
    cfg, params = arch_state(arch)
    enc_out = None
    if cfg.is_encdec:
        frames = make_train_batch(cfg, B, S)["frames"]
        enc_out = encode(params, frames, cfg)
    cache = init_cache(cfg, B, max_len=64, enc_out=enc_out)
    token = make_decode_token(cfg, B)
    logits, cache = decode_step(params, cache, token, cfg)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, dtype=np.float32)))
    logits2, cache = decode_step(params, cache, token, cfg)
    assert int(cache["pos"]) == 2
    assert np.all(np.isfinite(np.asarray(logits2, dtype=np.float32)))


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_analytic_close(arch, arch_state):
    cfg, params = arch_state(arch)
    actual = sum(int(np.prod(x.shape))
                 for x in jax.tree_util.tree_leaves(params))
    approx = param_count(cfg)
    assert abs(actual - approx) / actual < 0.15, (actual, approx)


def test_full_config_param_counts():
    """Full (non-reduced) configs land near their advertised sizes."""
    targets = {"qwen3-8b": (8e9, 0.3), "tinyllama-1.1b": (1.1e9, 0.25),
               "gemma-7b": (8.5e9, 0.3), "mixtral-8x7b": (46e9, 0.15),
               "arctic-480b": (480e9, 0.15), "mamba2-1.3b": (1.3e9, 0.3),
               "jamba-v0.1-52b": (52e9, 0.25), "pixtral-12b": (12e9, 0.25)}
    for arch, (target, tol) in targets.items():
        n = param_count(get_config(arch))
        assert abs(n - target) / target < tol, (arch, n, target)
