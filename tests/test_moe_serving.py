"""Grouped (layer x expert) joint-sparse serving for MoE, the fixed
rank-3 expert-weight accounting in the jaxpr cost walker, and the
capacity clamp for tiny decode batches.

Mirrors tests/test_stacked_serving.py for the grouped pack: round-trip
identity per (layer, expert) slice, padded-slot-zero guard, forward /
decode vs the dense FTA reference, serving-graph + weight-traffic
guarantees — on reduced mixtral (plain MoE) and arctic (MoE + dense
residual MLP).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ops
from repro.models import decode_step, forward, init_cache, init_params
from repro.models import moe as moe_mod
from repro.runtime.jaxpr_cost import analyze
from repro.sparsity.sparse_linear import (build_stacked_tables,
                                          reconstruct_stacked_params,
                                          strip_packed_projections)

ARCH = "mixtral-8x7b"


def _quant_ref(w, mask):
    """Independent dense recomputation of the pack's quantization step."""
    from repro.core import fta
    m = np.asarray(mask, np.int32)
    amax = np.abs(w * m).max(axis=0)
    scales = (amax / 127.0 + 1e-12).astype(np.float32)
    q = np.clip(np.round(w * m / scales), -127, 127).astype(np.int32)
    q, _ = fta.fta_quantize(q, m)
    return (np.asarray(q) * m).astype(np.float32) * scales.reshape(1, -1)


def _setup(arch=ARCH, vs=0.5, dtype="float32", mode="joint"):
    cfg = get_config(arch, reduced=True, dbpim_mode=mode).scaled(
        dtype=dtype, dbpim_value_sparsity=vs)
    params = init_params(cfg, jax.random.PRNGKey(0))
    tables = build_stacked_tables(params, cfg, bk=32, bn=32)
    assert tables is not None
    return cfg, params, tables


# ------------------------------------------------- grouped pack layout ----

@pytest.mark.parametrize("K,N", [(256, 256), (200, 100)])
@pytest.mark.parametrize("vs", [0.0, 0.5])
def test_grouped_pack_roundtrip_per_expert(K, N, vs):
    """pack -> unpack reproduces each (layer, expert) slice's pruned +
    quantized dense reference bitwise, across value sparsities and odd
    (ragged-tile) shapes."""
    rng = np.random.default_rng(0)
    L, E, bk, bn = 2, 3, 32, 32
    ws = rng.laplace(0, 0.02, (L, E, K, N)).astype(np.float32)
    p = ops.pack_joint_sparse_grouped(ws, value_sparsity=vs or None,
                                      bk=bk, bn=bn)
    dense = ops.unpack_joint_sparse_grouped(p)
    assert dense.shape == (L, E, K, N)
    for l in range(L):
        for e in range(E):
            mask = (ops.tile_prune_mask_balanced(ws[l, e], vs, bk, bn)
                    if vs else np.ones((K, N), np.int32))
            np.testing.assert_array_equal(dense[l, e],
                                          _quant_ref(ws[l, e], mask))
    if vs:
        # balanced pruning: one shared MAXB, zero padded slots group-wide
        nb = np.asarray(p.nblocks)
        assert (nb == p.maxb).all()


def test_grouped_pack_shares_maxb_and_zero_pads_short_members():
    """Ragged explicit masks: MAXB is the max survivor count over every
    (layer, expert) pair; short members pad with zero-payload slots."""
    rng = np.random.default_rng(1)
    L, E, K, N, bk = 2, 2, 128, 64, 32
    masks = np.ones((L, E, K, N), np.int32)
    masks[0, 0, bk:] = 0                  # (0,0) keeps 1 of 4 K-blocks
    masks[0, 1, 2 * bk:] = 0              # (0,1) keeps 2
    ws = rng.laplace(0, 0.02, (L, E, K, N)).astype(np.float32)
    p = ops.pack_joint_sparse_grouped(ws, masks, bk=bk, bn=32)
    assert p.maxb == 4                    # layer 1 keeps all 4
    nb = np.asarray(p.nblocks)
    assert nb[0, 0].max() == 1 and nb[0, 1].max() == 2
    assert (nb[1] == 4).all()
    wb = np.asarray(p.w_blocks)
    for l in range(L):
        for e in range(E):
            for n_t in range(wb.shape[2]):
                assert not wb[l, e, n_t, nb[l, e, n_t]:].any()
    dense = ops.unpack_joint_sparse_grouped(p)
    for l in range(L):
        for e in range(E):
            np.testing.assert_array_equal(dense[l, e],
                                          _quant_ref(ws[l, e],
                                                     masks[l, e]))


def test_grouped_pack_rejects_bad_shapes():
    with pytest.raises(ValueError):
        ops.pack_joint_sparse_grouped(np.zeros((2, 4, 4)),
                                      value_sparsity=0.5)


# ------------------------------------------------------- family gates -----

def test_moe_family_gates():
    """Segmented per-kind scans closed the family matrix: every family
    packs stacked tables (jamba included), and chunked prefill gates only
    on sliding windows — arctic (no window, per-position capacity
    dispatch) chunks; mixtral's reduced config keeps window=32 and stays
    stepwise (ring-buffer writes need the sequential walk)."""
    mixtral = get_config("mixtral-8x7b", reduced=True)
    arctic = get_config("arctic-480b", reduced=True)
    jamba = get_config("jamba-v0.1-52b", reduced=True)
    assert mixtral.supports_stacked_tables
    assert arctic.supports_stacked_tables
    assert jamba.supports_stacked_tables
    assert not mixtral.supports_chunked_prefill
    assert arctic.supports_chunked_prefill
    assert jamba.supports_chunked_prefill


# ------------------------------------- forward / decode vs reference ------

@pytest.mark.parametrize("arch", ["mixtral-8x7b", "arctic-480b"])
def test_moe_stacked_forward_matches_dense_fta_reference(arch):
    """The scan-stacked joint forward (grouped expert dispatch + packed
    attention, and arctic's packed dense residual MLP) equals a plain
    forward over the FTA-reconstructed weights to fp32 tolerance."""
    cfg, params, tables = _setup(arch)
    recon = reconstruct_stacked_params(params, tables, cfg)
    toks = jnp.asarray(np.random.default_rng(2).integers(
        1, cfg.vocab_size, (2, 16)), jnp.int32)
    got = forward(params, toks, cfg, tables=tables)
    want = forward(recon, toks, cfg)
    assert got.shape == want.shape
    tol = 1e-4 * max(float(jnp.max(jnp.abs(want))), 1.0)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)
    # and the compressed path is genuinely different from uncompressed
    assert float(jnp.max(jnp.abs(want - forward(params, toks, cfg)))) > 0


def test_moe_ragged_decode_step_matches_reference():
    """Batch-4 decode through grouped tables: logits + caches match the
    FTA reference, and the stripped-params serving configuration (dense
    copies replaced by placeholders) is bitwise identical."""
    cfg, params, tables = _setup()
    recon = reconstruct_stacked_params(params, tables, cfg)
    cache = init_cache(cfg, 4, 16)
    tok = jnp.asarray([[3], [5], [7], [11]], jnp.int32)
    got, cache_j = decode_step(params, cache, tok, cfg, tables=tables)
    want, cache_r = decode_step(recon, cache, tok, cfg)
    tol = 1e-4 * max(float(jnp.max(jnp.abs(want))), 1.0)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)
    stripped = strip_packed_projections(params, cfg)
    sbytes = sum(l.size * l.dtype.itemsize
                 for l in jax.tree_util.tree_leaves(stripped))
    pbytes = sum(l.size * l.dtype.itemsize
                 for l in jax.tree_util.tree_leaves(params))
    assert sbytes < pbytes
    got_s, _ = decode_step(stripped, cache, tok, cfg, tables=tables)
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(got))
    for leaf_j, leaf_r in zip(jax.tree_util.tree_leaves(cache_j),
                              jax.tree_util.tree_leaves(cache_r)):
        np.testing.assert_allclose(
            np.asarray(leaf_j, np.float32), np.asarray(leaf_r, np.float32),
            atol=1e-4 * max(float(np.abs(np.asarray(leaf_r)).max()), 1.0))


# ----------------------------------------- serving graph + traffic --------

def test_moe_joint_mode_changes_compiled_serving_graph():
    """The acceptance bar: dbpim_mode="joint" on the MoE smoke arch puts
    pallas_call into the decode jaxpr (expert projections run the DB-PIM
    kernel) and drops weight bytes to <= 0.55x dense — measured with the
    fixed accounting, whose dense baseline now counts the experts."""
    cfg, params, tables = _setup()
    cache = init_cache(cfg, 4, 16)
    tok = jnp.ones((4, 1), jnp.int32)

    dense_jaxpr = str(jax.make_jaxpr(
        lambda p, c, t: decode_step(p, c, t, cfg))(params, cache, tok))
    joint_jaxpr = str(jax.make_jaxpr(
        lambda p, c, t: decode_step(p, c, t, cfg, tables=tables))(
            params, cache, tok))
    assert "pallas_call" not in dense_jaxpr
    assert "pallas_call" in joint_jaxpr

    dense_cost = analyze(lambda p, c, t: decode_step(p, c, t, cfg),
                         params, cache, tok)
    joint_cost = analyze(
        lambda p, c, t: decode_step(p, c, t, cfg, tables=tables),
        params, cache, tok)
    # the dense baseline must include the experts (the silently-zero bug)
    E, d, f, L = cfg.n_experts, cfg.d_model, cfg.d_ff, cfg.n_layers
    expert_bytes = L * E * 3 * d * f * 4          # f32 gate/up/down
    assert dense_cost["weight_bytes"] > expert_bytes > 0
    ratio = joint_cost["weight_bytes"] / dense_cost["weight_bytes"]
    assert ratio <= 0.55, f"joint/dense weight traffic {ratio:.3f} > 0.55"


# --------------------------------------------- fixed weight accounting ----

def _analytic_weight_bytes(cfg):
    """What one decode step's projections weigh, per the cost-model
    coverage contract (README): attention q/k/v/o + router + per-expert
    gate/up/down (+ arctic's dense residual MLP) per layer, + the
    unembedding. Nothing else — no activation einsums, caches, norms."""
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    bpe = 2 if cfg.dtype == "bfloat16" else 4
    if cfg.family == "ssm":
        from repro.models.ssm import ssm_dims
        d_in, nh, N, _ = ssm_dims(cfg)
        return cfg.n_layers * (d * (2 * d_in + 2 * N + nh)
                               + d_in * d) * bpe + d * cfg.vocab_size * bpe
    per_layer = (d * cfg.q_dim + 2 * d * cfg.kv_dim + cfg.q_dim * d) * bpe
    if E:
        n_mlp = 3 if cfg.mlp_type in ("swiglu", "geglu") else 2
        per_layer += d * E * 4                    # router (f32)
        per_layer += E * n_mlp * d * f * bpe      # expert stacks
        if cfg.dense_residual:
            per_layer += n_mlp * d * f * bpe
    else:
        per_layer += 3 * d * f * bpe
    return cfg.n_layers * per_layer + d * cfg.vocab_size * bpe


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "arctic-480b",
                                  "tinyllama-1.1b", "mamba2-1.3b"])
def test_decode_weight_bytes_exact(arch):
    """The headline regression: a dense MoE decode step charges nonzero —
    and exactly correct — expert weight bytes (rank-3 einsum weights were
    silently zero before the provenance fix), while attention/SSM
    ACTIVATION einsums stay excluded (equality would break if any KV/SSM
    state dot were charged)."""
    cfg = get_config(arch, reduced=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    cache = init_cache(cfg, 4, 16)
    tok = jnp.ones((4, 1), jnp.int32)
    cost = analyze(lambda p, c, t: decode_step(p, c, t, cfg),
                   params, cache, tok)
    assert int(cost["weight_bytes"]) == _analytic_weight_bytes(cfg)
    if cfg.n_experts:
        bpe = 2 if cfg.dtype == "bfloat16" else 4
        expert_bytes = (cfg.n_layers * cfg.n_experts * 3
                        * cfg.d_model * cfg.d_ff * bpe)
        assert int(cost["weight_bytes"]) > expert_bytes > 0


# -------------------------------------------------------- capacity --------

def test_capacity_clamps_to_assignment_count():
    """n_tokens * top_k assignments bound the per-expert slots: tiny
    decode batches no longer allocate 8 phantom slots per expert, while
    larger pools keep the multiple-of-8 round-up."""
    cfg = get_config(ARCH, reduced=True)          # E=4, top_k=2
    assert moe_mod.capacity(cfg, 1) == 2          # 2 assignments total
    assert moe_mod.capacity(cfg, 3) == 6
    assert moe_mod.capacity(cfg, 4) == 8          # at the floor exactly
    c64 = moe_mod.capacity(cfg, 64)               # 40 = ceil-to-8 of 40
    assert c64 == 40 and c64 % 8 == 0
    assert c64 <= 64 * cfg.top_k


def test_moe_single_token_decode_runs_with_clamped_capacity():
    """B=1 decode: capacity == top_k slots per expert; the step still
    produces finite logits of the right shape."""
    cfg = get_config(ARCH, reduced=True).scaled(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    cache = init_cache(cfg, 1, 8)
    logits, new_cache = decode_step(params, cache, jnp.ones((1, 1),
                                                            jnp.int32), cfg)
    assert logits.shape == (1, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert int(new_cache["pos"]) == 1
