"""Segmented per-kind layer scans: the families the segment descriptor
API brought into the stacked joint-sparse serving matrix — hybrid
(jamba: mixed attention / SSM / MoE sublayer runs packed per segment)
and enc-dec (whisper: decoder + cross-attention packed, run-once encoder
dense) — plus MoE chunked prefill (per-position capacity dispatch), the
hybrid refill-slot regression, the serving_capabilities() API, and the
unified launch.steps.build_step builder.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.mesh import make_test_mesh
from repro.launch.steps import (build_prefill_chunk_step, build_serve_step,
                                build_slot_decode_step, build_step)
from repro.models import (decode_chunk, decode_step, forward, init_cache,
                          init_params)
from repro.models.segments import (decoder_layout, packable_projections,
                                   projection_param_path,
                                   serving_capabilities)
from repro.models.ssm import PARALLEL_PREFILL_ATOL
from repro.models.transformer import encode
from repro.sparsity.sparse_linear import (build_stacked_tables,
                                          reconstruct_stacked_params,
                                          strip_packed_projections)


def _setup(arch, vs=0.5, mode="joint", **scale):
    cfg = get_config(arch, reduced=True, dbpim_mode=mode).scaled(
        dtype="float32", dbpim_value_sparsity=vs, **scale)
    params = init_params(cfg, jax.random.PRNGKey(0))
    tables = build_stacked_tables(params, cfg, bk=32, bn=32)
    assert tables is not None
    return cfg, params, tables


def _whisper_enc_out(cfg, params, batch):
    frames = jax.random.normal(jax.random.PRNGKey(5),
                               (batch, cfg.encoder_seq, cfg.d_model),
                               dtype=jnp.float32)
    return encode(params, frames, cfg)


# --------------------------------------------------- segment layouts ------

def test_decoder_layouts_per_family():
    """Run-length segment descriptors: single-kind stacks keep the
    historical "blocks" name (param/cache back-compat); jamba's mixed
    periods become per-kind seg00.. runs."""
    ll = decoder_layout(get_config("tinyllama-1.1b", reduced=True))
    assert [(s.name, s.mixer, s.ffn, s.length)
            for s in ll] == [("blocks", "attn", "mlp", 2)]
    mm = decoder_layout(get_config("mamba2-1.3b", reduced=True))
    assert [(s.name, s.mixer, s.ffn, s.cache)
            for s in mm] == [("blocks", "ssm", "none", "ssm")]
    wh = decoder_layout(get_config("whisper-base", reduced=True))
    assert [(s.name, s.mixer, s.ffn, s.cross)
            for s in wh] == [("blocks", "attn", "mlp", True)]
    # jamba reduced: attn_period=4, attn_index=2, moe_every=2 over 4 layers
    jb = decoder_layout(get_config("jamba-v0.1-52b", reduced=True))
    assert [(s.name, s.mixer, s.ffn, s.length, s.cache) for s in jb] == [
        ("seg00", "ssm", "mlp", 1, "seg00"),
        ("seg01", "ssm", "moe", 1, "seg01"),
        ("seg02", "attn", "mlp", 1, "seg02"),
        ("seg03", "ssm", "moe", 1, "seg03")]


def test_serving_capabilities_and_deprecated_shims():
    """serving_capabilities() is the single source of truth; the old
    boolean cfg properties are shims over it. Every family packs stacked
    tables; only sliding windows gate chunked prefill; parallel prefill
    means an SSM segment exists."""
    for arch, chunked, par in [("tinyllama-1.1b", True, False),
                               ("mamba2-1.3b", True, True),
                               ("mixtral-8x7b", False, False),
                               ("arctic-480b", True, False),
                               ("jamba-v0.1-52b", True, True),
                               ("whisper-base", True, False)]:
        cfg = get_config(arch, reduced=True)
        caps = cfg.serving_capabilities()
        assert caps.stacked_tables
        assert caps.chunked_prefill is chunked
        assert caps.parallel_prefill is par
        assert caps.prefill_modes == (("chunked", "full") if chunked
                                      else ("full",))
        # shims agree with the capability object
        assert cfg.supports_stacked_tables == caps.stacked_tables
        assert cfg.supports_chunked_prefill == caps.chunked_prefill
        assert cfg.supports_parallel_prefill == caps.parallel_prefill
    # packable projections carry exact segment-qualified paths
    wh = serving_capabilities(get_config("whisper-base", reduced=True))
    assert "blocks/xattn/wq" in wh.packable
    assert "blocks/w_gate" not in wh.packable       # gelu MLP has no gate
    jb = serving_capabilities(get_config("jamba-v0.1-52b", reduced=True))
    assert "seg02/wq" in jb.packable
    assert "seg01/moe/w_gate" in jb.packable
    assert "seg00/in_proj" in jb.packable


def test_projection_param_paths_disambiguate_hooks():
    """The hook-name -> param-path map resolves the ambiguous bare MLP
    names: a "w_gate" hook inside a MoE segment is arctic's dense
    residual MLP (nested under moe/dense_mlp), not a plain mlp."""
    segs = {s.name: s for s in decoder_layout(
        get_config("jamba-v0.1-52b", reduced=True))}
    assert projection_param_path(segs["seg02"], "wq") == "seg02/attn/wq"
    assert projection_param_path(segs["seg00"], "in_proj") == \
        "seg00/ssm/in_proj"
    assert projection_param_path(segs["seg01"], "moe/w_up") == \
        "seg01/moe/w_up"
    arctic = decoder_layout(get_config("arctic-480b", reduced=True))[0]
    assert projection_param_path(arctic, "w_gate") == \
        "blocks/moe/dense_mlp/w_gate"
    whisper = decoder_layout(get_config("whisper-base", reduced=True))[0]
    assert projection_param_path(whisper, "xattn/wo") == "blocks/xattn/wo"
    assert projection_param_path(whisper, "w_up") == "blocks/mlp/w_up"


# ------------------------------------- jamba / whisper stacked serving ----

def test_jamba_stacked_serving_matches_reference():
    """Hybrid acceptance: jamba serves with dbpim_mode="joint" — the
    per-segment packs thread each segment's scan, the decode jaxpr grows
    pallas_call (graph change), logits match the dense FTA reference,
    and the stripped-params serving configuration is bitwise identical."""
    cfg, params, tables = _setup("jamba-v0.1-52b")
    assert set(tables.segments) == {"seg00", "seg01", "seg02", "seg03"}
    recon = reconstruct_stacked_params(params, tables, cfg)
    toks = jnp.asarray(np.random.default_rng(2).integers(
        1, cfg.vocab_size, (2, 16)), jnp.int32)
    got = forward(params, toks, cfg, tables=tables)
    want = forward(recon, toks, cfg)
    tol = 1e-4 * max(float(jnp.max(jnp.abs(want))), 1.0)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)
    assert float(jnp.max(jnp.abs(want - forward(params, toks, cfg)))) > 0

    cache = init_cache(cfg, 4, 16)
    tok = jnp.asarray([[3], [5], [7], [11]], jnp.int32)
    got_l, _ = decode_step(params, cache, tok, cfg, tables=tables)
    want_l, _ = decode_step(recon, cache, tok, cfg)
    tol = 1e-4 * max(float(jnp.max(jnp.abs(want_l))), 1.0)
    np.testing.assert_allclose(np.asarray(got_l, np.float32),
                               np.asarray(want_l, np.float32), atol=tol)
    stripped = strip_packed_projections(params, cfg)
    got_s, _ = decode_step(stripped, cache, tok, cfg, tables=tables)
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(got_l))
    jaxpr = str(jax.make_jaxpr(
        lambda p, c, t: decode_step(p, c, t, cfg, tables=tables))(
            stripped, cache, tok))
    assert "pallas_call" in jaxpr


def test_whisper_stacked_serving_and_exact_path_strip():
    """Enc-dec acceptance: the whisper decoder packs (cross-attention
    included), the encoder stays dense — strip matches exact param paths,
    so the encoder's identically-suffixed wq/wk/wv/wo survive — and the
    served decode matches the FTA reference with pallas_call in the
    jaxpr."""
    cfg, params, tables = _setup("whisper-base")
    names = set(tables.segments["blocks"].arrays)
    assert {"xattn/wq", "xattn/wk", "xattn/wv", "xattn/wo"} <= names
    assert "w_gate" not in names                     # gelu MLP
    stripped = strip_packed_projections(params, cfg)
    for n in ("wq", "wk", "wv", "wo"):
        np.testing.assert_array_equal(
            np.asarray(stripped["enc_blocks"]["attn"][n]),
            np.asarray(params["enc_blocks"]["attn"][n]))
        assert stripped["blocks"]["attn"][n].shape == \
            (cfg.n_layers, 1, 1)
        assert stripped["blocks"]["xattn"][n].shape == \
            (cfg.n_layers, 1, 1)

    enc_out = _whisper_enc_out(cfg, params, 2)
    recon = reconstruct_stacked_params(params, tables, cfg)
    cache = init_cache(cfg, 2, 16, enc_out=enc_out)
    tok = jnp.asarray([[3], [5]], jnp.int32)
    got, _ = decode_step(stripped, cache, tok, cfg, tables=tables)
    want, _ = decode_step(recon, cache, tok, cfg)
    tol = 1e-4 * max(float(jnp.max(jnp.abs(want))), 1.0)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)
    jaxpr = str(jax.make_jaxpr(
        lambda p, c, t: decode_step(p, c, t, cfg, tables=tables))(
            stripped, cache, tok))
    assert "pallas_call" in jaxpr


# --------------------------------------------- chunked prefill parity -----

def _stepwise(params, cache, toks, cfg, n):
    logits = None
    for t in range(n):
        logits, cache = decode_step(params, cache, toks[:, t:t + 1], cfg)
    return logits, cache


def test_whisper_chunk_prefill_bitwise_equals_stepwise():
    """Attention + cross-attention chunks are exact: one decode_chunk
    call over 5 prompt tokens reproduces 5 decode_step calls bitwise —
    logits AND the decode steps that continue from the resulting cache
    (the transitive cache-correctness check). rope_pct == 0 rides the
    shared _sinusoidal_at position math."""
    cfg = get_config("whisper-base", reduced=True).scaled(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    enc_out = _whisper_enc_out(cfg, params, 2)
    toks = jnp.asarray(np.random.default_rng(3).integers(
        1, cfg.vocab_size, (2, 7)), jnp.int32)
    lg_s, cache_s = _stepwise(params, init_cache(cfg, 2, 16,
                                                 enc_out=enc_out),
                              toks, cfg, 5)
    lg_c, cache_c = decode_chunk(params, init_cache(cfg, 2, 16,
                                                    enc_out=enc_out),
                                 toks[:, :5], jnp.full((2,), 5, jnp.int32),
                                 cfg)
    np.testing.assert_array_equal(np.asarray(lg_c), np.asarray(lg_s))
    for t in range(5, 7):
        lg_s, cache_s = decode_step(params, cache_s, toks[:, t:t + 1], cfg)
        lg_c, cache_c = decode_step(params, cache_c, toks[:, t:t + 1], cfg)
        np.testing.assert_array_equal(np.asarray(lg_c), np.asarray(lg_s))


def test_jamba_chunk_prefill_exact_bitwise_and_parallel_tolerance():
    """Hybrid chunks: with prefill_exact the SSM segments walk the exact
    recurrence and the whole chunk is bitwise-identical to stepwise; the
    default parallel SSD form stays within PARALLEL_PREFILL_ATOL."""
    cfg = get_config("jamba-v0.1-52b", reduced=True).scaled(
        dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.default_rng(4).integers(
        1, cfg.vocab_size, (2, 8)), jnp.int32)
    nv = jnp.full((2,), 6, jnp.int32)
    lg_s, cache_s = _stepwise(params, init_cache(cfg, 2, 16), toks, cfg, 6)
    lg_prefill = lg_s

    cfg_e = cfg.scaled(prefill_exact=True)
    lg_e, cache_e = decode_chunk(params, init_cache(cfg_e, 2, 16),
                                 toks[:, :6], nv, cfg_e)
    np.testing.assert_array_equal(np.asarray(lg_e), np.asarray(lg_s))
    for t in range(6, 8):
        lg_s, cache_s = decode_step(params, cache_s, toks[:, t:t + 1], cfg)
        lg_e, cache_e = decode_step(params, cache_e, toks[:, t:t + 1], cfg)
        np.testing.assert_array_equal(np.asarray(lg_e), np.asarray(lg_s))

    lg_p, _ = decode_chunk(params, init_cache(cfg, 2, 16), toks[:, :6],
                           nv, cfg)
    assert float(jnp.max(jnp.abs(lg_p - lg_prefill))) <= \
        PARALLEL_PREFILL_ATOL[cfg.dtype]


def test_moe_chunk_prefill_identical_to_stepwise():
    """MoE chunked prefill (the decode_chunk gate that used to reject
    n_experts): per-position capacity dispatch routes each chunk position
    against exactly one decode step's token pool, and at decode-batch
    scale capacity() clamps to B * top_k — drop-free — so the chunk is
    bitwise identical to stepwise prefill, continuation included."""
    cfg = get_config("arctic-480b", reduced=True).scaled(dtype="float32")
    assert cfg.serving_capabilities().chunked_prefill
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.default_rng(5).integers(
        1, cfg.vocab_size, (3, 7)), jnp.int32)
    lg_s, cache_s = _stepwise(params, init_cache(cfg, 3, 16), toks, cfg, 5)
    lg_c, cache_c = decode_chunk(params, init_cache(cfg, 3, 16),
                                 toks[:, :5], jnp.full((3,), 5, jnp.int32),
                                 cfg)
    np.testing.assert_array_equal(np.asarray(lg_c), np.asarray(lg_s))
    for t in range(5, 7):
        lg_s, cache_s = decode_step(params, cache_s, toks[:, t:t + 1], cfg)
        lg_c, cache_c = decode_step(params, cache_c, toks[:, t:t + 1], cfg)
        np.testing.assert_array_equal(np.asarray(lg_c), np.asarray(lg_s))


def test_windowed_arch_still_rejects_chunked_prefill():
    cfg = get_config("mixtral-8x7b", reduced=True)   # window=32
    assert not cfg.serving_capabilities().chunked_prefill
    params = init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="sliding-window"):
        decode_chunk(params, init_cache(cfg, 2, 16),
                     jnp.ones((2, 4), jnp.int32),
                     jnp.full((2,), 4, jnp.int32), cfg)


# -------------------------------------------- hybrid refill regression ----

def test_hybrid_engine_refill_slots_match_fresh_slots():
    """The refill-slot regression on the hybrid cache layout: an engine
    whose 2 slots are reset and refilled mid-trace (4 requests) must
    generate exactly what a 4-slot engine (every request on a fresh slot)
    generates — reset_slots/merge_slots walk the per-segment seg00..
    caches with uniform batch axis 1, no family-switched axis math."""
    from repro.serving import ServeEngine, WorkloadSpec, make_trace
    cfg = get_config("jamba-v0.1-52b", reduced=True,
                     prefill_exact=True).scaled(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    spec = WorkloadSpec(n_requests=4, arrival_rate=10.0, prompt_len=(3, 9),
                        gen_len=(3, 5), dist="uniform", seed=11)
    trace = make_trace(spec, cfg.vocab_size)
    outs = {}
    for n_slots in (2, 4):
        engine = ServeEngine(cfg, params, n_slots=n_slots, max_len=24,
                             prefill_chunk=4)
        outs[n_slots] = engine.run(trace)
    assert outs[2] == outs[4]


# ------------------------------------------------- unified step builder ---

def test_build_step_tags_and_validation():
    mesh = make_test_mesh()
    llama = get_config("tinyllama-1.1b", reduced=True)
    jamba = get_config("jamba-v0.1-52b", reduced=True)
    whisper = get_config("whisper-base", reduced=True)

    serve_fn, _ = build_step(llama, mesh, "serve")
    decode_fn, _ = build_step(llama, mesh, "decode")
    assert serve_fn.call_kind == "decode"
    assert decode_fn.call_kind == "decode"
    chunk_j, _ = build_step(jamba, mesh, "prefill_chunk")
    assert chunk_j.call_kind == "prefill_parallel"
    chunk_je, _ = build_step(jamba.scaled(prefill_exact=True), mesh,
                             "prefill_chunk")
    assert chunk_je.call_kind == "prefill_chunk_exact"
    chunk_w, _ = build_step(whisper, mesh, "prefill_chunk")
    assert chunk_w.call_kind == "prefill_chunk_exact"

    # the legacy builders are thin wrappers over the same entry point
    assert build_serve_step(llama, mesh)[0].call_kind == "decode"
    assert build_slot_decode_step(llama, mesh)[0].call_kind == "decode"
    assert build_prefill_chunk_step(jamba, mesh)[0].call_kind == \
        "prefill_parallel"

    with pytest.raises(ValueError, match="call_kind"):
        build_step(llama, mesh, "train")
    with pytest.raises(ValueError, match="mutually"):
        build_step(llama, mesh, "serve", int8_weights=True,
                   stacked_tables=object())
    with pytest.raises(ValueError, match="serve"):
        build_step(llama, mesh, "decode", int8_weights=True)
