"""MetricsRecorder.summary() contract: requests that never reach a first
token are counted explicitly (never silently folded into or dropped from
the TTFT aggregates), the all-queued-at-shutdown edge cannot crash, and
percentiles are nearest-rank.
"""

from repro.serving.metrics import MetricsRecorder


def _submit(m, rid, arrival=0):
    m.on_submit(rid, prompt_len=4, gen_len=2, arrival=arrival)


def test_all_queued_at_shutdown_summary_is_explicit_not_a_crash():
    """Engine shut down with every request still queued: no TTFT exists.
    summary() must report that state explicitly — None aggregates plus an
    n_no_first_token count — rather than crashing or averaging over an
    empty/placeholder population."""
    m = MetricsRecorder()
    for rid in range(3):
        _submit(m, rid)
    s = m.summary()
    assert s["n_requests"] == 3
    assert s["ttft_n"] == 0
    assert s["n_no_first_token"] == 3
    assert s["ttft_ticks_mean"] is None
    assert s["ttft_ticks_p50"] is None
    assert s["ttft_ticks_p95"] is None
    assert s["prefill_steps_per_request_mean"] is None
    assert s["n_completed"] == 0


def test_partial_first_tokens_aggregate_over_reached_only():
    """Mixed population: TTFT aggregates cover exactly the requests that
    reached a first token; the rest are counted, not imputed. Prefill
    steps average over every ADMITTED request — half-prefilled requests
    did real device work."""
    m = MetricsRecorder()
    for rid in range(4):
        _submit(m, rid)
        m.on_admit(rid, tick=0)
    # rids 0/1 reach first token at ticks 3 and 5; 2/3 never do, but rid 2
    # burned 2 prefill steps before shutdown
    m.on_prefill_step(0)
    m.on_first_token(0, 3)
    m.on_prefill_step(1)
    m.on_first_token(1, 5)
    m.on_prefill_step(2)
    m.on_prefill_step(2)
    s = m.summary()
    assert s["ttft_n"] == 2 and s["n_no_first_token"] == 2
    assert s["ttft_ticks_mean"] == 4.0            # (3 + 5) / 2, not /4
    assert s["ttft_ticks_p50"] == 3
    assert s["ttft_ticks_p95"] == 5
    assert s["prefill_steps_per_request_mean"] == 1.0   # 4 steps / 4 admitted
    assert s["ttft_n"] + s["n_no_first_token"] == s["n_requests"]


def test_percentiles_are_nearest_rank():
    """p95 of 20 samples is the 19th order statistic, not the max; p50 of
    an odd count is the middle element."""
    m = MetricsRecorder()
    for rid in range(20):
        _submit(m, rid, arrival=0)
        m.on_first_token(rid, rid + 1)            # ttfts 1..20
    s = m.summary()
    assert s["ttft_ticks_p95"] == 19
    assert s["ttft_ticks_p50"] == 10
    assert s["ttft_ticks_mean"] == 10.5
