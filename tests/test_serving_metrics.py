"""MetricsRecorder.summary() contract: requests that never reach a first
token are counted explicitly (never silently folded into or dropped from
the TTFT aggregates), the all-queued-at-shutdown edge cannot crash,
percentiles are nearest-rank, retries are attributed by call kind,
per-request rows carry deadline/admission-wait, and the slot audit log
aggregates into utilization.
"""

from repro.serving.metrics import MetricsRecorder


def _submit(m, rid, arrival=0):
    m.on_submit(rid, prompt_len=4, gen_len=2, arrival=arrival)


def test_all_queued_at_shutdown_summary_is_explicit_not_a_crash():
    """Engine shut down with every request still queued: no TTFT exists.
    summary() must report that state explicitly — None aggregates plus an
    n_no_first_token count — rather than crashing or averaging over an
    empty/placeholder population."""
    m = MetricsRecorder()
    for rid in range(3):
        _submit(m, rid)
    s = m.summary()
    assert s["n_requests"] == 3
    assert s["ttft_n"] == 0
    assert s["n_no_first_token"] == 3
    assert s["ttft_ticks_mean"] is None
    assert s["ttft_ticks_p50"] is None
    assert s["ttft_ticks_p95"] is None
    assert s["prefill_steps_per_request_mean"] is None
    assert s["n_completed"] == 0


def test_partial_first_tokens_aggregate_over_reached_only():
    """Mixed population: TTFT aggregates cover exactly the requests that
    reached a first token; the rest are counted, not imputed. Prefill
    steps average over every ADMITTED request — half-prefilled requests
    did real device work."""
    m = MetricsRecorder()
    for rid in range(4):
        _submit(m, rid)
        m.on_admit(rid, tick=0)
    # rids 0/1 reach first token at ticks 3 and 5; 2/3 never do, but rid 2
    # burned 2 prefill steps before shutdown
    m.on_prefill_step(0)
    m.on_first_token(0, 3)
    m.on_prefill_step(1)
    m.on_first_token(1, 5)
    m.on_prefill_step(2)
    m.on_prefill_step(2)
    s = m.summary()
    assert s["ttft_n"] == 2 and s["n_no_first_token"] == 2
    assert s["ttft_ticks_mean"] == 4.0            # (3 + 5) / 2, not /4
    assert s["ttft_ticks_p50"] == 3
    assert s["ttft_ticks_p95"] == 5
    assert s["prefill_steps_per_request_mean"] == 1.0   # 4 steps / 4 admitted
    assert s["ttft_n"] + s["n_no_first_token"] == s["n_requests"]


def test_percentiles_are_nearest_rank():
    """p95 of 20 samples is the 19th order statistic, not the max; p50 of
    an odd count is the middle element."""
    m = MetricsRecorder()
    for rid in range(20):
        _submit(m, rid, arrival=0)
        m.on_first_token(rid, rid + 1)            # ttfts 1..20
    s = m.summary()
    assert s["ttft_ticks_p95"] == 19
    assert s["ttft_ticks_p50"] == 10
    assert s["ttft_ticks_mean"] == 10.5


def test_retries_attributed_by_call_kind():
    """on_retry(kind) lands in retries_by_kind — the old recorder took
    the argument and dropped it, so "which executable kept failing" was
    unanswerable from a summary."""
    m = MetricsRecorder()
    m.on_retry("decode")
    m.on_retry("decode")
    m.on_retry("prefill_parallel")
    s = m.summary()
    assert s["retries"] == 3
    assert s["retries_by_kind"] == {"decode": 2, "prefill_parallel": 1}


def test_per_request_carries_deadline_and_admission_wait():
    """per_request() rows expose the SLO inputs: the deadline a request
    was submitted with, and how long it queued before admission (the
    queueing share of TTFT)."""
    m = MetricsRecorder()
    m.on_submit(0, prompt_len=4, gen_len=2, arrival=3, deadline=20)
    m.on_submit(1, prompt_len=4, gen_len=2, arrival=0)
    m.on_admit(0, tick=7)
    rows = {r["rid"]: r for r in m.per_request()}
    assert rows[0]["deadline"] == 20
    assert rows[0]["admission_wait_ticks"] == 4      # admitted 7, arrived 3
    assert rows[1]["deadline"] is None
    assert rows[1]["admission_wait_ticks"] is None   # never admitted


def test_slot_log_aggregates_into_utilization():
    """record_slot_log turns the engine's interval audit log into
    slot_busy_frac / per-slot occupancy; open intervals (still serving
    at shutdown) count busy through the last tick."""
    m = MetricsRecorder()
    for tick in range(10):
        m.on_tick(tick, queue_depth=0, n_prefilling=0, n_decoding=0,
                  device_calls=1)
    # slot 0: [0,4) then [6,10); slot 1: [2, open) -> busy to tick 10
    m.record_slot_log([(0, 0, 4), (0, 6, 10), (1, 2, None)], n_slots=2)
    s = m.summary()
    assert s["slot_occupancy"] == [0.8, 0.8]
    assert s["slot_busy_frac"] == 0.8


def test_slot_metrics_none_without_log():
    """Until the engine installs its audit log, utilization is an
    explicit None, not a fabricated zero."""
    s = MetricsRecorder().summary()
    assert s["slot_busy_frac"] is None
    assert s["slot_occupancy"] is None


def test_device_call_latency_histogram_by_kind():
    """Per-call dur_s lands in a per-kind log histogram; replay calls
    are tagged separately so recovery latency is attributable."""
    m = MetricsRecorder()
    for _ in range(8):
        m.on_device_call("decode", kind="decode", dur_s=0.010)
    m.on_device_call("prefill", kind="prefill_parallel", replay=True,
                     dur_s=0.040)
    s = m.summary()
    lat = s["call_latency_ms"]
    assert set(lat) == {"decode", "prefill_parallel+replay"}
    assert lat["decode"]["count"] == 8
    assert abs(lat["decode"]["p50_ms"] - 10.0) / 10.0 < 0.10
    assert s["calls_by_kind"]["prefill_parallel+replay"] == 1
