"""End-to-end behaviour tests: training convergence, checkpoint-resume
equivalence, serving, DB-PIM LM compression, fault-tolerant loop."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import SyntheticLMDataset
from repro.launch.mesh import make_test_mesh
from repro.launch.steps import build_serve_step, build_train_step
from repro.models import decode_step, init_cache, init_params, loss_fn
from repro.optim import adamw_init
from repro.runtime import sharding as shr
from repro.sparsity import dequant_tree, pim_speedup_estimate, \
    sparsify_params


def _train(cfg, steps, seed=0, microbatches=1, grad_compression=False,
           params=None, opt_state=None, start=0):
    mesh = make_test_mesh()
    if params is None:
        params = init_params(cfg, jax.random.PRNGKey(seed))
        opt_state = adamw_init(params)
    ds = SyntheticLMDataset(cfg, 8, 64, seed=seed)
    step_fn, shard_fn = build_train_step(cfg, mesh,
                                         microbatches=microbatches,
                                         grad_compression=grad_compression)
    with mesh:
        jitted = jax.jit(step_fn, donate_argnums=(0, 1))
        losses = []
        for s in range(start, steps):
            batch = {k: jnp.asarray(v) for k, v in ds.batch_at(s).items()}
            params, opt_state, loss = jitted(params, opt_state, batch)
            losses.append(float(loss))
    return params, opt_state, losses


def test_training_reduces_loss():
    cfg = get_config("tinyllama-1.1b", reduced=True)
    _, _, losses = _train(cfg, 60)
    assert losses[-1] < losses[0] - 0.05
    assert all(np.isfinite(l) for l in losses)


def test_microbatched_matches_full_batch():
    cfg = get_config("tinyllama-1.1b", reduced=True)
    _, _, l1 = _train(cfg, 3, microbatches=1)
    _, _, l4 = _train(cfg, 3, microbatches=4)
    # same data, same params: identical loss up to accumulation order
    np.testing.assert_allclose(l1, l4, rtol=2e-2, atol=2e-2)


def test_grad_compression_trains():
    cfg = get_config("tinyllama-1.1b", reduced=True)
    _, _, losses = _train(cfg, 40, grad_compression=True)
    assert losses[-1] < losses[0]


def test_checkpoint_resume_bit_identical(tmp_path):
    from repro.checkpoint import load_checkpoint, save_checkpoint
    cfg = get_config("tinyllama-1.1b", reduced=True)
    p1, o1, _ = _train(cfg, 5)
    save_checkpoint(str(tmp_path), 5, (p1, o1))
    (p2, o2), step, _ = load_checkpoint(str(tmp_path), (p1, o1))
    p2 = jax.tree_util.tree_map(jnp.asarray, p2)
    o2 = jax.tree_util.tree_map(jnp.asarray, o2)
    # continue both for 3 steps: identical trajectories
    pa, _, la = _train(cfg, 8, params=p1, opt_state=o1, start=5)
    pb, _, lb = _train(cfg, 8, params=p2, opt_state=o2, start=5)
    np.testing.assert_allclose(la, lb, rtol=1e-5)


def test_serve_decode_consistency():
    """Decode step by step == prefill logits at the same position."""
    cfg = get_config("tinyllama-1.1b", reduced=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)
    from repro.models.transformer import forward
    full = forward(params, toks, cfg)                     # (2, 8, V)
    cache = init_cache(cfg, 2, max_len=16)
    outs = []
    for i in range(8):
        logits, cache = decode_step(params, cache, toks[:, i:i + 1], cfg)
        outs.append(np.asarray(logits[:, 0], np.float32))
    np.testing.assert_allclose(np.stack(outs, 1),
                               np.asarray(full, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_dbpim_compression_preserves_function():
    cfg = get_config("tinyllama-1.1b", reduced=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    ds = SyntheticLMDataset(cfg, 4, 64, seed=0)
    batch = {k: jnp.asarray(v) for k, v in ds.batch_at(0).items()}
    base = float(loss_fn(params, batch, cfg))
    comp = sparsify_params(params, cfg, value_sparsity=0.0)
    loss_c = float(loss_fn(dequant_tree(params, comp), batch, cfg))
    assert abs(loss_c - base) < 0.5          # FTA-only: mild perturbation
    est = pim_speedup_estimate(comp, cfg)
    assert est["speedup"] > 2.0              # bit-level >= ~4x ideal
    rep = list(comp.report.values())
    assert all(r["bit_sparsity"] >= 0.75 - 1e-6 for r in rep)


def test_fta_aware_training_loop():
    """Fig.4 stage 2 at LM scale: periodic FTA projection inside the
    training loop still reduces loss (the paper's FTA-aware QAT claim,
    reduced scale)."""
    from repro.launch.train import main as train_main
    losses = train_main(["--arch", "tinyllama-1.1b", "--reduced",
                         "--steps", "40", "--batch", "8", "--seq", "64",
                         "--dbpim-every", "10", "--log-every", "100"])
    assert losses[-1] < losses[0]
    assert all(np.isfinite(l) for l in losses)
