"""Beyond-paper example: DB-PIM hybrid-grained compression applied to a
transformer LM (the paper evaluates CNNs only).

    PYTHONPATH=src python examples/dbpim_compress_lm.py

Compresses every projection of a TinyLlama-family model with the exact
paper pipeline (block pruning + FTA), runs the SAME model code on the
reconstructed FTA-compliant weights, reports perplexity impact on the
synthetic stream, and estimates DB-PIM chip speedup via the cost model.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import SyntheticLMDataset
from repro.models import init_params, loss_fn
from repro.sparsity import (dequant_tree, pim_speedup_estimate,
                            sparsify_params)


def main():
    cfg = get_config("tinyllama-1.1b", reduced=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    ds = SyntheticLMDataset(cfg, 8, 128, seed=0)
    batch = {k: jnp.asarray(v) for k, v in ds.batch_at(0).items()}

    base_loss = float(loss_fn(params, batch, cfg))

    for vs in (0.0, 0.4, 0.6):
        comp = sparsify_params(params, cfg, value_sparsity=vs)
        params_c = dequant_tree(params, comp)
        loss_c = float(loss_fn(params_c, batch, cfg))
        est = pim_speedup_estimate(comp, cfg)
        n_proj = est["n_projections"]
        int8 = sum(r["int8_bytes"] for r in comp.report.values())
        orig = sum(r["orig_bytes"] for r in comp.report.values())
        bit_s = np.mean([r["bit_sparsity"] for r in comp.report.values()])
        print(f"value_sparsity={vs:.1f}: loss {base_loss:.3f} -> "
              f"{loss_c:.3f} | bit_sparsity={bit_s:.2f} | "
              f"bytes {orig} -> {int8} ({int8/orig:.2f}x) | "
              f"PIM speedup {est['speedup']:.2f}x, "
              f"energy savings {est['energy_savings']*100:.1f}%, "
              f"U_act {est['u_act']*100:.1f}% over {n_proj} projections")


if __name__ == "__main__":
    main()
