"""Batched serving example: continuous-batching decode over a fixed-slot
batch (the TPU-efficient regime) on a Mixtral-family (MoE + SWA) model.

    PYTHONPATH=src python examples/serve_lm.py
"""

from repro.launch.serve import main as serve_main


if __name__ == "__main__":
    serve_main(["--arch", "mixtral-8x7b", "--reduced", "--batch", "4",
                "--requests", "8", "--gen-len", "12", "--max-len", "64"])
