"""Quickstart: the DB-PIM pipeline end to end on one weight matrix.

    PYTHONPATH=src python examples/quickstart.py

1. Random "trained" weights -> coarse block pruning (value sparsity).
2. FTA quantization (CSD fixed-threshold, Alg. 1) -> bit sparsity.
3. Dyadic-block packing (the offline compilation of Fig. 4).
4. Bit-true DBMU datapath check (Pallas kernel, interpret mode).
5. DB-PIM cost model: speedup / energy / utilization vs dense PIM.
6. JOINT kernel (mode="joint"): value-compacted + INT8 bit-compressed
   weights served by one Pallas matmul — the paper's headline fusion.
"""

import numpy as np
import jax.numpy as jnp

from repro.core import csd, dyadic, fta, pruning
from repro.core.pim_model import (LayerGEMM, evaluate_dense_baseline,
                                  evaluate_model, sparsity_from_export)
from repro.kernels import ops, ref


def main():
    rng = np.random.default_rng(0)
    K, N = 256, 128

    print("== 1. weights + coarse block pruning (60% value sparsity)")
    w = rng.laplace(0, 0.02, (K, N)).astype(np.float32)
    mask = np.asarray(pruning.block_prune_mask(w, 0.6, alpha=8))
    print(f"   value sparsity: {pruning.value_sparsity(mask):.2f}")

    print("== 2. FTA quantization (phi_th in {0,1,2})")
    scale = np.abs(w).max() / 127.0
    q = np.clip(np.round(w / scale), -127, 127).astype(np.int32)
    q_fta, phi = fta.fta_quantize(q, mask)
    print(f"   phi_th histogram: {np.bincount(np.asarray(phi), minlength=3)}")
    print(f"   bit sparsity of kept weights: "
          f"{fta.achieved_bit_sparsity(q_fta, mask):.3f} (>= 0.75)")

    print("== 3. dyadic-block packing (signs + indices)")
    packed = dyadic.pack_terms(np.asarray(q_fta))
    recon = dyadic.unpack_terms(packed)
    print(f"   pack/unpack exact: {bool((recon == np.asarray(q_fta)).all())}")

    print("== 4. bit-true DBMU datapath (Pallas, interpret)")
    x = rng.integers(-127, 128, (16, K), dtype=np.int32)
    got = np.asarray(ops.dbmu_reference_check(x, packed))
    want = ref.dbmu_matmul_ref(x, packed)
    print(f"   bit-serial AND + CSD tree == int matmul: "
          f"{bool((got == want).all())}")

    print("== 5. DB-PIM vs dense digital PIM (cost model)")
    layer = LayerGEMM("demo", M=64, K=K, N=N, kind="fc")
    sp = sparsity_from_export(np.asarray(q_fta), mask, np.asarray(phi))
    ours = evaluate_model([layer], {"demo": sp})
    dense = evaluate_dense_baseline([layer])
    print(f"   speedup {dense.cycles/ours.cycles:.2f}x | energy savings "
          f"{(1-ours.energy_pj/dense.energy_pj)*100:.1f}% | "
          f"U_act {ours.u_act*100:.1f}%")

    print("== 6. joint value x bit kernel (the TPU serving path)")
    packed = ops.pack_joint_sparse(w, mask)
    xf = jnp.asarray(rng.normal(0, 1, (64, K)), jnp.float32)
    y = ops.joint_dense(xf, packed)
    want = ref.joint_packed_ref(xf, packed)
    err = float(jnp.max(jnp.abs(y - want)))
    stored = ops.joint_storage_bytes(packed)
    print(f"   weight bytes: joint={stored} vs dense bf16={2*K*N} "
          f"({stored/(2*K*N):.2f}x) | max |kernel - dense ref| = {err:.2e}")


if __name__ == "__main__":
    main()
