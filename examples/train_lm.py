"""End-to-end training driver example: train a ~100M-param TinyLlama-family
model for a few hundred steps with checkpointing + fault tolerance.

Full-size run (what you'd do on a pod; ~100M params):

    PYTHONPATH=src python examples/train_lm.py --full

CPU-container default: the reduced config, 200 steps (loss visibly drops).
"""

import argparse

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="~100M params (d=768, 12L) instead of the smoke "
                         "config; needs ~1h on this CPU container")
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    if args.full:
        # ~100M: override the reduced config via the registry's full
        # config scaled down to 12 x 768 (vocab kept).
        import repro.configs.tinyllama_1_1b as t
        cfg = t.config().scaled(name="tinyllama-100m", n_layers=12,
                                d_model=768, n_heads=12, n_kv_heads=4,
                                d_ff=2048)
        t.reduced_config = lambda: cfg  # serve it through --reduced
        train_main(["--arch", "tinyllama-1.1b", "--reduced",
                    "--steps", str(args.steps), "--batch", "8",
                    "--seq", "512", "--microbatches", "2",
                    "--ckpt-dir", "/tmp/repro_ckpt_100m"])
    else:
        train_main(["--arch", "tinyllama-1.1b", "--reduced",
                    "--steps", str(args.steps), "--batch", "16",
                    "--seq", "128", "--ckpt-dir", "/tmp/repro_ckpt_smoke"])


if __name__ == "__main__":
    main()
