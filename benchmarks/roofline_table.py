"""Roofline table benchmark: all (arch x shape) baselines from the
dry-run records (single-pod mesh, per the spec), CSV-emitted."""

from __future__ import annotations

from pathlib import Path

from repro.launch.roofline import format_table, load_cells
from .common import emit, timed

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def run():
    rows = []
    if not DRYRUN_DIR.exists() or not list(DRYRUN_DIR.glob("*.json")):
        rows.append(("roofline.missing", 0.0,
                     "run `python -m repro.launch.dryrun --all` first"))
        return emit(rows)
    cells, us = timed(load_cells, str(DRYRUN_DIR))
    for c in cells:
        if c.mesh != "single":
            continue
        name = f"roofline.{c.arch}.{c.shape}"
        if c.status != "ok":
            rows.append((name, 0.0, f"status={c.status}"))
            continue
        rows.append((name, us / max(len(cells), 1),
                     f"compute_ms={c.compute_s*1e3:.3f} "
                     f"memory_ms={c.memory_s*1e3:.3f} "
                     f"collective_ms={c.collective_s*1e3:.3f} "
                     f"bound={c.bottleneck} "
                     f"useful_ratio={c.useful_ratio:.2f} "
                     f"roofline_frac={c.roofline_fraction:.3f}"))
    return emit(rows)


if __name__ == "__main__":
    print(format_table(load_cells(str(DRYRUN_DIR))))
