"""Fig. 13 — execution-time breakdown by operation type on the DB-PIM
system for MobileNetV2 and EfficientNetB0.

Paper reference: std/pw-conv+FC only 51.3% (MNv2) / 60.8% (EffNet) of
runtime; dw-conv 48.3% / 35.9%; mul + etc the remainder.
"""

from __future__ import annotations

from repro.configs.paper_cnns import CNN_MODELS
from repro.core import pim_model as pm
from repro.core.workload_gen import model_metadata
from .common import emit, timed


def run():
    rows = []
    for name in ("mobilenetv2", "efficientnetb0"):
        layers = CNN_MODELS[name]()
        def point():
            md = model_metadata(layers, 0.6, name, seed=0)
            ours = pm.evaluate_model(layers, md)
            total = ours.cycles
            by_kind = {}
            for layer, rep in zip(layers, ours.layers):
                k = "pw/std/fc" if layer.kind in ("std", "pw", "fc") else layer.kind
                by_kind[k] = by_kind.get(k, 0.0) + rep.cycles
            return {k: v / total for k, v in by_kind.items()}
        shares, us = timed(point)
        desc = " ".join(f"{k}={v*100:.1f}%" for k, v in sorted(shares.items()))
        rows.append((f"fig13.{name}", us, desc))
    return emit(rows)


if __name__ == "__main__":
    run()
