"""Shared helpers for the benchmark harness (one module per paper table)."""

from __future__ import annotations

import time
from typing import Callable, Iterable, List, Tuple


def timed(fn: Callable, *args, repeat: int = 1, **kwargs):
    """Run fn, return (result, us_per_call)."""
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kwargs)
    us = (time.perf_counter() - t0) / repeat * 1e6
    return out, us


def emit(rows: Iterable[Tuple[str, float, str]]) -> List[str]:
    """Print ``name,us_per_call,derived`` CSV lines and return them."""
    lines = []
    for name, us, derived in rows:
        line = f"{name},{us:.1f},{derived}"
        print(line)
        lines.append(line)
    return lines
