"""Serving-path weight-traffic benchmark: dense vs stacked-joint decode.

Measures, via the trip-aware jaxpr walker, the WEIGHT bytes one decode
step moves through HBM on a reduced arch — once with plain dense serving,
once with the uniform-MAXB stacked joint-sparse tables threaded through
the decode scan — and emits the comparison as ``BENCH_serve.json``.

The contract under test: at 0.5 value sparsity the joint path must move
at most ``TARGET_RATIO`` (0.55x) of the dense-mode weight bytes — the
``(1 - value_sparsity) * 0.5`` packed-layout saving plus index/scale
overhead and the (mode-independent) dense unembedding. A violation
raises: this is the CI guard that the serving graph actually changed.

The MoE case (mixtral) additionally guards the EXPERT stacks on the
fixed accounting: the per-expert einsum weights (rank-3 ``edf`` rhs,
silently zero in the walker before the provenance fix) must contribute
nonzero dense bytes, and the grouped packed tables must move <=
``TARGET_RATIO`` of those dense expert bytes.

The hybrid (jamba) and enc-dec (whisper) cases guard the segmented
per-kind scans that closed the family matrix: jamba's mixed attention /
SSM / MoE sublayer runs pack per segment (seg00..), whisper's decoder —
cross-attention included — packs while its run-once encoder stays
dense; both must hit the same <= ``TARGET_RATIO`` decode-step contract.

    PYTHONPATH=src python -m benchmarks.serve_bench [--smoke] \
        [--out BENCH_serve.json]

Shapes note: the bench arch is the reduced family config scaled up to
d_model=256 so the (128, 128) kernel tiles see >= 2 K-blocks per column
— at d_model=64 a projection is a single padded tile and tile-granular
value sparsity cannot exist.
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import decode_step, init_cache, init_params
from repro.models.transformer import encode
from repro.runtime.jaxpr_cost import analyze
from repro.sparsity.sparse_linear import (build_stacked_tables,
                                          reconstruct_stacked_params)
from .common import emit

TARGET_RATIO = 0.55
VALUE_SPARSITY = 0.5
ARCHS = ("tinyllama-1.1b", "mamba2-1.3b", "mixtral-8x7b",
         "jamba-v0.1-52b", "whisper-base")
#: CI subset: one dense arch + the MoE arch (grouped-expert pack and the
#: fixed rank-3 expert weight accounting) + the two families the
#: segmented scans brought in — hybrid (per-segment packs, mixed
#: sublayer kinds) and enc-dec (cross-attention packs, dense encoder).
SMOKE_ARCHS = ("tinyllama-1.1b", "mixtral-8x7b",
               "jamba-v0.1-52b", "whisper-base")


def bench_cfg(arch: str, dtype: str = "bfloat16"):
    cfg = get_config(arch, reduced=True, dbpim_mode="joint")
    cfg = cfg.scaled(name=f"{cfg.name}-bench", dtype=dtype,
                     dbpim_value_sparsity=VALUE_SPARSITY)
    if cfg.family == "ssm":
        return cfg.scaled(d_model=256, ssm_state=64, ssm_head_dim=64)
    if cfg.family == "hybrid":
        return cfg.scaled(d_model=256, n_heads=4, n_kv_heads=2, d_ff=512,
                          ssm_state=64, ssm_head_dim=64)
    if cfg.family == "audio":
        return cfg.scaled(d_model=256, n_heads=4, n_kv_heads=4, d_ff=512)
    return cfg.scaled(d_model=256, n_heads=4, n_kv_heads=2, d_ff=512)


def _enc_out(cfg, params, batch: int):
    """Whisper: the decode caches carry the encoder output (computed once
    per request; its weights are deliberately unpacked and NOT part of
    the per-step traffic contract)."""
    if not cfg.is_encdec:
        return None
    frames = jax.random.normal(jax.random.PRNGKey(7),
                               (batch, cfg.encoder_seq, cfg.d_model),
                               dtype=jnp.float32)
    return encode(params, frames.astype(
        jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32), cfg)


def _packed_bytes(tables) -> int:
    return sum(int(a.size * a.dtype.itemsize)
               for t in tables.arrays.values() for a in t.values())


def bench_arch(arch: str, batch: int = 4, max_len: int = 32) -> dict:
    # --- weight traffic at the serving dtype (bf16 dense baseline) ------
    cfg = bench_cfg(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    tables = build_stacked_tables(params, cfg)
    if tables is None:
        raise RuntimeError(f"{arch}: no stacked joint path — the serving "
                           "integration this bench guards is missing")
    cache = init_cache(cfg, batch, max_len, enc_out=_enc_out(cfg, params,
                                                             batch))
    tok = jnp.ones((batch, 1), jnp.int32)

    dense_cost = analyze(
        lambda p, c, t: decode_step(p, c, t, cfg), params, cache, tok)
    joint_cost = analyze(
        lambda p, c, t: decode_step(p, c, t, cfg, tables=tables),
        params, cache, tok)
    dense_wb = dense_cost["weight_bytes"]
    joint_wb = joint_cost["weight_bytes"]
    if not dense_wb:
        raise RuntimeError(f"{arch}: dense decode step charged zero weight "
                           "bytes — the cost walker is broken")
    ratio = joint_wb / dense_wb

    # eligible-projection view: packed artifact vs its dense bf16
    # footprint. Leading axes of w_blocks before (NT, MAXB, bk, bn) are
    # the layer axis (stacked) or layer x expert (grouped MoE packs).
    eligible_dense = sum(
        2 * int(np.prod(t["w_blocks"].shape[:-4])) * k * n
        for name, t in tables.arrays.items()
        for k, n in [tables.static[name][:2]])
    packed = _packed_bytes(tables)

    # MoE: the per-expert einsum weights were the silently-zero term of
    # the cost walker — guard their accounting and their packed saving
    # separately from the blended ratio. A decode step reads every
    # layer's packed expert tables once (scan xs), so packed traffic per
    # step equals stored bytes.
    expert = {}
    if cfg.n_experts:
        # flat-view keys are "moe/w_up" on single-segment stacks and
        # "segNN/moe/w_up" on hybrid per-segment packs; arctic's dense
        # residual MLP packs under bare names and stays excluded
        moe_names = [n for n in tables.arrays if "moe/" in n]
        dense_expert = sum(
            2 * int(np.prod(tables.arrays[n]["w_blocks"].shape[:-4]))
            * k * nn for n in moe_names
            for k, nn in [tables.static[n][:2]])
        packed_expert = sum(int(a.size * a.dtype.itemsize)
                            for n in moe_names
                            for a in tables.arrays[n].values())
        if not dense_expert:
            raise RuntimeError(f"{arch}: dense expert weight bytes are "
                               "zero — the MoE projections never packed")
        if dense_wb <= dense_expert:
            raise RuntimeError(
                f"{arch}: dense decode charged {int(dense_wb)} weight "
                f"bytes, not more than the {dense_expert} the expert "
                f"stacks alone must contribute — the rank-3 einsum "
                f"weight accounting regressed to zero")
        expert_ratio = packed_expert / dense_expert
        expert = {"dense_expert_weight_bytes_per_step": int(dense_expert),
                  "packed_expert_weight_bytes_per_step": int(packed_expert),
                  "expert_ratio": expert_ratio}
        if expert_ratio > TARGET_RATIO:
            raise RuntimeError(
                f"{arch}: packed expert weight traffic {expert_ratio:.3f}x "
                f"of dense expert bytes > {TARGET_RATIO}")

    # --- numeric check at f32: joint decode == dense FTA reference ------
    cfg32 = bench_cfg(arch, dtype="float32")
    params32 = init_params(cfg32, jax.random.PRNGKey(0))
    tables32 = build_stacked_tables(params32, cfg32)
    recon32 = reconstruct_stacked_params(params32, tables32, cfg32)
    cache32 = init_cache(cfg32, batch, max_len,
                         enc_out=_enc_out(cfg32, params32, batch))
    logits_j, _ = decode_step(params32, cache32, tok, cfg32, tables=tables32)
    logits_r, _ = decode_step(recon32, cache32, tok, cfg32)
    max_diff = float(jnp.max(jnp.abs(logits_j - logits_r)))
    scale = float(jnp.max(jnp.abs(logits_r)))
    if max_diff > 1e-3 * max(scale, 1.0):
        raise RuntimeError(
            f"{arch}: stacked joint decode diverged from the dense FTA "
            f"reference (max_diff={max_diff}, scale={scale})")

    return {
        "arch": cfg.name, "family": cfg.family, "batch": batch,
        "value_sparsity": VALUE_SPARSITY,
        "dense_weight_bytes_per_step": int(dense_wb),
        "joint_weight_bytes_per_step": int(joint_wb),
        "ratio": ratio,
        "eligible_dense_bf16_bytes": int(eligible_dense),
        "packed_table_bytes": int(packed),
        "eligible_ratio": packed / eligible_dense,
        "max_abs_diff_vs_fta_reference": max_diff,
        "logit_scale": scale,
        "target_ratio": TARGET_RATIO,
        "pass": ratio <= TARGET_RATIO,
        **expert,
    }


def run(smoke: bool = False, out: str = "BENCH_serve.json"):
    archs = SMOKE_ARCHS if smoke else ARCHS
    rows, records = [], {}
    for arch in archs:
        r = bench_arch(arch)
        records[r["arch"]] = r
        extra = (f" experts={r['expert_ratio']:.3f}x "
                 f"(dense_expert={r['dense_expert_weight_bytes_per_step']})"
                 if "expert_ratio" in r else "")
        rows.append((f"serve.weight_bytes.{r['arch']}", 0.0,
                     f"dense={r['dense_weight_bytes_per_step']} "
                     f"joint={r['joint_weight_bytes_per_step']} "
                     f"({r['ratio']:.3f}x, target<={TARGET_RATIO}) "
                     f"eligible={r['eligible_ratio']:.3f}x "
                     f"max_diff={r['max_abs_diff_vs_fta_reference']:.1e}"
                     f"{extra}"))
    emit(rows)
    payload = {"value_sparsity": VALUE_SPARSITY,
               "target_ratio": TARGET_RATIO,
               "smoke": smoke,
               "archs": records,
               "pass": all(r["pass"] for r in records.values())}
    if out:
        with open(out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"[serve_bench] wrote {out}")
    failures = [a for a, r in records.items() if not r["pass"]]
    if failures:
        raise RuntimeError(
            f"joint serving weight traffic exceeds {TARGET_RATIO}x dense "
            f"for {failures} — see {out}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="dense + MoE archs only — the CI serve-path guard")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke, out=args.out)
