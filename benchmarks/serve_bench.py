"""Serving-path weight-traffic benchmark: dense vs stacked-joint decode.

Measures, via the trip-aware jaxpr walker, the WEIGHT bytes one decode
step moves through HBM on a reduced arch — once with plain dense serving,
once with the uniform-MAXB stacked joint-sparse tables threaded through
the decode scan — and emits the comparison as ``BENCH_serve.json``.

The contract under test: at 0.5 value sparsity the joint path must move
at most ``TARGET_RATIO`` (0.55x) of the dense-mode weight bytes — the
``(1 - value_sparsity) * 0.5`` packed-layout saving plus index/scale
overhead and the (mode-independent) dense unembedding. A violation
raises: this is the CI guard that the serving graph actually changed.

    PYTHONPATH=src python -m benchmarks.serve_bench [--smoke] \
        [--out BENCH_serve.json]

Shapes note: the bench arch is the reduced family config scaled up to
d_model=256 so the (128, 128) kernel tiles see >= 2 K-blocks per column
— at d_model=64 a projection is a single padded tile and tile-granular
value sparsity cannot exist.
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import decode_step, init_cache, init_params
from repro.runtime.jaxpr_cost import analyze
from repro.sparsity.sparse_linear import (build_stacked_tables,
                                          reconstruct_stacked_params)
from .common import emit

TARGET_RATIO = 0.55
VALUE_SPARSITY = 0.5
ARCHS = ("tinyllama-1.1b", "mamba2-1.3b")


def bench_cfg(arch: str, dtype: str = "bfloat16"):
    cfg = get_config(arch, reduced=True, dbpim_mode="joint")
    cfg = cfg.scaled(name=f"{cfg.name}-bench", dtype=dtype,
                     dbpim_value_sparsity=VALUE_SPARSITY)
    if cfg.family == "ssm":
        return cfg.scaled(d_model=256, ssm_state=64, ssm_head_dim=64)
    return cfg.scaled(d_model=256, n_heads=4, n_kv_heads=2, d_ff=512)


def _packed_bytes(tables) -> int:
    return sum(int(a.size * a.dtype.itemsize)
               for t in tables.arrays.values() for a in t.values())


def bench_arch(arch: str, batch: int = 4, max_len: int = 32) -> dict:
    # --- weight traffic at the serving dtype (bf16 dense baseline) ------
    cfg = bench_cfg(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    tables = build_stacked_tables(params, cfg)
    if tables is None:
        raise RuntimeError(f"{arch}: no stacked joint path — the serving "
                           "integration this bench guards is missing")
    cache = init_cache(cfg, batch, max_len)
    tok = jnp.ones((batch, 1), jnp.int32)

    dense_cost = analyze(
        lambda p, c, t: decode_step(p, c, t, cfg), params, cache, tok)
    joint_cost = analyze(
        lambda p, c, t: decode_step(p, c, t, cfg, tables=tables),
        params, cache, tok)
    dense_wb = dense_cost["weight_bytes"]
    joint_wb = joint_cost["weight_bytes"]
    if not dense_wb:
        raise RuntimeError(f"{arch}: dense decode step charged zero weight "
                           "bytes — the cost walker is broken")
    ratio = joint_wb / dense_wb

    # eligible-projection view: packed artifact vs its dense bf16 footprint
    eligible_dense = sum(
        2 * int(t["w_blocks"].shape[0]) * k * n      # L layers x K x N bf16
        for name, t in tables.arrays.items()
        for k, n in [tables.static[name][:2]])
    packed = _packed_bytes(tables)

    # --- numeric check at f32: joint decode == dense FTA reference ------
    cfg32 = bench_cfg(arch, dtype="float32")
    params32 = init_params(cfg32, jax.random.PRNGKey(0))
    tables32 = build_stacked_tables(params32, cfg32)
    recon32 = reconstruct_stacked_params(params32, tables32, cfg32)
    cache32 = init_cache(cfg32, batch, max_len)
    logits_j, _ = decode_step(params32, cache32, tok, cfg32, tables=tables32)
    logits_r, _ = decode_step(recon32, cache32, tok, cfg32)
    max_diff = float(jnp.max(jnp.abs(logits_j - logits_r)))
    scale = float(jnp.max(jnp.abs(logits_r)))
    if max_diff > 1e-3 * max(scale, 1.0):
        raise RuntimeError(
            f"{arch}: stacked joint decode diverged from the dense FTA "
            f"reference (max_diff={max_diff}, scale={scale})")

    return {
        "arch": cfg.name, "family": cfg.family, "batch": batch,
        "value_sparsity": VALUE_SPARSITY,
        "dense_weight_bytes_per_step": int(dense_wb),
        "joint_weight_bytes_per_step": int(joint_wb),
        "ratio": ratio,
        "eligible_dense_bf16_bytes": int(eligible_dense),
        "packed_table_bytes": int(packed),
        "eligible_ratio": packed / eligible_dense,
        "max_abs_diff_vs_fta_reference": max_diff,
        "logit_scale": scale,
        "target_ratio": TARGET_RATIO,
        "pass": ratio <= TARGET_RATIO,
    }


def run(smoke: bool = False, out: str = "BENCH_serve.json"):
    archs = ARCHS[:1] if smoke else ARCHS
    rows, records = [], {}
    for arch in archs:
        r = bench_arch(arch)
        records[r["arch"]] = r
        rows.append((f"serve.weight_bytes.{r['arch']}", 0.0,
                     f"dense={r['dense_weight_bytes_per_step']} "
                     f"joint={r['joint_weight_bytes_per_step']} "
                     f"({r['ratio']:.3f}x, target<={TARGET_RATIO}) "
                     f"eligible={r['eligible_ratio']:.3f}x "
                     f"max_diff={r['max_abs_diff_vs_fta_reference']:.1e}"))
    emit(rows)
    payload = {"value_sparsity": VALUE_SPARSITY,
               "target_ratio": TARGET_RATIO,
               "smoke": smoke,
               "archs": records,
               "pass": all(r["pass"] for r in records.values())}
    if out:
        with open(out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"[serve_bench] wrote {out}")
    failures = [a for a, r in records.items() if not r["pass"]]
    if failures:
        raise RuntimeError(
            f"joint serving weight traffic exceeds {TARGET_RATIO}x dense "
            f"for {failures} — see {out}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="first arch only — the CI serve-path guard")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke, out=args.out)
