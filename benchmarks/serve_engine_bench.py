"""Engine-level serving benchmark: chunked vs full-forward prefill.

Runs the SAME deterministic workload trace (Poisson arrivals, mixed
prompt lengths, fixed seed) through serving.ServeEngine twice — once with
chunked cache-filling prefill (prompt chunks of PREFILL_CHUNK tokens per
device call) and once with the full-forward baseline (every prompt token
rides a decode call) — over the stacked joint-sparse path, and emits
``BENCH_serve_engine.json``:

  * per-request steps-to-first-token (prefill device calls consumed by
    the prompt) under both policies;
  * served tokens per device step and MODELED weight bytes per served
    token (per-call weight bytes from the trip-aware jaxpr walker x call
    counts — chunked prefill reads the packed weights once per C prompt
    tokens instead of once per token);
  * engine tick / TTFT / queue-depth summaries from serving.metrics.

Guards (raise -> CI fails):
  1. both policies generate IDENTICAL tokens (chunked prefill is
     bit-identical math, only the step schedule changes);
  2. every request with prompt_len > PREFILL_CHUNK takes STRICTLY fewer
     prefill steps chunked than full-forward;
  3. chunked served tokens/step >= the full-forward baseline
     (the tinyllama reduced config is the CI-guarded cell).

    PYTHONPATH=src python -m benchmarks.serve_engine_bench [--smoke] \
        [--out BENCH_serve_engine.json]
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.mesh import make_test_mesh
from repro.launch.steps import (build_prefill_chunk_step,
                                build_slot_decode_step)
from repro.models import init_cache, init_params
from repro.runtime.jaxpr_cost import analyze
from repro.serving import ServeEngine, WorkloadSpec, make_trace
from repro.sparsity.sparse_linear import (build_stacked_tables,
                                          strip_packed_projections)
from .common import emit

ARCHS = ("tinyllama-1.1b", "mamba2-1.3b")
PREFILL_CHUNK = 8
N_SLOTS = 4
MAX_LEN = 48
SPEC = WorkloadSpec(n_requests=6, arrival_rate=1.0, prompt_len=(4, 24),
                    gen_len=(4, 8), dist="uniform", seed=7)


def _per_call_weight_bytes(cfg, mesh, params, tables) -> dict:
    """Modeled weight bytes one decode call / one prefill-chunk call moves
    through HBM (trip-aware jaxpr walk; packed kernels charge stored
    bytes only)."""
    cache = init_cache(cfg, N_SLOTS, MAX_LEN)
    cache["pos"] = jnp.zeros((N_SLOTS,), jnp.int32)
    if "attn" in cache:
        cache["attn"]["pos"] = jnp.zeros((N_SLOTS,), jnp.int32)
    decode_fn, _ = build_slot_decode_step(cfg, mesh, stacked_tables=tables)
    tok1 = jnp.zeros((N_SLOTS, 1), jnp.int32)
    act = jnp.ones((N_SLOTS,), bool)
    wb_decode = analyze(decode_fn, params, cache, tok1, act)["weight_bytes"]
    prefill_fn, _ = build_prefill_chunk_step(cfg, mesh,
                                             stacked_tables=tables)
    tokc = jnp.zeros((N_SLOTS, PREFILL_CHUNK), jnp.int32)
    nv = jnp.full((N_SLOTS,), PREFILL_CHUNK, jnp.int32)
    wb_prefill = analyze(prefill_fn, params, cache, tokc, nv)["weight_bytes"]
    return {"decode": float(wb_decode), "prefill_chunk": float(wb_prefill)}


def bench_arch(arch: str) -> dict:
    cfg = get_config(arch, reduced=True, dbpim_mode="joint")
    mesh = make_test_mesh()
    params = init_params(cfg, jax.random.PRNGKey(0))
    tables = build_stacked_tables(params, cfg)
    if tables is None:
        raise RuntimeError(f"{arch}: no stacked joint path — the serving "
                           "integration this bench measures is missing")
    params = strip_packed_projections(params, cfg)
    wb = _per_call_weight_bytes(cfg, mesh, params, tables)

    trace = make_trace(SPEC, cfg.vocab_size)
    runs = {}
    for mode in ("chunked", "full"):
        engine = ServeEngine(cfg, params, mesh=mesh, n_slots=N_SLOTS,
                             max_len=MAX_LEN, prefill_chunk=PREFILL_CHUNK,
                             prefill_mode=mode, stacked_tables=tables)
        outputs = engine.run(trace)
        s = engine.metrics.summary()
        total_wb = (s["decode_calls"] * wb["decode"]
                    + s["prefill_calls"] * wb["prefill_chunk"])
        runs[mode] = {
            "outputs": outputs,
            "summary": s,
            "per_request": engine.metrics.per_request(),
            "weight_bytes_per_served_token":
                total_wb / max(s["generated_tokens"], 1),
        }

    # guard 1: identical generations — the schedule changed, the math not
    if runs["chunked"]["outputs"] != runs["full"]["outputs"]:
        raise RuntimeError(f"{arch}: chunked and full-forward prefill "
                           "generated different tokens")

    # guard 2: strict prefill-step reduction for prompts > one chunk
    chunk_steps = {r["rid"]: r["prefill_steps"]
                   for r in runs["chunked"]["per_request"]}
    for r in runs["full"]["per_request"]:
        if r["prompt_len"] > PREFILL_CHUNK and \
                chunk_steps[r["rid"]] >= r["prefill_steps"]:
            raise RuntimeError(
                f"{arch}: req{r['rid']} (prompt {r['prompt_len']} > chunk "
                f"{PREFILL_CHUNK}) took {chunk_steps[r['rid']]} chunked "
                f"prefill steps vs {r['prefill_steps']} full — no "
                f"steps-to-first-token reduction")

    tps_c = runs["chunked"]["summary"]["tokens_per_step"]
    tps_f = runs["full"]["summary"]["tokens_per_step"]
    record = {
        "arch": cfg.name, "family": cfg.family,
        "prefill_chunk": PREFILL_CHUNK, "n_slots": N_SLOTS,
        "max_len": MAX_LEN,
        "workload": {"n_requests": SPEC.n_requests,
                     "arrival_rate": SPEC.arrival_rate,
                     "prompt_len": SPEC.prompt_len, "gen_len": SPEC.gen_len,
                     "dist": SPEC.dist, "seed": SPEC.seed},
        "per_call_weight_bytes": wb,
        "chunked": {k: v for k, v in runs["chunked"].items()
                    if k != "outputs"},
        "full": {k: v for k, v in runs["full"].items() if k != "outputs"},
        "tokens_per_step_chunked": tps_c,
        "tokens_per_step_full": tps_f,
        "ttft_ticks_mean_chunked":
            runs["chunked"]["summary"]["ttft_ticks_mean"],
        "ttft_ticks_mean_full": runs["full"]["summary"]["ttft_ticks_mean"],
        "pass": tps_c >= tps_f,
    }
    return record


def run(smoke: bool = False, out: str = "BENCH_serve_engine.json"):
    archs = ARCHS[:1] if smoke else ARCHS
    rows, records = [], {}
    for arch in archs:
        r = bench_arch(arch)
        records[r["arch"]] = r
        rows.append((
            f"serve_engine.{r['arch']}", 0.0,
            f"tok/step chunked={r['tokens_per_step_chunked']:.3f} "
            f"full={r['tokens_per_step_full']:.3f}  "
            f"ttft_ticks {r['ttft_ticks_mean_chunked']:.1f} vs "
            f"{r['ttft_ticks_mean_full']:.1f}  wB/token "
            f"{r['chunked']['weight_bytes_per_served_token']:.0f} vs "
            f"{r['full']['weight_bytes_per_served_token']:.0f}"))
    emit(rows)
    payload = {"smoke": smoke, "archs": records,
               "pass": all(r["pass"] for r in records.values())}
    if out:
        with open(out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"[serve_engine_bench] wrote {out}")
    failures = [a for a, r in records.items() if not r["pass"]]
    if failures:
        raise RuntimeError(
            f"chunked prefill served fewer tokens/step than the "
            f"full-forward baseline for {failures} — see {out}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="first arch only — the CI engine-path guard")
    ap.add_argument("--out", default="BENCH_serve_engine.json")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke, out=args.out)
