"""Engine-level serving benchmark: prefill policies + admission schedules.

Runs the SAME deterministic workload trace (Poisson arrivals, mixed
prompt lengths, fixed seed) through serving.ServeEngine under every
prefill policy the arch supports, over the stacked joint-sparse path,
and emits ``BENCH_serve_engine.json``:

  * per-ENGINE-CALL-KIND modeled weight bytes (decode vs
    prefill_chunk_exact vs prefill_parallel — trip-aware jaxpr walk,
    runtime.jaxpr_cost.analyze_call_kinds; packed kernels charge stored
    bytes only) and the same normalized PER PROMPT TOKEN — the number
    the parallel-form SSD prefill attacks;
  * per-request steps-to-first-token, served tokens per device step,
    weight bytes per served token, TTFT/queue summaries per policy;
  * a FIFO-vs-SPF admission case on a bimodal (chat-vs-document)
    workload: mean TTFT under both schedules.

Guards (raise -> CI fails):
  1. exact policies (chunked with cfg.prefill_exact for SSM; chunked as
     is for attention) generate IDENTICAL tokens to the full-forward
     baseline — only the step schedule changes;
  2. every request with prompt_len > PREFILL_CHUNK takes STRICTLY fewer
     prefill steps chunked than full-forward;
  3. chunked served tokens/step >= the full-forward baseline;
  4. SSM parallel-form prefill: first-token logits within
     models.ssm.PARALLEL_PREFILL_ATOL of the sequential-decode baseline,
     and prefill weight bytes PER PROMPT TOKEN <= 0.35x the exact-chunk
     path at C=8 (the ~C x projection-read saving, measured not
     asserted);
  5. SPF mean TTFT <= FIFO mean TTFT on the bimodal workload, with the
     no-starvation skip bound (skips <= spf_age_cap) intact;
  6. a ZERO-fault FaultPlan *with the tracer attached* leaves outputs
     and device-call count exactly unchanged vs the bare fault-free run
     (the fault layer AND the obs layer are free when idle — the
     zero-overhead-when-off contract);
  7. under a seeded fault schedule containing every fault kind, every
     completed request's tokens are BITWISE identical to the fault-free
     run (recovery-by-replay), with >= 1 of each kind detected;
  8. goodput under that schedule >= 0.9;
  9. per-call-kind weight-traffic WATERFALL rows (repro.obs.waterfall,
     attribution by parameter path) sum EXACTLY to the call kind's
     weight_bytes — no byte is unattributed;
 10. the recompile sentinel reports exactly ONE compile per
     (call_kind, arch) after every engine run — the fixed-shape
     no-recompile contract, measured not assumed;
 11. durability is PASSIVE — with the write-ahead journal and periodic
     snapshots ON (no crash), outputs and device-call count are exactly
     the bare run's;
 12. kill-chaos warm restart — the engine is killed (EngineCrash) at
     two seeded ticks, restored from the latest snapshot + journal
     tail, and every completed request's tokens are BITWISE identical
     to the uninterrupted run, on BOTH smoke archs (attention, and SSM
     under cfg.prefill_exact where chunk==decode must be exact);
 13. bounded redo — each restore's journal-evidenced re-prefilled
     tokens <= snapshot_every x slots restored (the cadence-vs-
     replay-work contract);
 14. paged continuous batching is BITWISE — a >= 1000-request long-tail
     workload (lognormal prompts, zipf generations) through the paged
     engine generates streams identical to the contiguous engine,
     preemption-resumes included;
 15. >= 1 preemption actually fired and goodput >= 0.9 under pressure;
 16. the paged KV pool is strictly smaller than the static cache;
 17. page churn causes ZERO recompiles (the table is a per-call
     operand, not a traced shape).

The chaos run is traced end to end; its span/event/interval stream plus
the waterfall is dumped to ``TRACE_serve_chaos.jsonl`` (a CI artifact)
and rendered through ``repro.launch.report`` as a smoke test. The
restart case dumps its own artifacts the same way — one tracer spans
the kill/restore chain (``TRACE_serve_restart.jsonl``) and the
recovered journal is preserved as ``JOURNAL_serve_restart.jsonl``.

    PYTHONPATH=src python -m benchmarks.serve_engine_bench [--smoke] \
        [--out BENCH_serve_engine.json] [--trace-out TRACE.jsonl]
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_test_mesh
from repro.models import init_cache, init_params
from repro.models.ssm import PARALLEL_PREFILL_ATOL
from repro.obs import Tracer, serving_cost_by_kind, validate
from repro.serving import (EngineCrash, FaultPlan, ServeEngine,
                           WorkloadSpec, make_trace)
from repro.serving.faults import INJECTABLE_KINDS
from repro.serving.faults import FaultEvent
from repro.serving.journal import fold_records, read_journal
from repro.sparsity.sparse_linear import (build_stacked_tables,
                                          strip_packed_projections)
from .common import emit

#: arctic is the MoE chunked-prefill case: no sliding window, so the
#: per-position capacity dispatch (models.moe.apply_moe per_position)
#: chunk-prefills — guard 1 holds it to generations IDENTICAL to
#: stepwise prefill, guard 2 to strictly fewer steps-to-first-token.
ARCHS = ("tinyllama-1.1b", "mamba2-1.3b", "arctic-480b")
PREFILL_CHUNK = 8
N_SLOTS = 4
MAX_LEN = 48
SPEC = WorkloadSpec(n_requests=6, arrival_rate=1.0, prompt_len=(4, 24),
                    gen_len=(4, 8), dist="uniform", seed=7)
#: guard 4 threshold: parallel-form SSM prefill weight bytes per prompt
#: token vs the exact-chunk path at C=8 — the CI-enforced >= 4x
#: reduction. The raw projection saving is ~1/C = 0.125; the unembedding
#: (once per chunk either way) dilutes it to a measured 0.174, which
#: leaves deterministic (modeled-bytes, no timing) headroom under 0.25.
SSM_PARALLEL_MAX_RATIO = 0.25
#: bimodal schedule case: short chats vs long documents competing for
#: two slots — the mix where shortest-prompt-first pays.
SCHED_SPEC = WorkloadSpec(n_requests=10, arrival_rate=2.0,
                          prompt_len=(3, 24), gen_len=(4, 6),
                          dist="bimodal", seed=13)
SCHED_SLOTS = 2
SPF_AGE_CAP = 4
#: chaos case: a Poisson trace under an injected fault schedule. The
#: arch is attention-family (tinyllama) so every prefill chunk —
#: recovery replays included — is BITWISE identical to sequential
#: decode, which is what makes the recovered-vs-fault-free equality an
#: exact guard, not a tolerance. seed/rate are picked so the sampled
#: plan contains every fault kind (asserted, so a regeneration that
#: loses one fails loudly).
CHAOS_SPEC = WorkloadSpec(n_requests=8, arrival_rate=0.8,
                          prompt_len=(3, 18), gen_len=(4, 8),
                          dist="uniform", seed=21)
CHAOS_FAULT_SEED = 3
CHAOS_FAULT_RATE = 0.2
CHAOS_GOODPUT_MIN = 0.9
#: kill-chaos restart case: same workload shape as chaos but its own
#: seed, the engine killed at two ticks derived from the uninterrupted
#: run's length (1/3 and 2/3 through — mid-prefill-and-decode, the
#: worst case for a restart). Snapshot cadence bounds redone work:
#: each restore may re-prefill at most RESTART_SNAPSHOT_EVERY journal-
#: evidenced tokens per restored slot (guard 13).
RESTART_SPEC = WorkloadSpec(n_requests=6, arrival_rate=0.5,
                            prompt_len=(3, 18), gen_len=(4, 8),
                            dist="uniform", seed=17)
RESTART_SNAPSHOT_EVERY = 4
#: continuous-batching case: a LONG-TAIL workload (lognormal prompts,
#: zipf generation lengths — most requests tiny, a heavy tail of big
#: ones) through the PAGED engine with a pool deliberately smaller than
#: the static worst-case cache. The shape is the argument for paging:
#: static slots reserve max_len for everyone, the pool reserves for the
#: traffic actually seen, and pressure spills into preemption instead
#: of rejection. CB_N_PAGES=9 vs the static 4x8=32 pages keeps the
#: pool at ~28% of worst case while goodput stays 1.0.
CB_SPEC = WorkloadSpec(n_requests=1000, arrival_rate=1.0,
                       prompt_len=(3, 16), gen_len=(3, 8),
                       dist="lognormal", gen_dist="zipf", seed=29)
CB_MAX_LEN = 32
CB_PAGE_SIZE = 4
CB_N_PAGES = 9
CB_GOODPUT_MIN = 0.9


def _mk_cache(cfg):
    cache = init_cache(cfg, N_SLOTS, MAX_LEN)
    cache["pos"] = jnp.zeros((N_SLOTS,), jnp.int32)
    if "attn" in cache:
        cache["attn"]["pos"] = jnp.zeros((N_SLOTS,), jnp.int32)
    return cache


def _weight_bytes_by_kind(cfg, mesh, params, tables) -> tuple:
    """(per-call weight bytes, per-parameter-path waterfall) for each
    engine call kind, keyed by the step builders' call_kind tags
    (repro.obs.waterfall.serving_cost_by_kind). Guard 9: each kind's
    waterfall rows must sum EXACTLY to its weight_bytes."""
    costs = serving_cost_by_kind(
        cfg, mesh, params, _mk_cache(cfg), n_slots=N_SLOTS,
        prefill_chunk=PREFILL_CHUNK, tables=tables,
        include_exact_fallback=True)
    wb = {kind: float(acc["weight_bytes"]) for kind, acc in costs.items()}
    waterfall = {kind: dict(acc["weight_bytes_by_path"])
                 for kind, acc in costs.items()}
    for kind, rows in waterfall.items():
        total = sum(rows.values())
        if total != wb[kind]:              # integer bytes: exact equality
            raise RuntimeError(
                f"{cfg.name}/{kind}: waterfall rows sum to {total}, "
                f"weight_bytes is {wb[kind]} — "
                f"{wb[kind] - total:+.0f} bytes unattributed")
    return wb, waterfall


def _per_prompt_token(wb_by_kind: dict) -> dict:
    """Normalize per-call weight bytes to PER PROMPT TOKEN for each way a
    prompt token can enter the cache: stepwise (decode call, 1 token per
    slot) or chunked (C tokens per slot)."""
    out = {}
    for kind, wb in wb_by_kind.items():
        tokens = N_SLOTS * (1 if kind == "decode" else PREFILL_CHUNK)
        out[kind] = wb / tokens
    return out


def _run_engine(cfg, params, mesh, tables, trace, prefill_mode):
    engine = ServeEngine(cfg, params, mesh=mesh, n_slots=N_SLOTS,
                         max_len=MAX_LEN, prefill_chunk=PREFILL_CHUNK,
                         prefill_mode=prefill_mode, stacked_tables=tables)
    outputs = engine.run(trace)
    return engine, outputs


def _check_sentinel(engine, label: str) -> dict:
    """Guard 10: after a full engine run, every registered jitted step
    compiled exactly once. check() already ran per tick; this pins the
    terminal counts into the BENCH record (0 = never called is fine for
    steps the policy skips, e.g. chunk prefill in "full" mode)."""
    counts = engine.sentinel.counts()
    over = {k: c for k, c in counts.items() if c > 1}
    if over:
        raise RuntimeError(f"{label}: steps recompiled: {over} — the "
                           f"fixed-shape no-recompile contract broke")
    return counts


def bench_arch(arch: str) -> dict:
    cfg = get_config(arch, reduced=True, dbpim_mode="joint")
    mesh = make_test_mesh()
    params = init_params(cfg, jax.random.PRNGKey(0))
    tables = build_stacked_tables(params, cfg)
    if tables is None:
        raise RuntimeError(f"{arch}: no stacked joint path — the serving "
                           "integration this bench measures is missing")
    params = strip_packed_projections(params, cfg)
    wb, waterfall = _weight_bytes_by_kind(cfg, mesh, params, tables)
    wb_per_tok = _per_prompt_token(wb)

    trace = make_trace(SPEC, cfg.vocab_size)
    # policies: "chunked" is the arch's default chunk math (parallel SSD
    # for SSM, exact for attention); SSM adds the exact-chunk fallback.
    policies = {"chunked": cfg, "full": cfg}
    if cfg.supports_parallel_prefill:
        policies = {"chunked": cfg,
                    "chunked_exact": cfg.scaled(prefill_exact=True),
                    "full": cfg}
    runs = {}
    recompile_counts = {}
    for mode, mode_cfg in policies.items():
        prefill_mode = "full" if mode == "full" else "chunked"
        engine, outputs = _run_engine(mode_cfg, params, mesh, tables,
                                      trace, prefill_mode)
        recompile_counts[mode] = _check_sentinel(engine, f"{arch}/{mode}")
        s = engine.metrics.summary()
        kind = engine.prefill_kind or "decode"
        total_wb = (s["decode_calls"] * wb["decode"]
                    + s["prefill_calls"] * wb.get(kind, 0.0))
        runs[mode] = {
            "prefill_kind": engine.prefill_kind,
            "outputs": outputs,
            "first_logits": engine.first_logits,
            "summary": s,
            "per_request": engine.metrics.per_request(),
            "weight_bytes_per_served_token":
                total_wb / max(s["generated_tokens"], 1),
        }

    # guard 1: exact chunk policy generates IDENTICAL tokens to full —
    # the schedule changed, the math not ("chunked_exact" for SSM, plain
    # "chunked" for attention where chunks are always exact)
    exact_mode = ("chunked_exact" if "chunked_exact" in runs else "chunked")
    if runs[exact_mode]["outputs"] != runs["full"]["outputs"]:
        raise RuntimeError(f"{arch}: {exact_mode} and full-forward prefill "
                           "generated different tokens")

    # guard 2: strict prefill-step reduction for prompts > one chunk
    chunk_steps = {r["rid"]: r["prefill_steps"]
                   for r in runs["chunked"]["per_request"]}
    for r in runs["full"]["per_request"]:
        if r["prompt_len"] > PREFILL_CHUNK and \
                chunk_steps[r["rid"]] >= r["prefill_steps"]:
            raise RuntimeError(
                f"{arch}: req{r['rid']} (prompt {r['prompt_len']} > chunk "
                f"{PREFILL_CHUNK}) took {chunk_steps[r['rid']]} chunked "
                f"prefill steps vs {r['prefill_steps']} full — no "
                f"steps-to-first-token reduction")

    # guard 3: chunked tokens/step >= the full-forward baseline
    tps_c = runs["chunked"]["summary"]["tokens_per_step"]
    tps_f = runs["full"]["summary"]["tokens_per_step"]

    record = {
        "arch": cfg.name, "family": cfg.family,
        "prefill_chunk": PREFILL_CHUNK, "n_slots": N_SLOTS,
        "max_len": MAX_LEN,
        "workload": {"n_requests": SPEC.n_requests,
                     "arrival_rate": SPEC.arrival_rate,
                     "prompt_len": SPEC.prompt_len, "gen_len": SPEC.gen_len,
                     "dist": SPEC.dist, "seed": SPEC.seed},
        "per_call_weight_bytes": wb,
        "weight_waterfall": waterfall,
        "recompile_counts": recompile_counts,
        "prefill_weight_bytes_per_prompt_token": wb_per_tok,
        "tokens_per_step_chunked": tps_c,
        "tokens_per_step_full": tps_f,
        "ttft_ticks_mean_chunked":
            runs["chunked"]["summary"]["ttft_ticks_mean"],
        "ttft_ticks_mean_full": runs["full"]["summary"]["ttft_ticks_mean"],
        "pass": tps_c >= tps_f,
    }
    for mode, run_ in runs.items():
        record[mode] = {k: v for k, v in run_.items()
                        if k not in ("outputs", "first_logits")}

    # guard 4 (SSM only): parallel-form equivalence + traffic contract
    if cfg.supports_parallel_prefill:
        atol = PARALLEL_PREFILL_ATOL[cfg.dtype]
        dmax = 0.0
        for rid, lg in runs["full"]["first_logits"].items():
            lp = runs["chunked"]["first_logits"][rid]
            dmax = max(dmax, float(np.max(np.abs(
                np.asarray(lg, np.float32) - np.asarray(lp, np.float32)))))
        ratio = (wb_per_tok["prefill_parallel"]
                 / wb_per_tok["prefill_chunk_exact"])
        record["parallel_max_abs_dlogits"] = dmax
        record["parallel_atol"] = atol
        record["parallel_over_exact_weight_ratio"] = ratio
        if dmax > atol:
            raise RuntimeError(
                f"{arch}: parallel-form prefill first-token logits drifted "
                f"max|d|={dmax:.4f} > atol={atol} from sequential decode")
        if ratio > SSM_PARALLEL_MAX_RATIO:
            raise RuntimeError(
                f"{arch}: parallel-form prefill weight bytes/prompt token "
                f"= {ratio:.3f}x of the exact chunk path at C="
                f"{PREFILL_CHUNK} (guard: <= {SSM_PARALLEL_MAX_RATIO})")
    return record


def bench_schedule(arch: str = "tinyllama-1.1b") -> dict:
    """FIFO vs shortest-prompt-first admission on a bimodal workload:
    more requests than slots, short chats queued behind long documents.
    Guard 5: SPF mean TTFT <= FIFO's, and no request is queue-jumped more
    than spf_age_cap times (the no-starvation bound)."""
    cfg = get_config(arch, reduced=True, dbpim_mode="joint")
    mesh = make_test_mesh()
    params = init_params(cfg, jax.random.PRNGKey(0))
    tables = build_stacked_tables(params, cfg)
    params = strip_packed_projections(params, cfg)
    trace = make_trace(SCHED_SPEC, cfg.vocab_size)
    out = {"arch": cfg.name, "n_slots": SCHED_SLOTS,
           "spf_age_cap": SPF_AGE_CAP,
           "workload": {"n_requests": SCHED_SPEC.n_requests,
                        "arrival_rate": SCHED_SPEC.arrival_rate,
                        "prompt_len": SCHED_SPEC.prompt_len,
                        "dist": SCHED_SPEC.dist, "seed": SCHED_SPEC.seed}}
    for schedule in ("fifo", "spf"):
        engine = ServeEngine(cfg, params, mesh=mesh, n_slots=SCHED_SLOTS,
                             max_len=MAX_LEN, prefill_chunk=PREFILL_CHUNK,
                             schedule=schedule, spf_age_cap=SPF_AGE_CAP,
                             stacked_tables=tables)
        engine.run(trace)
        s = engine.metrics.summary()
        out[schedule] = {"ttft_ticks_mean": s["ttft_ticks_mean"],
                         "ttft_ticks_p95": s["ttft_ticks_p95"],
                         "n_completed": s["n_completed"],
                         # skip entries are dropped at admission; the
                         # final counts live in per-request metrics
                         "max_skips": max(
                             (r.skips
                              for r in engine.metrics.requests.values()),
                             default=0)}
        if s["n_completed"] != SCHED_SPEC.n_requests:
            raise RuntimeError(f"schedule={schedule}: only "
                               f"{s['n_completed']} of "
                               f"{SCHED_SPEC.n_requests} completed")
    if out["spf"]["ttft_ticks_mean"] > out["fifo"]["ttft_ticks_mean"]:
        raise RuntimeError(
            f"spf mean TTFT {out['spf']['ttft_ticks_mean']:.2f} > fifo "
            f"{out['fifo']['ttft_ticks_mean']:.2f} on the bimodal workload")
    if out["spf"]["max_skips"] > SPF_AGE_CAP:
        raise RuntimeError(
            f"spf queue-jumped a request {out['spf']['max_skips']} times "
            f"> cap {SPF_AGE_CAP} — starvation bound broken")
    out["pass"] = True
    return out


def bench_chaos(arch: str = "tinyllama-1.1b",
                trace_out: str = "TRACE_serve_chaos.jsonl") -> dict:
    """Fault-tolerance + observability guard (BENCH key ``chaos``): the
    same Poisson trace runs fault-free (bare), under a ZERO-fault plan
    with the TRACER ATTACHED, and under a seeded fault schedule with
    every fault kind (also traced). Guards:

      6. zero-overhead-when-off — the traced zero-fault run's outputs
         AND device-call count are exactly the bare fault-free run's
         (neither the fault layer nor the obs layer may perturb the
         engine);
      7. bitwise recovery-by-replay — every request completed under
         faults carries IDENTICAL generated tokens to the fault-free
         run (the PR 3 chunk==decode invariant, weaponized as the
         recovery mechanism), with >= 1 of each fault kind actually
         landing (step exception, NaN logits, corrupted slot cache);
      8. goodput (completed / submitted) >= CHAOS_GOODPUT_MIN under the
         bench fault rate.

    The chaos run's trace (spans, lifecycle events, slot intervals,
    waterfall) is structurally validated, dumped to ``trace_out``, and
    rendered through the report CLI as a smoke test.
    """
    cfg = get_config(arch, reduced=True, dbpim_mode="joint")
    mesh = make_test_mesh()
    params = init_params(cfg, jax.random.PRNGKey(0))
    tables = build_stacked_tables(params, cfg)
    params = strip_packed_projections(params, cfg)
    trace = make_trace(CHAOS_SPEC, cfg.vocab_size)

    def run_once(plan, tracer=None):
        engine = ServeEngine(cfg, params, mesh=mesh, n_slots=N_SLOTS,
                             max_len=MAX_LEN, prefill_chunk=PREFILL_CHUNK,
                             stacked_tables=tables, fault_plan=plan,
                             tracer=tracer)
        outputs = engine.run(trace)
        return engine, outputs

    ref_engine, ref_out = run_once(None)
    ref_s = ref_engine.metrics.summary()

    # guard 6: a zero-fault plan + an attached tracer must BOTH be free
    zero_engine, zero_out = run_once(FaultPlan.none(),
                                     tracer=Tracer(arch=cfg.name))
    zero_s = zero_engine.metrics.summary()
    if zero_out != ref_out:
        raise RuntimeError(f"{arch}: a ZERO-fault FaultPlan + tracer "
                           "changed the generated tokens — the fault/obs "
                           "layer is not free when idle")
    if zero_s["device_calls"] != ref_s["device_calls"]:
        raise RuntimeError(
            f"{arch}: a ZERO-fault FaultPlan + tracer changed the "
            f"device-call count ({zero_s['device_calls']} vs "
            f"{ref_s['device_calls']}) — the fault/obs layer is not free")

    # the schedule outlives the fault-free run: recovery replays stretch
    # the faulted run past ref ticks, and faults must keep landing there
    plan = FaultPlan.generate(seed=CHAOS_FAULT_SEED,
                              n_ticks=2 * ref_s["engine_ticks"],
                              rate=CHAOS_FAULT_RATE, n_slots=N_SLOTS)
    # the sampler only ever emits the three INJECTABLE kinds —
    # engine_crash is scheduled explicitly by the restart case below
    missing = set(INJECTABLE_KINDS) - {e.kind for e in plan.events}
    if missing:
        raise RuntimeError(f"chaos plan (seed={CHAOS_FAULT_SEED}) lost "
                           f"fault kinds {missing} — re-pick the seed")
    chaos_tracer = Tracer(arch=cfg.name, meta={
        "case": "chaos", "n_slots": N_SLOTS,
        "prefill_chunk": PREFILL_CHUNK,
        "fault_seed": CHAOS_FAULT_SEED, "fault_rate": CHAOS_FAULT_RATE})
    chaos_engine, chaos_out = run_once(plan, tracer=chaos_tracer)
    s = chaos_engine.metrics.summary()

    # guard 7: bitwise recovery + every fault kind actually landed
    for rid, toks in chaos_out.items():
        if chaos_engine.metrics.requests[rid].outcome == "done" \
                and toks != ref_out[rid]:
            raise RuntimeError(
                f"{arch}: req{rid} recovered tokens differ from the "
                f"fault-free run — recovery-by-replay is not bitwise")
    detected = s["faults"]
    for needed in ("step_exception", "cache_corruption",
                   "nonfinite_logits"):
        if detected.get(needed, 0) < 1:
            raise RuntimeError(
                f"{arch}: chaos run detected no {needed!r} fault "
                f"(detected: {detected}) — the schedule missed a kind")

    # guard 8: goodput under faults
    if s["goodput"] < CHAOS_GOODPUT_MIN:
        raise RuntimeError(
            f"{arch}: chaos goodput {s['goodput']:.2f} < "
            f"{CHAOS_GOODPUT_MIN} at fault rate {CHAOS_FAULT_RATE}")

    # the chaos trace is the CI artifact: attach the waterfall, validate
    # structurally, dump, and render through the report CLI (smoke)
    from repro.obs import engine_waterfall
    for kind, wf in engine_waterfall(chaos_engine).items():
        chaos_tracer.waterfall(kind, wf["rows"], wf["total"])
    trace_stats = validate(chaos_tracer.records)
    if trace_out:
        chaos_tracer.dump(trace_out)
        from repro.launch.report import main as report_main
        print(f"[serve_engine_bench] chaos trace -> {trace_out} "
              f"({trace_stats}); report:")
        report_main([trace_out])

    return {
        "arch": cfg.name, "n_slots": N_SLOTS, "max_len": MAX_LEN,
        "prefill_chunk": PREFILL_CHUNK,
        "workload": {"n_requests": CHAOS_SPEC.n_requests,
                     "arrival_rate": CHAOS_SPEC.arrival_rate,
                     "prompt_len": CHAOS_SPEC.prompt_len,
                     "gen_len": CHAOS_SPEC.gen_len,
                     "dist": CHAOS_SPEC.dist, "seed": CHAOS_SPEC.seed},
        "fault_plan": {"seed": CHAOS_FAULT_SEED, "rate": CHAOS_FAULT_RATE,
                       "n_events": len(plan.events),
                       "by_kind": {k: sum(e.kind == k for e in plan.events)
                                   for k in INJECTABLE_KINDS}},
        "goodput": s["goodput"],
        "goodput_min": CHAOS_GOODPUT_MIN,
        "bitwise_recovery": True,
        "zero_overhead_traced": True,
        "trace_out": trace_out or None,
        "trace_stats": trace_stats,
        "recompile_counts": _check_sentinel(chaos_engine,
                                            f"{arch}/chaos"),
        "retries_by_kind": s["retries_by_kind"],
        "call_latency_ms": s["call_latency_ms"],
        "slot_busy_frac": s["slot_busy_frac"],
        "faults_detected": detected,
        "retries": s["retries"], "replays": s["replays"],
        "n_shed": s["n_shed"], "straggler_ticks": s["straggler_ticks"],
        "calls_by_kind": s["calls_by_kind"],
        "engine_ticks_fault_free": ref_s["engine_ticks"],
        "engine_ticks_chaos": s["engine_ticks"],
        "device_calls_fault_free": ref_s["device_calls"],
        "device_calls_chaos": s["device_calls"],
        "pass": True,
    }


def bench_restart(arch: str = "tinyllama-1.1b",
                  trace_out: str = "",
                  journal_out: str = "") -> dict:
    """Crash-safe serving guard (BENCH key ``restart``): the engine is
    KILLED at two seeded ticks (FaultPlan ``engine_crash`` ->
    EngineCrash between ticks) and brought back with
    ``ServeEngine.restore`` from the latest snapshot + write-ahead
    journal tail. Guards 11-13:

     11. durability passive — journal + snapshots ON, no crash: outputs
         and device-call count exactly the bare run's;
     12. bitwise warm restart — after >= 2 kill/restore cycles every
         request's tokens are IDENTICAL to the uninterrupted run (the
         chunk==decode invariant driving the restore re-prefill; the
         SSM arch runs under cfg.prefill_exact so its chunks are exact
         too);
     13. bounded redo — per restore, journal-evidenced re-prefilled
         tokens <= RESTART_SNAPSHOT_EVERY x slots restored.

    One tracer spans the whole kill/restore chain (crash, restore and
    snapshot events interleaved with the serving spans) and is dumped
    to ``trace_out``; the recovered journal — the single file that
    tells the run's whole story — is copied to ``journal_out``.
    """
    cfg = get_config(arch, reduced=True, dbpim_mode="joint")
    if cfg.supports_parallel_prefill:
        # restart re-prefill must be BITWISE, so the SSM serves exact
        # per-token chunks (the parallel form is tolerance-equivalent)
        cfg = cfg.scaled(prefill_exact=True)
    mesh = make_test_mesh()
    params = init_params(cfg, jax.random.PRNGKey(0))
    tables = build_stacked_tables(params, cfg)
    params = strip_packed_projections(params, cfg)
    trace = make_trace(RESTART_SPEC, cfg.vocab_size)

    def mk(**kw):
        return ServeEngine(cfg, params, mesh=mesh, n_slots=N_SLOTS,
                           max_len=MAX_LEN, prefill_chunk=PREFILL_CHUNK,
                           stacked_tables=tables, **kw)

    ref_engine = mk()
    ref_out = ref_engine.run(trace)
    ref_s = ref_engine.metrics.summary()

    with tempfile.TemporaryDirectory() as tmp:
        # guard 11: durability ON, no crash — exactly the bare run
        eng = mk(journal=os.path.join(tmp, "passive.jsonl"),
                 snapshot_dir=os.path.join(tmp, "passive-snaps"),
                 snapshot_every=RESTART_SNAPSHOT_EVERY)
        out = eng.run(trace)
        s = eng.metrics.summary()
        if out != ref_out:
            raise RuntimeError(
                f"{arch}: journal + snapshots changed the generated "
                "tokens — the durability layer is not passive")
        if s["device_calls"] != ref_s["device_calls"]:
            raise RuntimeError(
                f"{arch}: journal + snapshots changed the device-call "
                f"count ({s['device_calls']} vs {ref_s['device_calls']}) "
                "— the durability layer is not passive")

        # guard 12/13: kill at two ticks mid-run, restore, finish
        ticks = ref_s["engine_ticks"]
        crash_ticks = sorted({max(2, ticks // 3),
                              max(4, (2 * ticks) // 3)})
        plan = FaultPlan(events=tuple(
            FaultEvent(tick=t, kind="engine_crash") for t in crash_ticks))
        tracer = Tracer(arch=cfg.name, meta={
            "case": "restart", "n_slots": N_SLOTS,
            "prefill_chunk": PREFILL_CHUNK,
            "snapshot_every": RESTART_SNAPSHOT_EVERY,
            "crash_ticks": list(crash_ticks)})
        jpath = os.path.join(tmp, "journal.jsonl")
        snapdir = os.path.join(tmp, "snaps")
        engine = mk(journal=jpath, snapshot_dir=snapdir,
                    snapshot_every=RESTART_SNAPSHOT_EVERY,
                    fault_plan=plan, tracer=tracer)
        crashes, outputs, restores = 0, None, []
        try:
            outputs = engine.run(trace)
        except EngineCrash:
            crashes += 1
        while outputs is None:
            engine = ServeEngine.restore(
                cfg, params, snapshot_dir=snapdir, journal_path=jpath,
                mesh=mesh, stacked_tables=tables, fault_plan=plan,
                tracer=tracer)
            st = engine.restore_stats
            restores.append(st)
            if st["replayed_prefill_tokens"] > \
                    RESTART_SNAPSHOT_EVERY * max(st["slots_restored"], 1):
                raise RuntimeError(
                    f"{arch}: restore replayed "
                    f"{st['replayed_prefill_tokens']} prefill tokens for "
                    f"{st['slots_restored']} slots — over the "
                    f"snapshot_every={RESTART_SNAPSHOT_EVERY} bound")
            try:
                outputs = engine.resume()
            except EngineCrash:
                crashes += 1
        if crashes != len(crash_ticks):
            raise RuntimeError(
                f"{arch}: {crashes} crashes fired, expected "
                f"{len(crash_ticks)} at ticks {crash_ticks}")
        if outputs != ref_out:
            raise RuntimeError(
                f"{arch}: restarted run's tokens differ from the "
                "uninterrupted run — warm restart is not bitwise")

        # the recovered journal alone must replay the full token story
        recs, _, torn = read_journal(jpath)
        if torn:
            raise RuntimeError(f"{arch}: final journal has a torn tail")
        if {r: t for r, t in fold_records(recs)["tokens"].items()} \
                != ref_out:
            raise RuntimeError(
                f"{arch}: journal token records do not reproduce the "
                "generated streams")

        trace_stats = validate(tracer.records)
        if journal_out:
            shutil.copyfile(jpath, journal_out)
            print(f"[serve_engine_bench] restart journal -> {journal_out} "
                  f"({len(recs)} records)")
    if trace_out:
        tracer.dump(trace_out)
        print(f"[serve_engine_bench] restart trace -> {trace_out} "
              f"({trace_stats})")

    return {
        "arch": cfg.name, "n_slots": N_SLOTS, "max_len": MAX_LEN,
        "prefill_chunk": PREFILL_CHUNK,
        "prefill_exact": bool(cfg.supports_parallel_prefill),
        "snapshot_every": RESTART_SNAPSHOT_EVERY,
        "workload": {"n_requests": RESTART_SPEC.n_requests,
                     "arrival_rate": RESTART_SPEC.arrival_rate,
                     "prompt_len": RESTART_SPEC.prompt_len,
                     "gen_len": RESTART_SPEC.gen_len,
                     "dist": RESTART_SPEC.dist, "seed": RESTART_SPEC.seed},
        "engine_ticks_uninterrupted": ticks,
        "crash_ticks": list(crash_ticks),
        "n_crashes": crashes,
        "restores": restores,
        "replayed_prefill_tokens": sum(
            st["replayed_prefill_tokens"] for st in restores),
        "journal_records": len(recs),
        "durability_passive": True,
        "bitwise_restart": True,
        "trace_out": trace_out or None,
        "journal_out": journal_out or None,
        "trace_stats": trace_stats,
        "pass": True,
    }


def _cache_bytes(cache, keys) -> int:
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(cache)[0]:
        if str(getattr(path[-1], "key", path[-1])) in keys:
            total += leaf.size * leaf.dtype.itemsize
    return total


def bench_continuous_batching(arch: str = "tinyllama-1.1b",
                              n_requests: int = 0) -> dict:
    """Paged-cache continuous batching (BENCH key ``continuous``): the
    long-tail CB_SPEC workload (>= 1000 requests by default) through the
    paged engine with a pool ~3.5x smaller than the static cache, vs the
    contiguous engine on the SAME trace. Guards:

     14. bitwise paging — the paged run's generated streams are
         IDENTICAL to the contiguous run's, preemptions included (a
         preempted stream re-enters via the journaled-replay record and
         resumes on the chunk==decode invariant);
     15. pressure is survivable — >= 1 preemption actually happened
         (else the pool was not small enough to test anything) AND
         goodput >= CB_GOODPUT_MIN;
     16. the pool is genuinely smaller — paged KV pool bytes < the
         contiguous engine's static KV cache bytes;
     17. zero recompiles — page churn (tables are per-call operands)
         never retriggers compilation, per the sentinel.
    """
    cfg = get_config(arch, reduced=True, dbpim_mode="joint")
    mesh = make_test_mesh()
    params = init_params(cfg, jax.random.PRNGKey(0))
    tables = build_stacked_tables(params, cfg)
    params = strip_packed_projections(params, cfg)
    spec = CB_SPEC
    if n_requests and n_requests != spec.n_requests:
        from dataclasses import replace
        spec = replace(spec, n_requests=n_requests)
    trace = make_trace(spec, cfg.vocab_size)

    def mk(**kw):
        return ServeEngine(cfg, params, mesh=mesh, n_slots=N_SLOTS,
                           max_len=CB_MAX_LEN, prefill_chunk=PREFILL_CHUNK,
                           stacked_tables=tables,
                           queue_cap=spec.n_requests, **kw)

    ref = mk()
    ref_out = ref.run(trace)
    ref_s = ref.metrics.summary()
    eng = mk(paged=True, page_size=CB_PAGE_SIZE, n_pages=CB_N_PAGES)
    out = eng.run(trace)
    s = eng.metrics.summary()

    # guard 14: bitwise paging, preemption-resumes included
    if out != ref_out:
        bad = [r for r in ref_out if out.get(r) != ref_out[r]]
        raise RuntimeError(
            f"{arch}: paged run diverged from contiguous on "
            f"{len(bad)} streams (first: {bad[:5]}) — paging is not "
            f"bitwise")
    # guard 15: the pool was actually under pressure, and survived it
    if s["n_preemptions"] < 1:
        raise RuntimeError(
            f"{arch}: no preemption in {spec.n_requests} requests at "
            f"n_pages={CB_N_PAGES} — the pool is too big to exercise "
            f"page pressure")
    if s["goodput"] < CB_GOODPUT_MIN:
        raise RuntimeError(f"{arch}: continuous-batching goodput "
                           f"{s['goodput']:.3f} < {CB_GOODPUT_MIN}")
    # guard 16: the pool undercuts the static worst-case reservation
    pool_bytes = _cache_bytes(eng.cache, {"pk", "pv"})
    static_bytes = _cache_bytes(ref.cache, {"k", "v"})
    if not pool_bytes or not static_bytes or pool_bytes >= static_bytes:
        raise RuntimeError(
            f"{arch}: paged KV pool {pool_bytes}B >= static KV cache "
            f"{static_bytes}B — paging saved nothing")
    recompiles = _check_sentinel(eng, f"{arch}/continuous")  # guard 17

    return {
        "arch": cfg.name, "n_slots": N_SLOTS, "max_len": CB_MAX_LEN,
        "prefill_chunk": PREFILL_CHUNK,
        "page_size": CB_PAGE_SIZE, "n_pages": CB_N_PAGES,
        "workload": {"n_requests": spec.n_requests,
                     "arrival_rate": spec.arrival_rate,
                     "prompt_len": spec.prompt_len,
                     "gen_len": spec.gen_len, "dist": spec.dist,
                     "gen_dist": spec.gen_dist, "seed": spec.seed},
        "goodput": s["goodput"], "goodput_min": CB_GOODPUT_MIN,
        "n_preemptions": s["n_preemptions"],
        "page_alloc_failures": s["page_alloc_failures"],
        "pages_used_mean": s["pages_used_mean"],
        "pages_used_max": s["pages_used_max"],
        "pages_total": s["pages_total"],
        "pool_kv_bytes": pool_bytes,
        "static_kv_bytes": static_bytes,
        "pool_over_static": pool_bytes / static_bytes,
        "engine_ticks_paged": s["engine_ticks"],
        "engine_ticks_contiguous": ref_s["engine_ticks"],
        "tokens_per_step_paged": s["tokens_per_step"],
        "tokens_per_step_contiguous": ref_s["tokens_per_step"],
        "ttft_ticks_mean_paged": s["ttft_ticks_mean"],
        "ttft_ticks_mean_contiguous": ref_s["ttft_ticks_mean"],
        "recompile_counts": recompiles,
        "bitwise_paging": True,
        "pass": True,
    }


def run(smoke: bool = False, out: str = "BENCH_serve_engine.json",
        trace_out: str = "TRACE_serve_chaos.jsonl",
        restart_trace_out: str = "TRACE_serve_restart.jsonl",
        restart_journal_out: str = "JOURNAL_serve_restart.jsonl",
        cb_n_requests: int = 0):
    # smoke covers BOTH archs: mamba2's parallel-prefill traffic contract
    # (guard 4) is a CI guard, not a local-only measurement
    archs = ARCHS
    rows, records = [], {}
    for arch in archs:
        r = bench_arch(arch)
        records[r["arch"]] = r
        extra = ""
        if "parallel_over_exact_weight_ratio" in r:
            extra = (f"  parallel/exact wB/ptok "
                     f"{r['parallel_over_exact_weight_ratio']:.3f}x "
                     f"max|dlogit| {r['parallel_max_abs_dlogits']:.3f}")
        rows.append((
            f"serve_engine.{r['arch']}", 0.0,
            f"tok/step chunked={r['tokens_per_step_chunked']:.3f} "
            f"full={r['tokens_per_step_full']:.3f}  "
            f"ttft_ticks {r['ttft_ticks_mean_chunked']:.1f} vs "
            f"{r['ttft_ticks_mean_full']:.1f}{extra}"))
    sched = bench_schedule()
    rows.append((
        "serve_engine.schedule.bimodal", 0.0,
        f"ttft_ticks fifo={sched['fifo']['ttft_ticks_mean']:.2f} "
        f"spf={sched['spf']['ttft_ticks_mean']:.2f} "
        f"max_skips={sched['spf']['max_skips']}/{SPF_AGE_CAP}"))
    chaos = bench_chaos(trace_out=trace_out)
    rows.append((
        "serve_engine.chaos", 0.0,
        f"goodput={chaos['goodput']:.2f} (min {CHAOS_GOODPUT_MIN}) "
        f"faults={chaos['faults_detected']} replays={chaos['replays']} "
        f"bitwise_recovery={chaos['bitwise_recovery']} "
        f"traced_zero_overhead={chaos['zero_overhead_traced']}"))
    # kill-chaos restart on both smoke archs (attention + exact SSM);
    # artifacts come from the attention run
    restart = {}
    for arch in ("tinyllama-1.1b", "mamba2-1.3b"):
        first = arch == "tinyllama-1.1b"
        r = bench_restart(
            arch,
            trace_out=restart_trace_out if first else "",
            journal_out=restart_journal_out if first else "")
        restart[r["arch"]] = r
        rows.append((
            f"serve_engine.restart.{r['arch']}", 0.0,
            f"crashes={r['n_crashes']}@{r['crash_ticks']} "
            f"replayed_prefill_tokens={r['replayed_prefill_tokens']} "
            f"(cadence {r['snapshot_every']}) "
            f"bitwise_restart={r['bitwise_restart']} "
            f"durability_passive={r['durability_passive']}"))
    cb = bench_continuous_batching(n_requests=cb_n_requests)
    rows.append((
        "serve_engine.continuous", 0.0,
        f"n_requests={cb['workload']['n_requests']} "
        f"goodput={cb['goodput']:.2f} preemptions={cb['n_preemptions']} "
        f"pool/static={cb['pool_over_static']:.2f} "
        f"pages_used_max={cb['pages_used_max']}/{cb['pages_total']} "
        f"bitwise_paging={cb['bitwise_paging']}"))
    emit(rows)
    payload = {"smoke": smoke, "archs": records, "schedule": sched,
               "chaos": chaos, "restart": restart, "continuous": cb,
               "pass": all(r["pass"] for r in records.values())
               and sched["pass"] and chaos["pass"] and cb["pass"]
               and all(r["pass"] for r in restart.values())}
    if out:
        with open(out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"[serve_engine_bench] wrote {out}")
    failures = [a for a, r in records.items() if not r["pass"]]
    if failures:
        raise RuntimeError(
            f"chunked prefill served fewer tokens/step than the "
            f"full-forward baseline for {failures} — see {out}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI engine-path guard (same archs, marks the "
                         "JSON as a smoke artifact)")
    ap.add_argument("--out", default="BENCH_serve_engine.json")
    ap.add_argument("--trace-out", default="TRACE_serve_chaos.jsonl",
                    help="chaos-case trace artifact (JSONL; '' disables)")
    ap.add_argument("--restart-trace-out",
                    default="TRACE_serve_restart.jsonl",
                    help="restart-case trace artifact spanning the "
                         "kill/restore chain (JSONL; '' disables)")
    ap.add_argument("--restart-journal-out",
                    default="JOURNAL_serve_restart.jsonl",
                    help="restart-case recovered write-ahead journal "
                         "artifact ('' disables)")
    ap.add_argument("--n-requests", type=int, default=0,
                    help="continuous-batching case request count "
                         "(0 = the spec default, >= 1000)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke, out=args.out, trace_out=args.trace_out,
        restart_trace_out=args.restart_trace_out,
        restart_journal_out=args.restart_journal_out,
        cb_n_requests=args.n_requests)
