"""Tab. III — on-chip execution time (std/pw-conv + FC only): DAC'24 [16]
configuration vs bit-level vs hybrid-level DB-PIM.

The DAC'24 system is modeled as: bit-level weight sparsity only, no input
bit-column skipping, no sparse allocation network, and half the
filter-level parallelism (the journal version "expanded the architecture
to increase computational parallelism", Sec. VII). Absolute ms use the
500 MHz clock; the reproduction target is the RATIO structure
(paper: up to 11.10x vs DAC'24; bit->hybrid ~1.4-1.7x).
"""

from __future__ import annotations

import dataclasses

from repro.configs.paper_cnns import CNN_MODELS
from repro.core import pim_model as pm
from repro.core.workload_gen import model_metadata
from .common import emit, timed

ACCEL = ("std", "pw", "fc")


def run():
    rows = []
    dac_cfg = dataclasses.replace(pm.DEFAULT_PIM, n_cores=4,
                                  macros_per_core=2)
    for name in CNN_MODELS:
        layers = [l for l in CNN_MODELS[name]() if l.kind in ACCEL]
        def point():
            md = model_metadata(layers, 0.6, name, seed=0)
            md_nv = model_metadata(layers, 0.0, name, seed=0)
            dac = pm.evaluate_model(layers, md_nv, cfg=dac_cfg,
                                    use_value=False, use_input_bit=False)
            bit = pm.evaluate_model(layers, md_nv, use_value=False)
            hyb = pm.evaluate_model(layers, md)
            return (dac.time_ms(dac_cfg), bit.time_ms(), hyb.time_ms())
        (t_dac, t_bit, t_hyb), us = timed(point)
        rows.append((f"tab3.{name}", us,
                     f"dac24_ms={t_dac:.3f} bit_ms={t_bit:.3f} "
                     f"hybrid_ms={t_hyb:.3f} speedup_vs_dac={t_dac/t_hyb:.2f}x"))
    return emit(rows)


if __name__ == "__main__":
    run()
