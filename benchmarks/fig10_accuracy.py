"""Fig. 10 — accuracy: hybrid-grained pruning vs coarse-grained pruning at
matched compound sparsity.

REDUCED-SCALE reproduction (CIFAR-100 x 500 epochs is out of scope for a
1-core CPU container): a 2-layer MLP classifier on a synthetic separable
10-class problem, trained under IDENTICAL budgets (paper protocol) with
 (a) coarse-grained block pruning alone at compound sparsity s, and
 (b) hybrid pruning: block pruning at s_v + FTA bit sparsity
     (compound = 1 - (1-s_v) * 0.25).
The reproduction claim asserted here is the ORDERING: hybrid accuracy >=
coarse accuracy at matched compound sparsity, with the gap growing at 90%.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import pruning, qat
from .common import emit, timed

D_IN, D_H, N_CLS = 64, 128, 10
STEPS, LR, BATCH = 300, 5e-2, 128


def _data(rng, centers, n=4096):
    y = rng.integers(0, N_CLS, size=n)
    x = centers[y] + rng.normal(0, 0.9, size=(n, D_IN))
    return jnp.asarray(x, jnp.float32), jnp.asarray(y, jnp.int32)


def _init(rng):
    return {
        "w0": jnp.asarray(rng.normal(0, 0.1, (D_IN, D_H)), jnp.float32),
        "w1": jnp.asarray(rng.normal(0, 0.1, (D_H, N_CLS * 8)), jnp.float32),
    }


def _forward(params, x, masks, mode):
    """mode: dense | coarse | hybrid. N padded to multiple of alpha=8;
    logits use the first N_CLS columns of the last layer."""
    scale0 = jnp.maximum(jnp.max(jnp.abs(params["w0"])), 1e-6) / 127.0
    scale1 = jnp.maximum(jnp.max(jnp.abs(params["w1"])), 1e-6) / 127.0
    if mode == "dense":
        w0, w1 = params["w0"], params["w1"]
    elif mode == "coarse":
        w0 = params["w0"] * masks["w0"]
        w1 = params["w1"] * masks["w1"]
    else:  # hybrid: block mask + FTA projection with STE
        w0, _ = qat.fta_fake_quant(params["w0"], masks["w0"], scale0)
        w1, _ = qat.fta_fake_quant(params["w1"], masks["w1"], scale1)
    h = jax.nn.relu(x @ w0)
    return (h @ w1)[:, :N_CLS]


def _train_eval(mode, sparsity, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(0, 1.0, size=(N_CLS, D_IN))
    xtr, ytr = _data(rng, centers)
    xte, yte = _data(rng, centers, 2048)
    params = _init(rng)
    if mode == "coarse":
        sv = {"w0": sparsity, "w1": sparsity}
    elif mode == "hybrid":
        # FTA contributes 75% bit sparsity: 1-(1-sv)*0.25 = s  => sv
        sv = {k: max(0.0, 1 - (1 - sparsity) / 0.25) for k in ("w0", "w1")}
    else:
        sv = {"w0": 0.0, "w1": 0.0}
    masks = {k: pruning.block_prune_mask(params[k], sv[k], 8)
             for k in params}

    def loss_fn(p, xb, yb):
        logits = _forward(p, xb, masks, mode)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, yb[:, None], 1))

    @jax.jit
    def step(p, xb, yb):
        g = jax.grad(loss_fn)(p, xb, yb)
        return jax.tree_util.tree_map(lambda a, b: a - LR * b, p, g)

    n = xtr.shape[0]
    for i in range(STEPS):
        idx = (np.arange(BATCH) + i * BATCH) % n
        params = step(params, xtr[idx], ytr[idx])
    logits = _forward(params, xte, masks, mode)
    return float(jnp.mean(jnp.argmax(logits, -1) == yte))


def run():
    rows = []
    acc_dense, us = timed(_train_eval, "dense", 0.0)
    rows.append(("fig10.dense", us, f"acc={acc_dense*100:.1f}%"))
    ordering_ok = True
    for s, label in [(0.75, 75), (0.90, 90)]:
        acc_c, us_c = timed(_train_eval, "coarse", s)
        acc_h, us_h = timed(_train_eval, "hybrid", s)
        ordering_ok &= acc_h >= acc_c - 0.02
        rows.append((f"fig10.coarse.s{label}", us_c, f"acc={acc_c*100:.1f}%"))
        rows.append((f"fig10.hybrid.s{label}", us_h, f"acc={acc_h*100:.1f}%"))
    rows.append(("fig10.ordering", 0.0,
                 f"hybrid>=coarse_at_matched_sparsity={ordering_ok}"))
    return emit(rows)


if __name__ == "__main__":
    run()
