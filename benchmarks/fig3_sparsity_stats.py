"""Fig. 3 — sparsity richness statistics.

(a) proportion of zero bits in weights: original INT8, after 60% value
    pruning, and after hybrid (60% value + FTA bit) pruning;
(b) proportion of all-zero input bit columns for groups of 1 / 8 / 16.
"""

from __future__ import annotations

import numpy as np

from repro.configs.paper_cnns import CNN_MODELS
from repro.core import fta, pruning
from repro.core.csd import PHI_TABLE
from repro.core.pim_model import input_zero_col_fraction
from repro.core.workload_gen import (MODEL_WEIGHT_STATS, synth_activation,
                                     synth_quantized_weight)
from .common import emit, timed


def _zero_bit_frac(q: np.ndarray, mask=None) -> float:
    """Fraction of zero CSD digits over all (kept) weights, zeros included."""
    phi = PHI_TABLE[np.asarray(q, dtype=np.int32) - (-128)]
    if mask is not None:
        phi = phi * np.asarray(mask)
    return float(1.0 - phi.sum() / (8.0 * phi.size))


def run():
    rows = []
    rng = np.random.default_rng(0)
    for name, (base_q, dead) in MODEL_WEIGHT_STATS.items():
        layers = [l for l in CNN_MODELS[name]() if l.kind in ("std", "pw", "fc")]
        big = max(layers, key=lambda l: l.K * l.N)
        def stats():
            q = synth_quantized_weight(big.K, big.N - big.N % 8 or 8,
                                       base_q, rng, dead)
            ori = _zero_bit_frac(q)
            mask = np.asarray(pruning.block_prune_mask(
                q.astype(np.float32), 0.6, 8))
            val = _zero_bit_frac(q * mask)
            q_fta, _ = fta.fta_quantize(q, mask)
            ours = _zero_bit_frac(q_fta * mask)
            return ori, val, ours
        (ori, val, ours), us = timed(stats)
        rows.append((f"fig3a.{name}", us,
                     f"zero_bits ori={ori:.3f} val60={val:.3f} hybrid={ours:.3f}"))
    # (b) all-zero input bit columns vs group size
    acts = synth_activation(256, 1024, rng)
    for g in (1, 8, 16):
        frac, us = timed(input_zero_col_fraction, acts, g)
        rows.append((f"fig3b.group{g}", us, f"zero_col_frac={frac:.3f}"))
    return emit(rows)


if __name__ == "__main__":
    run()
