"""Fig. 12 — end-to-end speedup/energy breakdown by sparsity type
(value-only / bit-only incl. input skip / joint a.k.a. the paper's
"hybrid") over the five models, using the shared kernel-mode vocabulary
(paper_cnns.MODE_FLAGS == ModelConfig.dbpim_mode values).

Paper reference maxima: bit-level 5.46x / 77.66% savings; hybrid 8.01x /
85.28% savings; compact models much lower (SIMD-core share, Fig. 13).
"""

from __future__ import annotations

from repro.configs.paper_cnns import CNN_MODELS, MODE_FLAGS
from repro.core import pim_model as pm
from repro.core.workload_gen import model_metadata
from .common import emit, timed


def run():
    rows = []
    for name in CNN_MODELS:
        layers = CNN_MODELS[name]()
        dense = pm.evaluate_dense_baseline(layers)
        md = model_metadata(layers, 0.6, name, seed=0)
        for mode, kw in MODE_FLAGS.items():
            if mode == "dense":          # the baseline itself
                continue
            def point():
                ours = pm.evaluate_model(layers, md, **kw)
                return (dense.cycles / ours.cycles,
                        1 - ours.energy_pj / dense.energy_pj, ours.u_act)
            (sp, es, u), us = timed(point)
            rows.append((f"fig12.{name}.{mode}", us,
                         f"speedup={sp:.2f}x energy_savings={es*100:.1f}% "
                         f"u_act={u*100:.1f}%"))
    return emit(rows)


if __name__ == "__main__":
    run()
