"""Benchmark harness: one module per paper table/figure + kernel micro-
benchmarks + the roofline table. Prints ``name,us_per_call,derived`` CSV."""

from __future__ import annotations

import importlib
import sys
import traceback

MODULES = [
    "benchmarks.fig3_sparsity_stats",
    "benchmarks.fig10_accuracy",
    "benchmarks.fig11_speedup",
    "benchmarks.fig12_breakdown",
    "benchmarks.fig13_op_breakdown",
    "benchmarks.tab2_comparison",
    "benchmarks.tab3_exec_time",
    "benchmarks.kernel_bench",
    "benchmarks.roofline_table",
]


def main() -> None:
    print("name,us_per_call,derived")
    failures = []
    for modname in MODULES:
        try:
            mod = importlib.import_module(modname)
            mod.run()
        except Exception as e:  # keep the harness going, report at the end
            failures.append((modname, e))
            traceback.print_exc()
    if failures:
        print(f"FAILED modules: {[m for m, _ in failures]}", file=sys.stderr)
        sys.exit(1)


if __name__ == '__main__':
    main()
