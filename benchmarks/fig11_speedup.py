"""Fig. 11 — speedup / energy vs dense digital-PIM baseline on VGG19,
ResNet18, MobileNetV2 at 75/80/85/90% weight sparsity.

Protocol (Sec. VI-C): only value + bit sparsity of WEIGHTS; dynamic input
bit-column skipping disabled; only std/pw-conv + FC layers evaluated.
Paper reference: VGG19 5.50x-8.10x, energy savings 73.68%-83.90%.
"""

from __future__ import annotations

from repro.configs.paper_cnns import CNN_MODELS
from repro.core import pim_model as pm
from repro.core.workload_gen import model_metadata
from .common import emit, timed

SPARSITY_POINTS = [(0.0, 75), (0.2, 80), (0.4, 85), (0.6, 90)]
ACCEL = ("std", "pw", "fc")


def run():
    rows = []
    for name in ("vgg19", "resnet18", "mobilenetv2"):
        layers = [l for l in CNN_MODELS[name]() if l.kind in ACCEL]
        dense = pm.evaluate_dense_baseline(layers)
        for vs, label in SPARSITY_POINTS:
            def point():
                md = model_metadata(layers, vs, name, seed=0)
                ours = pm.evaluate_model(layers, md, use_input_bit=False)
                return (dense.cycles / ours.cycles,
                        1 - ours.energy_pj / dense.energy_pj,
                        ours.u_act)
            (sp, es, u), us = timed(point)
            rows.append((f"fig11.{name}.s{label}", us,
                         f"speedup={sp:.2f}x energy_savings={es*100:.1f}% "
                         f"u_act={u*100:.1f}%"))
    return emit(rows)


if __name__ == "__main__":
    run()
