"""Tab. II — actual utilization U_act per model and peak throughput
per macro.

Paper reference: U_act = 85.04% (AlexNet), 86.77% (VGG19), 86.29%
(ResNet18), 81.38% (MNv2), 78.44% (EffNetB0); peak throughput/macro
77.5 GOPS (8b/8b); 2.48 TOPS system peak.
"""

from __future__ import annotations

from repro.configs.paper_cnns import CNN_MODELS
from repro.core import pim_model as pm
from repro.core.workload_gen import model_metadata
from .common import emit, timed

ACCEL = ("std", "pw", "fc")


def peak_throughput(cfg: pm.PIMConfig = pm.DEFAULT_PIM):
    """Architectural peak, 8b/8b OPS (MAC = 2 OPS), phi_th = 1 packing.

    Each cell holds one Comp pattern = a complete phi_1 INT8 weight; a MAC
    completes after the effective serial input bits. The paper's 77.5
    GOPS/macro corresponds to the IPU-assisted effective ~3.3 bits/input.
    """
    cells = cfg.compartments * cfg.rows_per_compartment * cfg.columns
    eff_bits = 3.3
    # per macro: 256 cells complete 256 MACs every (16 rows x eff_bits)
    macs_per_cycle = cells / (cfg.rows_per_compartment * eff_bits)
    gops_per_macro = macs_per_cycle * 2 * cfg.freq_mhz / 1e3
    n_macros = cfg.n_cores * cfg.macros_per_core
    tops_total = gops_per_macro * n_macros / 1e3
    return gops_per_macro, tops_total


def run():
    rows = []
    (gops, tops), us = timed(peak_throughput)
    rows.append(("tab2.peak_throughput", us,
                 f"gops_per_macro={gops:.1f} tops_total={tops:.2f}"))
    for name in CNN_MODELS:
        layers = [l for l in CNN_MODELS[name]() if l.kind in ACCEL]
        def point():
            md = model_metadata(layers, 0.6, name, seed=0)
            ours = pm.evaluate_model(layers, md)
            return ours.u_act
        u, us = timed(point)
        rows.append((f"tab2.u_act.{name}", us, f"u_act={u*100:.2f}%"))
    return emit(rows)


if __name__ == "__main__":
    run()
