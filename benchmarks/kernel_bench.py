"""Kernel micro-benchmarks (interpret mode — correctness + derived
traffic/compression stats; wall time on CPU is NOT a TPU metric, the
derived column reports the structural savings the kernel realizes)."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import pruning
from repro.kernels import ops, ref
from .common import emit, timed


def run():
    rows = []
    rng = np.random.default_rng(0)

    # block-sparse: HBM bytes scale with survival
    M, K, N = 256, 1024, 256
    x = jnp.asarray(rng.normal(0, 1, (M, K)), jnp.float32)
    w = rng.normal(0, 1, (K, N)).astype(np.float32)
    for sp in (0.0, 0.5, 0.75):
        kt = K // 128
        alive = rng.random((kt, N // 128)) >= sp
        mask = np.repeat(np.repeat(alive, 128, 0), 128, 1)
        w_blocks, idx = ops.pack_block_sparse(w * mask,
                                              np.ones_like(w, np.int32))
        (y,), us = timed(lambda: (ops.sparse_dense(x, w_blocks, idx),))
        dense_bytes = w.nbytes
        stored = w_blocks.size * 4
        rows.append((f"kernel.block_sparse.s{int(sp*100)}", us,
                     f"weight_bytes={stored} vs dense={dense_bytes} "
                     f"({stored/dense_bytes:.2f}x)"))

    # fta int8: 2x weight traffic vs bf16, 4x vs f32
    wq = jnp.asarray(rng.integers(-127, 128, (1024, 256)), jnp.int8)
    scales = jnp.asarray(rng.uniform(0.005, 0.02, (1, 256)), jnp.float32)
    xb = jnp.asarray(rng.normal(0, 1, (256, 1024)), jnp.bfloat16)
    (y,), us = timed(lambda: (ops.fta_dense(xb, wq, scales),))
    rows.append(("kernel.fta_int8", us,
                 f"weight_bytes={wq.size} vs bf16={wq.size*2} (0.50x)"))

    # dbmu bit-true sim
    from repro.core import fta as fta_mod, dyadic
    q = rng.integers(-127, 128, (128, 128), dtype=np.int32)
    q_fta, _ = fta_mod.fta_quantize(q, np.ones_like(q))
    packed = dyadic.pack_terms(q_fta)
    xi = rng.integers(-127, 128, (16, 128), dtype=np.int32)
    got, us = timed(lambda: np.asarray(ops.dbmu_reference_check(xi, packed)))
    exact = bool((got == ref.dbmu_matmul_ref(xi, packed)).all())
    rows.append(("kernel.dbmu_sim", us, f"bit_true_exact={exact}"))
    return emit(rows)


if __name__ == "__main__":
    run()
