"""Kernel micro-benchmarks (interpret mode — correctness + derived
traffic/compression stats; wall time on CPU is NOT a TPU metric, the
derived column reports the structural savings the kernel realizes).

``--smoke`` runs tiny shapes only — the CI guard that the kernels still
compile and match their references without TPU hardware.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.configs.paper_cnns import joint_bench_shapes
from repro.core import pruning
from repro.kernels import ops, ref
from .common import emit, timed


_tile_mask = ops.random_tile_mask        # shared with tests: one semantics


def _joint_cases(rows, smoke: bool):
    """dense / value-only / bit-only / joint weight traffic on the paper's
    layer shapes (largest conv per CNN + AlexNet fc), plus a joint-vs-
    dense-reference correctness probe."""
    rng = np.random.default_rng(7)
    shapes = ([("smoke", 128, 256, 256)] if smoke
              else joint_bench_shapes(max_m=256))
    sparsity = 0.5
    for name, M, K, N in shapes:
        w = rng.laplace(0, 0.02, (K, N)).astype(np.float32)
        mask = _tile_mask(rng, K, N, sparsity)
        survival = mask.mean()

        dense_bytes = 2 * K * N                       # bf16 baseline
        value_bytes = int(2 * K * N * survival)       # compacted bf16
        bit_bytes = K * N                             # dense int8
        packed = ops.pack_joint_sparse(w, mask)
        joint_bytes = ops.joint_storage_bytes(packed)

        x = jnp.asarray(rng.normal(0, 1, (M, K)), jnp.float32)
        (y,), us = timed(lambda: (ops.joint_dense(x, packed),))
        want = ref.joint_packed_ref(x, packed)
        err = float(jnp.max(jnp.abs(y - want))
                    / jnp.maximum(jnp.max(jnp.abs(want)), 1e-6))
        # the CI guard: a kernel-vs-reference mismatch must FAIL the run
        # even under `python -O` (which strips bare asserts)
        if not err < 1e-4:
            raise RuntimeError(f"joint kernel diverged on {name}: "
                               f"rel_err={err}")
        rows.append((f"kernel.joint.{name}", us,
                     f"bytes dense={dense_bytes} value={value_bytes} "
                     f"bit={bit_bytes} joint={joint_bytes} "
                     f"({joint_bytes/dense_bytes:.2f}x) rel_err={err:.1e}"))


def _stacked_case(rows):
    """Uniform-MAXB stacked pack driven through a layer scan — the smoke
    guard for the stacked serving path: every per-layer slice must match
    the dense reference of ITS layer, and balanced pruning must produce
    zero padded slots."""
    rng = np.random.default_rng(11)
    L, M, K, N = 3, 8, 256, 256
    ws = rng.laplace(0, 0.02, (L, K, N)).astype(np.float32)
    packed = ops.pack_joint_sparse_stacked(ws, value_sparsity=0.5)
    nb = np.asarray(packed.nblocks)
    if not (nb == packed.maxb).all():
        raise RuntimeError(f"stacked pack has padded slots: nblocks={nb} "
                           f"vs MAXB={packed.maxb}")
    dense = ops.unpack_joint_sparse_stacked(packed)
    x = jnp.asarray(rng.normal(0, 1, (M, K)), jnp.float32)

    def body(carry, slices):
        wb, idx, sc, nbl = slices
        layer = ops.JointPacked(wb, idx, sc, nbl, packed.k, packed.n,
                                packed.k_pad)
        return carry, ops.joint_dense(carry, layer)

    import jax
    xs = (packed.w_blocks, packed.idx, packed.scales, packed.nblocks)
    (ys,), us = timed(lambda: (jax.lax.scan(body, x, xs)[1],))
    err = 0.0
    for l in range(L):
        want = x @ jnp.asarray(dense[l])
        err = max(err, float(jnp.max(jnp.abs(ys[l] - want))
                             / jnp.maximum(jnp.max(jnp.abs(want)), 1e-6)))
    if not err < 1e-4:
        raise RuntimeError(f"stacked joint scan diverged: rel_err={err}")
    stored = ops.joint_storage_bytes(packed)
    dense_bytes = 2 * L * K * N
    rows.append(("kernel.joint.stacked_scan", us,
                 f"L={L} MAXB={packed.maxb} bytes={stored} vs "
                 f"dense_bf16={dense_bytes} ({stored/dense_bytes:.2f}x) "
                 f"rel_err={err:.1e}"))


def _grouped_case(rows):
    """Grouped (L, E) expert pack driven through a layer scan with a
    per-expert dispatch loop — the MoE serving layout smoke guard: every
    (layer, expert) slice must match the dense reference of ITS slice,
    and balanced pruning must leave zero padded slots group-wide."""
    import jax
    rng = np.random.default_rng(17)
    L, E, M, K, N = 2, 4, 8, 256, 256
    ws = rng.laplace(0, 0.02, (L, E, K, N)).astype(np.float32)
    packed = ops.pack_joint_sparse_grouped(ws, value_sparsity=0.5)
    nb = np.asarray(packed.nblocks)
    if not (nb == packed.maxb).all():
        raise RuntimeError(f"grouped pack has padded slots: nblocks={nb} "
                           f"vs MAXB={packed.maxb}")
    dense = ops.unpack_joint_sparse_grouped(packed)
    x = jnp.asarray(rng.normal(0, 1, (M, K)), jnp.float32)

    def body(carry, slices):
        wb, idx, sc, nbl = slices               # (E, ...) per layer
        ys = [ops.joint_dense(
            carry, ops.JointPacked(wb[e], idx[e], sc[e], nbl[e],
                                   packed.k, packed.n, packed.k_pad))
            for e in range(E)]
        return carry, jnp.stack(ys)

    xs = (packed.w_blocks, packed.idx, packed.scales, packed.nblocks)
    (ys,), us = timed(lambda: (jax.lax.scan(body, x, xs)[1],))
    err = 0.0
    for l in range(L):
        for e in range(E):
            want = x @ jnp.asarray(dense[l, e])
            err = max(err, float(jnp.max(jnp.abs(ys[l, e] - want))
                                 / jnp.maximum(jnp.max(jnp.abs(want)),
                                               1e-6)))
    if not err < 1e-4:
        raise RuntimeError(f"grouped joint scan diverged: rel_err={err}")
    stored = ops.joint_storage_bytes(packed)
    dense_bytes = 2 * L * E * K * N
    rows.append(("kernel.joint.grouped_experts", us,
                 f"L={L} E={E} MAXB={packed.maxb} bytes={stored} vs "
                 f"dense_bf16={dense_bytes} ({stored/dense_bytes:.2f}x) "
                 f"rel_err={err:.1e}"))


def _ssm_parallel_prefill_case(rows):
    """Stacked-SSM parallel-form prefill driven through the Pallas joint
    path: one decode_chunk with the default parallel SSD chunk
    (models.ssm.prefill_ssm_parallel — in/out projections read once per
    chunk) vs the exact per-token recurrence, both over the SAME stacked
    joint tables. Guards the tolerance contract
    (models.ssm.PARALLEL_PREFILL_ATOL) on the kernel path itself."""
    import jax
    from repro.configs import get_config
    from repro.models import decode_chunk, init_cache, init_params
    from repro.models.ssm import PARALLEL_PREFILL_ATOL
    from repro.sparsity.sparse_linear import build_stacked_tables

    cfg = get_config("mamba2-1.3b", reduced=True, dbpim_mode="joint")
    cfg = cfg.scaled(dtype="float32", dbpim_value_sparsity=0.5)
    params = init_params(cfg, jax.random.PRNGKey(0))
    tables = build_stacked_tables(params, cfg, bk=32, bn=32)
    rng = np.random.default_rng(5)
    B, C = 2, 8
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (B, C)), jnp.int32)
    nv = jnp.full((B,), C, jnp.int32)
    cache = init_cache(cfg, B, 32)
    cache["pos"] = jnp.zeros((B,), jnp.int32)

    (lp, cache_p), us = timed(
        lambda: decode_chunk(params, cache, toks, nv, cfg, tables=tables))
    le, cache_e = decode_chunk(params, cache, toks, nv,
                               cfg.scaled(prefill_exact=True),
                               tables=tables)
    atol = PARALLEL_PREFILL_ATOL[cfg.dtype]
    dl = float(jnp.max(jnp.abs(lp.astype(jnp.float32)
                               - le.astype(jnp.float32))))
    ds = float(jnp.max(jnp.abs(cache_p["ssm"]["state"]
                               - cache_e["ssm"]["state"])))
    if not (dl <= atol and ds <= atol):
        raise RuntimeError(
            f"stacked-SSM parallel prefill diverged from the exact chunk: "
            f"max|dlogit|={dl:.2e} max|dstate|={ds:.2e} > atol={atol}")
    rows.append(("kernel.ssm_parallel_prefill", us,
                 f"C={C} proj_reads 1 vs {C} (parallel vs exact) "
                 f"max|dlogit|={dl:.1e} max|dstate|={ds:.1e} atol={atol}"))


def run(smoke: bool = False):
    rows = []
    rng = np.random.default_rng(0)

    # block-sparse: HBM bytes scale with survival
    M, K, N = (128, 256, 256) if smoke else (256, 1024, 256)
    x = jnp.asarray(rng.normal(0, 1, (M, K)), jnp.float32)
    w = rng.normal(0, 1, (K, N)).astype(np.float32)
    for sp in ((0.5,) if smoke else (0.0, 0.5, 0.75)):
        mask = _tile_mask(rng, K, N, sp)
        w_blocks, idx = ops.pack_block_sparse(w * mask,
                                              np.ones_like(w, np.int32))
        (y,), us = timed(lambda: (ops.sparse_dense(x, w_blocks, idx),))
        dense_bytes = w.nbytes
        stored = w_blocks.size * 4
        rows.append((f"kernel.block_sparse.s{int(sp*100)}", us,
                     f"weight_bytes={stored} vs dense={dense_bytes} "
                     f"({stored/dense_bytes:.2f}x)"))

    # fta int8: 2x weight traffic vs bf16, 4x vs f32
    Kq = 512 if smoke else 1024
    wq = jnp.asarray(rng.integers(-127, 128, (Kq, 256)), jnp.int8)
    scales = jnp.asarray(rng.uniform(0.005, 0.02, (1, 256)), jnp.float32)
    xb = jnp.asarray(rng.normal(0, 1, (M, Kq)), jnp.bfloat16)
    (y,), us = timed(lambda: (ops.fta_dense(xb, wq, scales),))
    rows.append(("kernel.fta_int8", us,
                 f"weight_bytes={wq.size} vs bf16={wq.size*2} (0.50x)"))

    # joint value x bit: the paper's headline configuration
    _joint_cases(rows, smoke)

    # stacked joint pack driven through a scan — the serving layout
    _stacked_case(rows)

    # grouped (layer x expert) pack — the MoE serving layout
    _grouped_case(rows)

    # parallel-form SSM prefill through the stacked Pallas path
    _ssm_parallel_prefill_case(rows)

    # dbmu bit-true sim
    from repro.core import fta as fta_mod, dyadic
    q = rng.integers(-127, 128, (128, 128), dtype=np.int32)
    q_fta, _ = fta_mod.fta_quantize(q, np.ones_like(q))
    packed = dyadic.pack_terms(q_fta)
    xi = rng.integers(-127, 128, (16, 128), dtype=np.int32)
    got, us = timed(lambda: np.asarray(ops.dbmu_reference_check(xi, packed)))
    exact = bool((got == ref.dbmu_matmul_ref(xi, packed)).all())
    if not exact:
        raise RuntimeError("DBMU bit-true equivalence broken")
    rows.append(("kernel.dbmu_sim", us, f"bit_true_exact={exact}"))
    return emit(rows)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, interpret mode — CI kernel guard")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke)
